"""Repo-root conftest: make `pytest` work without PYTHONPATH=src.

pytest.ini's ``pythonpath = src`` covers the normal invocation; this is
the belt-and-braces path injection for runs that bypass ini discovery
(e.g. `pytest /abs/path/to/repo/tests` from another rootdir).
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
