"""Serial vs cohort-vectorized round latency — the perf receipt for the
fused round (core/round.py ``make_cohort_round``).

Runs the SAME FederatedTrainer twice on a small dense task — once with
the historical serial path (one jit dispatch per client + host-side
stack, cfg.vectorize=False) and once with the fused cohort round — and
records per-round wall time after warm-up to BENCH_cohort.json.

  PYTHONPATH=src python -m benchmarks.bench_cohort            # K=10, CPU
  PYTHONPATH=src python -m benchmarks.bench_cohort --clients 32 --rounds 50
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import FLConfig, FederatedTrainer

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_cohort.json")


def build_task(num_clients: int, batches_per_client: int, batch: int,
               dim: int, hidden: int, classes: int, seed: int = 0):
    """Small MLP classification — the regime the paper's simulations live
    in, where per-client dispatch overhead rivals the math."""
    r = np.random.RandomState(seed)
    scale = 1.0 / np.sqrt(dim)
    params = {
        "w1": jnp.asarray(r.randn(dim, hidden) * scale, jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jnp.asarray(r.randn(hidden, classes) * scale, jnp.float32),
        "b2": jnp.zeros((classes,), jnp.float32),
    }

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, b["y"][:, None], axis=1))

    data = []
    for c in range(num_clients):
        rc = np.random.RandomState(seed + 1 + c)
        data.append([{"x": rc.randn(batch, dim).astype(np.float32),
                      "y": rc.randint(0, classes, size=batch).astype(np.int32)}
                     for _ in range(batches_per_client)])
    batch_fn = lambda c, t: data[c]
    return params, loss_fn, batch_fn


def bench(vectorize: bool, *, params, loss_fn, batch_fn, k: int,
          rounds: int, warmup: int, algorithm: str) -> Dict:
    cfg = FLConfig(algorithm=algorithm, rounds=warmup + rounds,
                   clients_per_round=k, eta_l=0.05, eta_g=0.1, seed=0,
                   eval_every=10 ** 9, vectorize=vectorize)
    tr = FederatedTrainer(loss_fn, params, k, batch_fn, cfg, None)
    for t in range(warmup):                       # compile + cache warm
        tr.run_round(t)
    times = []
    for t in range(warmup, warmup + rounds):
        rec = tr.run_round(t)
        times.append(rec.seconds)
    times = np.asarray(times)
    return {"mean_s": float(times.mean()), "p50_s": float(np.median(times)),
            "p90_s": float(np.percentile(times, 90)),
            "min_s": float(times.min()), "rounds": int(rounds)}


def run(clients: int = 10, rounds: int = 40, warmup: int = 3,
        batches_per_client: int = 4, batch: int = 16, dim: int = 32,
        hidden: int = 32, classes: int = 10, algorithm: str = "feddpc",
        out: str = DEFAULT_OUT) -> Dict:
    params, loss_fn, batch_fn = build_task(
        clients, batches_per_client, batch, dim, hidden, classes)
    results = {}
    for mode, vectorize in (("serial", False), ("vectorized", True)):
        results[mode] = bench(vectorize, params=params, loss_fn=loss_fn,
                              batch_fn=batch_fn, k=clients, rounds=rounds,
                              warmup=warmup, algorithm=algorithm)
        print(f"{mode:10s} mean {results[mode]['mean_s'] * 1e3:8.3f} ms/round"
              f"  p50 {results[mode]['p50_s'] * 1e3:8.3f} ms")
    speedup = results["serial"]["mean_s"] / results["vectorized"]["mean_s"]
    payload = {
        "bench": "cohort_round_latency",
        "backend": jax.default_backend(),
        "algorithm": algorithm,
        "clients_per_round": clients,
        "batches_per_client": batches_per_client,
        "batch": batch, "dim": dim, "hidden": hidden,
        "serial": results["serial"],
        "vectorized": results["vectorized"],
        "speedup": float(speedup),
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"speedup {speedup:.2f}x  ->  {out}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=3)
    ap.add_argument("--batches-per-client", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--algorithm", default="feddpc")
    ap.add_argument("--out", default=DEFAULT_OUT)
    a = ap.parse_args(argv)
    run(clients=a.clients, rounds=a.rounds, warmup=a.warmup,
        batches_per_client=a.batches_per_client, batch=a.batch,
        algorithm=a.algorithm, out=a.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
