"""Cohort round latency sweep — the perf receipt for the fused round and
its scaling levers (core/round.py, core/api.py, DESIGN.md §2):

  {serial, vectorized, sharded[, sharded2d]} x {prefetch} x {kernel}

serial        historical per-client dispatch (ExecConfig.vectorize=False)
vectorized    one fused jit program per round on a single device
sharded       client axis NamedSharding over the local devices
              (ExecConfig.shard_clients=True; force 8 host devices on CPU)
prefetch      double-buffered host ingest (ExecConfig.prefetch)
kernel        FedDPC epilogue through the batched Pallas kernel
              (FedDPCHyper.use_kernel; interpret mode on CPU)
sharded2d     --model-shards M > 1: the two-axis (clients x model) mesh —
              params/server state shard per leaf over a model axis of M
              inside each client slice (ExecConfig.shard_model); the
              receipt lands in BENCH_cohort_2axis.json

Per-mode stats include ``ingest_mean_s`` — the host time run_round spends
blocked on cohort stacking — so the prefetch win is measured directly.

  PYTHONPATH=src python -m benchmarks.bench_cohort --devices 8   # full sweep
  PYTHONPATH=src python -m benchmarks.bench_cohort --rounds 3 --devices 8

``--ingest-sweep`` runs the STAGED-INGEST receipt instead (DESIGN.md
§10): prefetch_depth in {1, 2, 4, 8} x {host-staged, device-staged},
reporting the split ingest waits (``ingest_host_mean_s`` = blocked on
staging, ``ingest_device_mean_s`` = blocked on H2D placement at
dispatch) -> BENCH_ingest.json. The headline number is
``transfer_wait_reduction_device_vs_host_d2``: how much of the depth-2
host-staged baseline's dispatch-side transfer wait device staging
removes (it moves the placement onto the staging thread, so the
consumer-side wait collapses to ~0 by construction).

``--codec-sweep`` runs the DELTA-CODEC receipt (DESIGN.md §13): the same
round under codec {identity, bf16, int8, int8+ef}, reporting the uplink
bytes the wire actually carries (``RoundRecord.comm_bytes_up``) and the
device-stage bytes of the encoded cohort payload
(``codec.EncodedCohort.nbytes`` — what ingest H2D placement moves) ->
BENCH_codec.json. The headline keys are the byte-reduction factors int8
vs identity; the acceptance bools (``int8_halves_uplink``,
``int8_shrinks_stage_bytes``) assert the §13 criteria on the receipt
itself so the bench gate holds them exactly.

``--multihost`` runs the HIERARCHICAL-AGGREGATION receipt (DESIGN.md
§15): the same sharded round flat (server folds K raw deltas) vs
hierarchical (E edge aggregators fold their cohort slices into partial
summaries; the server consumes E), including the Pallas-epilogue and
int8-dequant edge folds -> BENCH_multihost.json. Per mode it records
wall-clock AND the split uplink (``comm_bytes_edge_up`` /
``comm_bytes_server_up``) plus ``server_fanin`` — the headline is the
K -> E server fan-in reduction. With ``--processes N`` the whole bench
re-executes as an N-process jax.distributed job through
launch/distributed.spawn_local (single machine, 127.0.0.1 coordinator —
the offline-CI stand-in for a real multi-host launch); each process
stages only its local client shard and acts as one edge, and process 0
writes the receipt.

``--block-sweep`` runs the batched-epilogue BLOCK receipt instead: the
(rows, 128)-tile row-block size x cohort size K grid of
kernel.batched_epilogue (K·rows·128 f32 must stay VMEM-resident, so
the viable row block shrinks as K grows — the crossover this receipt
documents) -> BENCH_blocks.json. ``vmem_block_bytes`` per cell is the
exact-gated shape arithmetic; ``*_ms`` keys are wall-clock (interpret
mode off-TPU: a correctness/shape artifact, not kernel perf).

``--devices N`` must be handled BEFORE jax initializes (the device count
locks at first init), hence the argv scan at the top of this module
(and the ``--processes`` spawn, which must fork before this process
touches a backend).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict


def _maybe_force_devices(argv):
    n = None
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif a.startswith("--devices="):
            n = a.split("=", 1)[1]
    if n and int(n) > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={int(n)}").strip()
    return int(n) if n else None


def _maybe_spawn_processes(argv):
    """--processes N: re-exec this bench as an N-process jax.distributed
    job (launch/distributed.spawn_local — 127.0.0.1 coordinator, no
    external network). Returns the child results in the PARENT (which
    must exit without touching jax); returns None in the children
    (REPRO_DIST_PID set — they fall through, join the job in main())
    and in single-process runs. ``--devices`` means the TOTAL device
    count: it is stripped from the child argv and split evenly into
    per-process XLA host-device forces by spawn_local."""
    n = None
    for i, a in enumerate(argv):
        if a == "--processes" and i + 1 < len(argv):
            n = argv[i + 1]
        elif a.startswith("--processes="):
            n = a.split("=", 1)[1]
    n = int(n) if n else 0
    if n <= 1 or os.environ.get("REPRO_DIST_PID"):
        return None
    total = None
    child, skip = [], False
    for a in argv[1:]:
        if skip:
            total = int(a)
            skip = False
            continue
        if a == "--devices":
            skip = True
            continue
        if a.startswith("--devices="):
            total = int(a.split("=", 1)[1])
            continue
        child.append(a)
    from repro.launch.distributed import spawn_local
    return spawn_local(
        [sys.executable, os.path.abspath(argv[0]), *child], n,
        devices_per_process=max(1, (total or n) // n),
        timeout_s=1800.0)


_maybe_force_devices(sys.argv)
_spawned = _maybe_spawn_processes(sys.argv)
if _spawned is not None:
    # parent of a --processes job: the children did the work (process 0
    # wrote the receipt); surface their stdout and stop here
    for _i, (_rc, _out, _err) in enumerate(_spawned):
        sys.stdout.write(f"--- process {_i} ---\n{_out or ''}")
    sys.exit(0)

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.core.api import (AlgoConfig, ExecConfig,     # noqa: E402
                            FederatedTrainer)
from repro.core.baselines import default_hyper          # noqa: E402

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_cohort_sharded.json")
# --model-shards sweeps land in their own receipt
DEFAULT_OUT_2AXIS = os.path.join(_ROOT, "BENCH_cohort_2axis.json")
# --ingest-sweep (depth x staging) receipt
DEFAULT_OUT_INGEST = os.path.join(_ROOT, "BENCH_ingest.json")
# --async-sweep (runtime model x buffer/concurrency) receipt
DEFAULT_OUT_ASYNC = os.path.join(_ROOT, "BENCH_async.json")
# --codec-sweep (delta codec x error feedback) receipt
DEFAULT_OUT_CODEC = os.path.join(_ROOT, "BENCH_codec.json")
# --multihost (flat vs hierarchical edge aggregation) receipt
DEFAULT_OUT_MULTIHOST = os.path.join(_ROOT, "BENCH_multihost.json")
# --block-sweep (batched-epilogue row block x K) receipt
DEFAULT_OUT_BLOCKS = os.path.join(_ROOT, "BENCH_blocks.json")

# mode name -> config overrides (use_kernel routes into the feddpc hyper,
# the rest are ExecConfig fields); the sweep skips nothing silently — a
# combo that fails records its error string in the payload.
MODES = [
    ("serial", dict(vectorize=False, prefetch=False)),
    ("vectorized", dict(prefetch=False)),
    ("vectorized+prefetch", dict(prefetch=True)),
    ("vectorized+kernel", dict(prefetch=False, use_kernel=True)),
    ("vectorized+prefetch+kernel", dict(prefetch=True, use_kernel=True)),
    ("sharded", dict(shard_clients=True, prefetch=False)),
    ("sharded+prefetch", dict(shard_clients=True, prefetch=True)),
    ("sharded+kernel", dict(shard_clients=True, prefetch=False,
                            use_kernel=True)),
    ("sharded+prefetch+kernel", dict(shard_clients=True, prefetch=True,
                                     use_kernel=True)),
]


def modes_for(model_shards: int):
    """The sweep's mode list; --model-shards M > 1 appends the two-axis
    (clients x model) regimes (DESIGN.md §2) — params/server state shard
    per leaf over a model axis of M within each client slice. The kernel
    mode is included to measure the documented fall-back to the
    reference epilogue under model-sharded leaves."""
    if model_shards <= 1:
        return MODES
    two = dict(shard_clients=True, shard_model=model_shards)
    return MODES + [
        ("sharded2d", dict(two, prefetch=False)),
        ("sharded2d+prefetch", dict(two, prefetch=True)),
        ("sharded2d+prefetch+kernel", dict(two, prefetch=True,
                                           use_kernel=True)),
    ]


def build_task(num_clients: int, batches_per_client: int, batch: int,
               dim: int, hidden: int, classes: int, seed: int = 0):
    """MLP classification sized by --dim/--hidden; the sharded receipt
    uses a >=1M-param model where the round is compute-bound."""
    r = np.random.RandomState(seed)
    scale = 1.0 / np.sqrt(dim)
    params = {
        "w1": jnp.asarray(r.randn(dim, hidden) * scale, jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jnp.asarray(r.randn(hidden, classes) * scale, jnp.float32),
        "b2": jnp.zeros((classes,), jnp.float32),
    }

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, b["y"][:, None], axis=1))

    data = []
    for c in range(num_clients):
        rc = np.random.RandomState(seed + 1 + c)
        data.append([{"x": rc.randn(batch, dim).astype(np.float32),
                      "y": rc.randint(0, classes, size=batch).astype(np.int32)}
                     for _ in range(batches_per_client)])
    batch_fn = lambda c, t: data[c]
    return params, loss_fn, batch_fn


def bench(overrides: dict, *, params, loss_fn, batch_fn, k: int,
          rounds: int, warmup: int, algorithm: str) -> Dict:
    exec_kw = dict(overrides)
    hyper = default_hyper(algorithm,
                          use_kernel=exec_kw.pop("use_kernel", False))
    cfg = ExecConfig(rounds=warmup + rounds, clients_per_round=k, seed=0,
                     eval_every=10 ** 9, **exec_kw)
    algo = AlgoConfig(name=algorithm, eta_l=0.05, eta_g=0.1, hyper=hyper)
    with FederatedTrainer(loss_fn, params, k, batch_fn, cfg, None,
                          algo=algo) as tr:
        for t in range(warmup):                   # compile + cache warm
            tr.run_round(t)
        recs = [tr.run_round(t) for t in range(warmup, warmup + rounds)]
    times = np.asarray([r.seconds for r in recs])
    ingest = np.asarray([r.ingest_seconds for r in recs])
    return {"mean_s": float(times.mean()), "p50_s": float(np.median(times)),
            "p90_s": float(np.percentile(times, 90)),
            "min_s": float(times.min()),
            "ingest_mean_s": float(ingest.mean()),
            "ingest_host_mean_s": float(np.mean(
                [r.ingest_host_seconds for r in recs])),
            "ingest_device_mean_s": float(np.mean(
                [r.ingest_device_seconds for r in recs])),
            "rounds": int(rounds),
            # per-round training curve: a CURVE-class gate key
            # (bench_gate.py) — a regressing loss trajectory fails the
            # lane pointwise, not just at the final round
            "train_loss_curve": [float(r.train_loss) for r in recs]}


def run_ingest_sweep(clients: int = 16, rounds: int = 10, warmup: int = 2,
                     batches_per_client: int = 4, batch: int = None,
                     dim: int = None, hidden: int = None, classes: int = 10,
                     algorithm: str = "feddpc", out: str = None) -> Dict:
    """Staged-ingest receipt (DESIGN.md §10): prefetch_depth x staging.

    Every mode runs the same vectorized (sharded when >1 device) round;
    only the ingest pipeline changes. Host-staged modes pay the H2D
    placement on the consumer thread at dispatch (ingest_device_mean_s
    measures it); device-staged modes run it on the staging thread,
    overlapped with compute. The default batch payload is sized LARGER
    (batch 32 x dim 1024) and the model SMALLER than the compute-bound
    cohort sweep's, so the per-round transfer is actually visible on
    CPU hosts; --batch/--dim/--hidden override it."""
    batch = 32 if batch is None else batch
    dim = 1024 if dim is None else dim
    hidden = 512 if hidden is None else hidden
    out = out or DEFAULT_OUT_INGEST
    sharded = len(jax.devices()) > 1
    params, loss_fn, batch_fn = build_task(
        clients, batches_per_client, batch, dim, hidden, classes)
    n_params = sum(int(np.prod(np.shape(x))) for x in jax.tree.leaves(params))
    results = {}
    for depth in (1, 2, 4, 8):
        for staged in ("host", "device"):
            mode = f"d{depth}+{staged}"
            overrides = dict(prefetch=True, prefetch_depth=depth,
                             device_stage=(staged == "device"),
                             shard_clients=sharded)
            try:
                results[mode] = bench(
                    overrides, params=params, loss_fn=loss_fn,
                    batch_fn=batch_fn, k=clients, rounds=rounds,
                    warmup=warmup, algorithm=algorithm)
                r = results[mode]
                print(f"{mode:12s} mean {r['mean_s']*1e3:9.3f} ms"
                      f"  ingest host {r['ingest_host_mean_s']*1e3:8.3f} ms"
                      f"  device {r['ingest_device_mean_s']*1e3:8.3f} ms")
            except Exception as e:            # record, never skip silently
                results[mode] = {"error": f"{type(e).__name__}: {e}"}
                print(f"{mode:12s} FAILED: {results[mode]['error']}")

    def dev_wait(m):
        return results.get(m, {}).get("ingest_device_mean_s")

    payload = {
        "bench": "cohort_ingest_staged",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "sharded": sharded,
        "algorithm": algorithm,
        "clients_per_round": clients,
        "batches_per_client": batches_per_client,
        "batch": batch, "dim": dim, "hidden": hidden,
        "model_params": n_params,
        "modes": results,
        "note": ("ingest_host_mean_s = consumer blocked on staging "
                 "(sample+read+stack); ingest_device_mean_s = consumer "
                 "blocked on H2D placement at dispatch — ~0 when "
                 "device_stage moved it onto the staging thread"),
    }
    base, dev = dev_wait("d2+host"), dev_wait("d4+device")
    if base and dev is not None:
        # the acceptance comparison: device staging vs the depth-2
        # host-staged baseline's dispatch-side transfer wait
        payload["transfer_wait_reduction_device_vs_host_d2"] = \
            1.0 - dev / base
    d2 = dev_wait("d2+device")
    if base and d2 is not None:
        payload["transfer_wait_reduction_device_d2_vs_host_d2"] = \
            1.0 - d2 / base
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    for key in ("transfer_wait_reduction_device_vs_host_d2",
                "transfer_wait_reduction_device_d2_vs_host_d2"):
        if key in payload:
            print(f"{key}: {payload[key]:.3f}")
    print(f"-> {out}")
    return payload


def run_async_sweep(clients: int = 16, rounds: int = 10, warmup: int = 2,
                    batches_per_client: int = 4, batch: int = None,
                    dim: int = None, hidden: int = None, classes: int = 10,
                    algorithm: str = "feddpc", out: str = None) -> Dict:
    """Buffered-async receipt (DESIGN.md §11): every runtime model
    (core/runtime.runtime_matrix) under two (buffer, concurrency)
    points — the sync-shaped anchor (B=K, concurrency 1) and the
    streaming point (B=K/2, concurrency 4, where staleness appears).

    Two metric classes land in the payload: wall-clock stats (machine-
    dependent, gated loosely) and SIMULATION metrics — the virtual-time
    staleness series, wave counts, and the final train loss — which are
    deterministic functions of the seed and gate tightly. The
    deterministic-runtime anchor must report identically-zero staleness
    (the regime-matrix anchor cell, checked here as
    ``anchor_zero_staleness``)."""
    from repro.core.runtime import runtime_matrix

    batch = 8 if batch is None else batch
    dim = 256 if dim is None else dim
    hidden = 512 if hidden is None else hidden
    out = out or DEFAULT_OUT_ASYNC
    params, loss_fn, batch_fn = build_task(
        clients, batches_per_client, batch, dim, hidden, classes)
    n_params = sum(int(np.prod(np.shape(x))) for x in jax.tree.leaves(params))
    points = [("anchor", clients, 1),
              ("stream", max(1, clients // 2), 4)]
    results = {}
    for rt_name in runtime_matrix(clients):
        for label, bsize, conc in points:
            mode = f"{rt_name}+{label}"
            cfg = ExecConfig(rounds=warmup + rounds, clients_per_round=clients,
                             seed=0, eval_every=10 ** 9, async_buffer=True,
                             buffer_size=bsize, async_concurrency=conc)
            algo = AlgoConfig(name=algorithm, eta_l=0.05, eta_g=0.1)
            try:
                runtime = runtime_matrix(clients)[rt_name]   # fresh state
                with FederatedTrainer(loss_fn, params, clients, batch_fn,
                                      cfg, None, algo=algo,
                                      runtime=runtime) as tr:
                    for t in range(warmup):
                        tr.run_round(t)
                    recs = [tr.run_round(t)
                            for t in range(warmup, warmup + rounds)]
                    waves = len(tr.schedule)
                times = np.asarray([r.seconds for r in recs])
                results[mode] = {
                    "mean_s": float(times.mean()),
                    "p50_s": float(np.median(times)),
                    "min_s": float(times.min()),
                    "rounds": int(rounds),
                    "buffer_size": int(bsize),
                    "concurrency": int(conc),
                    # simulation metrics: deterministic given the seed
                    "staleness_mean": float(np.mean(
                        [r.staleness_mean for r in recs])),
                    "staleness_max": float(max(
                        r.staleness_max for r in recs)),
                    "waves_dispatched": int(waves),
                    "final_train_loss": float(recs[-1].train_loss),
                    "train_loss_curve": [float(r.train_loss)
                                         for r in recs],
                }
                r = results[mode]
                print(f"{mode:24s} mean {r['mean_s']*1e3:9.3f} ms"
                      f"  staleness {r['staleness_mean']:6.3f}"
                      f" (max {r['staleness_max']:4.1f})"
                      f"  waves {r['waves_dispatched']:4d}")
            except Exception as e:            # record, never skip silently
                results[mode] = {"error": f"{type(e).__name__}: {e}"}
                print(f"{mode:24s} FAILED: {results[mode]['error']}")
    payload = {
        "bench": "cohort_async_buffered",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "algorithm": algorithm,
        "clients_per_round": clients,
        "batches_per_client": batches_per_client,
        "batch": batch, "dim": dim, "hidden": hidden,
        "model_params": n_params,
        "staleness_alpha": 0.5,
        "modes": results,
        "note": ("staleness_*/waves_dispatched/final_train_loss are "
                 "virtual-time SIMULATION metrics — deterministic given "
                 "the seed, gated tightly by bench_gate.py; *_s keys are "
                 "wall-clock and gated loosely"),
    }
    det = results.get("deterministic+anchor", {})
    if "staleness_max" in det:
        # the regime-matrix anchor cell property, asserted on the receipt
        payload["anchor_zero_staleness"] = (det["staleness_max"] == 0.0)
    stream = results.get("heavytail+stream", {})
    if "staleness_mean" in stream:
        payload["heavytail_stream_staleness_mean"] = \
            stream["staleness_mean"]
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    for key in ("anchor_zero_staleness", "heavytail_stream_staleness_mean"):
        if key in payload:
            print(f"{key}: {payload[key]}")
    print(f"-> {out}")
    return payload


def run_codec_sweep(clients: int = 16, rounds: int = 10, warmup: int = 2,
                    batches_per_client: int = 4, batch: int = None,
                    dim: int = None, hidden: int = None, classes: int = 10,
                    algorithm: str = "feddpc", out: str = None) -> Dict:
    """Delta-codec receipt (DESIGN.md §13): the same vectorized (sharded
    when >1 device) round under each uplink codec, plus int8 with
    server-side error feedback.

    Byte accounting has two independent receipts per mode, both
    deterministic functions of the model shapes (gated exactly):

      comm_bytes_up   what the cohort's LIVE clients ship per round —
                      ``RoundRecord.comm_bytes_up``, i.e. the payload
                      arrays as actually wired (q codes + per-leaf
                      scale/zero vectors)
      stage_bytes     the encoded cohort stack's device-placement bytes
                      (``EncodedCohort.nbytes`` — what
                      ``CohortPlacer.place_encoded`` moves over the bus)

    ``final_train_loss`` is a simulation metric (deterministic given the
    seed — lossy codecs quantize deterministically); wall-clock keys are
    gated loosely like every other sweep's."""
    from repro.codec import make_codec, tree_nbytes

    batch = 8 if batch is None else batch
    dim = 512 if dim is None else dim
    hidden = 2048 if hidden is None else hidden
    out = out or DEFAULT_OUT_CODEC
    sharded = len(jax.devices()) > 1
    params, loss_fn, batch_fn = build_task(
        clients, batches_per_client, batch, dim, hidden, classes)
    n_params = sum(int(np.prod(np.shape(x))) for x in jax.tree.leaves(params))
    raw_client_bytes = tree_nbytes(params)        # f32 delta, one client
    codec_modes = [
        ("identity", dict(codec="identity")),
        ("bf16", dict(codec="bf16")),
        ("int8", dict(codec="int8")),
        ("int8+ef", dict(codec="int8", codec_ef=True)),
    ]
    results = {}
    for mode, overrides in codec_modes:
        try:
            cfg = ExecConfig(rounds=warmup + rounds, clients_per_round=clients,
                             seed=0, eval_every=10 ** 9,
                             shard_clients=sharded, **overrides)
            algo = AlgoConfig(name=algorithm, eta_l=0.05, eta_g=0.1)
            with FederatedTrainer(loss_fn, params, clients, batch_fn,
                                  cfg, None, algo=algo) as tr:
                for t in range(warmup):               # compile warm
                    tr.run_round(t)
                recs = [tr.run_round(t)
                        for t in range(warmup, warmup + rounds)]
            times = np.asarray([r.seconds for r in recs])
            # accounting receipts: per-client wire bytes and the
            # K-stack's device-stage bytes depend only on the codec and
            # the model shapes (encoded_template is shape-level)
            codec_obj = make_codec(overrides["codec"])
            stats = {
                "mean_s": float(times.mean()),
                "p50_s": float(np.median(times)),
                "min_s": float(times.min()),
                "rounds": int(rounds),
                "comm_bytes_up": int(recs[-1].comm_bytes_up),
                "client_bytes_up": int(codec_obj.client_bytes(params)),
                "stage_bytes": int(tree_nbytes(
                    codec_obj.encoded_template(params, clients))),
                "error_feedback": bool(overrides.get("codec_ef", False)),
                "final_train_loss": float(recs[-1].train_loss),
                "train_loss_curve": [float(r.train_loss) for r in recs],
            }
            results[mode] = stats
            print(f"{mode:10s} mean {stats['mean_s']*1e3:9.3f} ms"
                  f"  round bytes {stats['comm_bytes_up']:>11d}"
                  f"  stage bytes {stats['stage_bytes']:>11d}")
        except Exception as e:                # record, never skip silently
            results[mode] = {"error": f"{type(e).__name__}: {e}"}
            print(f"{mode:10s} FAILED: {results[mode]['error']}")

    def bytes_of(m, key="comm_bytes_up"):
        return results.get(m, {}).get(key)

    payload = {
        "bench": "cohort_codec",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "sharded": sharded,
        "algorithm": algorithm,
        "clients_per_round": clients,
        "batches_per_client": batches_per_client,
        "batch": batch, "dim": dim, "hidden": hidden,
        "model_params": n_params,
        "raw_client_bytes": raw_client_bytes,
        "modes": results,
        "note": ("client_bytes_up/stage_bytes are deterministic functions "
                 "of the model shapes and the codec wire format "
                 "(DESIGN.md §13) — gated exactly; identity must equal "
                 "raw_client_bytes bitwise"),
    }
    ident, i8 = bytes_of("identity"), bytes_of("int8")
    if ident and i8:
        payload["comm_bytes_reduction_int8_vs_identity"] = ident / i8
        # the §13 acceptance bool, held exactly by the bench gate
        payload["int8_halves_uplink"] = ident / i8 >= 2.0
    b16 = bytes_of("bf16")
    if ident and b16:
        payload["comm_bytes_reduction_bf16_vs_identity"] = ident / b16
    sid, si8 = bytes_of("identity", "stage_bytes"), bytes_of("int8",
                                                             "stage_bytes")
    if sid and si8:
        payload["stage_bytes_reduction_int8_vs_identity"] = sid / si8
        payload["int8_shrinks_stage_bytes"] = si8 < sid
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    for key in ("comm_bytes_reduction_int8_vs_identity",
                "comm_bytes_reduction_bf16_vs_identity",
                "stage_bytes_reduction_int8_vs_identity",
                "int8_halves_uplink", "int8_shrinks_stage_bytes"):
        if key in payload:
            print(f"{key}: {payload[key]}")
    print(f"-> {out}")
    return payload


def run_multihost(clients: int = 16, rounds: int = 10, warmup: int = 2,
                  batches_per_client: int = 4, batch: int = None,
                  dim: int = None, hidden: int = None, classes: int = 10,
                  algorithm: str = "feddpc", out: str = None) -> Dict:
    """Hierarchical edge-aggregation receipt (DESIGN.md §15): the same
    client-sharded round flat (the server's fold consumes all K client
    deltas) vs hierarchical (E edge aggregators — one per host in a
    --processes job — each fold their local cohort slice into one
    partial summary; the server consumes E), plus the hierarchical fold
    through the Pallas epilogue and the int8 dequant path.

    Wall-clock keys gate loosely as always; the comm split is exact
    shape arithmetic, gated exactly: every mode's clients ship
    ``comm_bytes_up``; flat rounds report edge_up=0 / server_up=up,
    hierarchical rounds pay ``comm_bytes_up`` on the client->edge hop
    and E raw-f32 summaries on the edge->server hop. ``server_fanin``
    (K flat, E hierarchical) is the headline reduction."""
    batch = 8 if batch is None else batch
    dim = 256 if dim is None else dim
    hidden = 512 if hidden is None else hidden
    out = out or DEFAULT_OUT_MULTIHOST
    nproc = jax.process_count()
    edges = nproc if nproc > 1 else 2
    params, loss_fn, batch_fn = build_task(
        clients, batches_per_client, batch, dim, hidden, classes)
    n_params = sum(int(np.prod(np.shape(x))) for x in jax.tree.leaves(params))
    modes = [
        ("flat", dict(shard_clients=True, prefetch=True)),
        ("hier", dict(shard_clients=True, prefetch=True, edges=edges)),
        ("hier+kernel", dict(shard_clients=True, prefetch=True,
                             edges=edges, use_kernel=True)),
        ("hier+int8", dict(shard_clients=True, prefetch=True,
                           edges=edges, codec="int8")),
    ]
    results = {}
    for mode, overrides in modes:
        try:
            exec_kw = dict(overrides)
            hyper = default_hyper(algorithm,
                                  use_kernel=exec_kw.pop("use_kernel",
                                                         False))
            cfg = ExecConfig(rounds=warmup + rounds,
                             clients_per_round=clients, seed=0,
                             eval_every=10 ** 9, **exec_kw)
            algo = AlgoConfig(name=algorithm, eta_l=0.05, eta_g=0.1,
                              hyper=hyper)
            with FederatedTrainer(loss_fn, params, clients, batch_fn,
                                  cfg, None, algo=algo) as tr:
                for t in range(warmup):               # compile warm
                    tr.run_round(t)
                recs = [tr.run_round(t)
                        for t in range(warmup, warmup + rounds)]
            times = np.asarray([r.seconds for r in recs])
            results[mode] = {
                "mean_s": float(times.mean()),
                "p50_s": float(np.median(times)),
                "min_s": float(times.min()),
                "ingest_mean_s": float(np.mean(
                    [r.ingest_seconds for r in recs])),
                "rounds": int(rounds),
                "server_fanin": int(edges if "edges" in overrides
                                    else clients),
                "comm_bytes_up": int(recs[-1].comm_bytes_up),
                "comm_bytes_edge_up": int(recs[-1].comm_bytes_edge_up),
                "comm_bytes_server_up": int(recs[-1].comm_bytes_server_up),
                "train_loss_curve": [float(r.train_loss) for r in recs],
            }
            r = results[mode]
            print(f"{mode:12s} mean {r['mean_s']*1e3:9.3f} ms"
                  f"  fan-in {r['server_fanin']:3d}"
                  f"  server uplink {r['comm_bytes_server_up']:>12d} B",
                  flush=True)
        except Exception as e:                # record, never skip silently
            results[mode] = {"error": f"{type(e).__name__}: {e}"}
            print(f"{mode:12s} FAILED: {results[mode]['error']}",
                  flush=True)
    payload = {
        "bench": "cohort_multihost_hier",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "processes": int(nproc),
        "edges": int(edges),
        "algorithm": algorithm,
        "clients_per_round": clients,
        "batches_per_client": batches_per_client,
        "batch": batch, "dim": dim, "hidden": hidden,
        "model_params": n_params,
        "modes": results,
        "note": ("comm_bytes_edge_up/server_up split the uplink across "
                 "the two hierarchy hops (exact shape arithmetic); "
                 "server_fanin is what the server's fold consumes per "
                 "round — K raw deltas flat, E partial summaries "
                 "hierarchical (DESIGN.md §15)"),
    }
    flat, hier = results.get("flat", {}), results.get("hier", {})
    if flat.get("server_fanin") and hier.get("server_fanin"):
        payload["server_fanin_flat"] = flat["server_fanin"]
        payload["server_fanin_hier"] = hier["server_fanin"]
        payload["server_fanin_reduced"] = \
            hier["server_fanin"] < flat["server_fanin"]
    if flat.get("comm_bytes_server_up") and \
            hier.get("comm_bytes_server_up"):
        payload["server_uplink_reduction_hier_vs_flat"] = \
            flat["comm_bytes_server_up"] / hier["comm_bytes_server_up"]
    if jax.process_index() == 0:
        with open(out, "w") as f:
            json.dump(payload, f, indent=1)
        for key in ("server_fanin_flat", "server_fanin_hier",
                    "server_fanin_reduced",
                    "server_uplink_reduction_hier_vs_flat"):
            if key in payload:
                print(f"{key}: {payload[key]}")
        print(f"-> {out}")
    return payload


def run_block_sweep(rounds: int = 10, warmup: int = 2, out: str = None,
                    classes: int = 10) -> Dict:
    """Batched-epilogue block receipt: kernel.batched_epilogue keeps all
    K clients' (rows, 128) tiles VMEM-resident at once, so the viable
    row block shrinks as K grows (default DEFAULT_ROWS/K, floor 8).
    This sweep times the row-block x K grid directly on one synthetic
    1M-element leaf — the crossover artifact behind that default.
    Off-TPU the kernel runs in interpret mode: the *_ms keys are then a
    correctness/shape artifact (gated loosely), while vmem_block_bytes
    is the exact VMEM footprint arithmetic."""
    import time as _time

    from repro.kernels.feddpc_project import kernel as KR

    out = out or DEFAULT_OUT_BLOCKS
    interpret = jax.default_backend() != "tpu"
    m_rows = 2048                       # 2048 x 128 f32 leaf = 1 MiB
    cells = {}
    for k in (4, 16, 64):
        r = np.random.RandomState(k)
        d3 = jnp.asarray(r.randn(k, m_rows, KR.LANE) * 1e-2, jnp.float32)
        p2 = jnp.asarray(r.randn(m_rows, KR.LANE) * 1e-2, jnp.float32)
        w2 = jnp.asarray(r.randn(m_rows, KR.LANE), jnp.float32)
        coefs = jnp.asarray(r.rand(k), jnp.float32)
        scales = jnp.asarray(1.0 + r.rand(k), jnp.float32)
        ref = None
        for rows in (8, 64, 512):
            label = f"k{k}_rows{rows}"
            try:
                def step():
                    return KR.batched_epilogue(d3, p2, w2, coefs, scales,
                                               0.1, rows=rows,
                                               interpret=interpret)
                w_out, dt = step()                       # compile + warm
                jax.block_until_ready((w_out, dt))
                if ref is None:
                    ref = (np.asarray(w_out), np.asarray(dt))
                else:                 # block size must not change math
                    np.testing.assert_allclose(np.asarray(w_out), ref[0],
                                               rtol=1e-5, atol=1e-6)
                    np.testing.assert_allclose(np.asarray(dt), ref[1],
                                               rtol=1e-5, atol=1e-6)
                times = []
                for _ in range(max(1, warmup - 1)):
                    jax.block_until_ready(step())
                for _ in range(rounds):
                    t0 = _time.perf_counter()
                    jax.block_until_ready(step())
                    times.append(_time.perf_counter() - t0)
                times = np.asarray(times)
                cells[label] = {
                    "mean_ms": float(times.mean() * 1e3),
                    "min_ms": float(times.min() * 1e3),
                    "rounds": int(rounds),
                    # K tiles of (rows, 128) f32 resident at once
                    "vmem_block_bytes": int(k * rows * KR.LANE * 4),
                    "block_consistent": True,
                }
                c = cells[label]
                print(f"{label:14s} mean {c['mean_ms']:9.3f} ms"
                      f"  VMEM block {c['vmem_block_bytes']:>9d} B")
            except Exception as e:            # record, never skip silently
                cells[label] = {"error": f"{type(e).__name__}: {e}"}
                print(f"{label:14s} FAILED: {cells[label]['error']}")
    payload = {
        "bench": "epilogue_block_sweep",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "interpret": bool(interpret),
        "lane": int(KR.LANE),
        "leaf_elems": int(m_rows * KR.LANE),
        "default_rows": int(KR.DEFAULT_ROWS),
        "modes": cells,
        "note": ("K (rows, 128) f32 tiles stay VMEM-resident per grid "
                 "step, so vmem_block_bytes = K*rows*128*4 is the "
                 "footprint the DEFAULT_ROWS/K row-block default keeps "
                 "bounded; block_consistent asserts the row block never "
                 "changes the math (allclose across the sweep)"),
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"-> {out}")
    return payload


def run(clients: int = 16, rounds: int = 10, warmup: int = 2,
        batches_per_client: int = 4, batch: int = 8, dim: int = 512,
        hidden: int = 2048, classes: int = 10, algorithm: str = "feddpc",
        model_shards: int = 1, out: str = None) -> Dict:
    out = out or (DEFAULT_OUT_2AXIS if model_shards > 1 else DEFAULT_OUT)
    params, loss_fn, batch_fn = build_task(
        clients, batches_per_client, batch, dim, hidden, classes)
    n_params = sum(int(np.prod(np.shape(x))) for x in jax.tree.leaves(params))
    results = {}
    for mode, overrides in modes_for(model_shards):
        try:
            results[mode] = bench(
                overrides, params=params, loss_fn=loss_fn, batch_fn=batch_fn,
                k=clients, rounds=rounds, warmup=warmup, algorithm=algorithm)
            print(f"{mode:28s} mean {results[mode]['mean_s']*1e3:9.3f} ms"
                  f"  ingest {results[mode]['ingest_mean_s']*1e3:8.3f} ms")
        except Exception as e:                    # record, never skip silently
            results[mode] = {"error": f"{type(e).__name__}: {e}"}
            print(f"{mode:28s} FAILED: {results[mode]['error']}")

    def mean(m):
        return results.get(m, {}).get("mean_s")

    def ing(m):
        return results.get(m, {}).get("ingest_mean_s")

    payload = {
        "bench": ("cohort_round_2axis" if model_shards > 1
                  else "cohort_round_sharded"),
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "model_shards": model_shards,
        "algorithm": algorithm,
        "clients_per_round": clients,
        "batches_per_client": batches_per_client,
        "batch": batch, "dim": dim, "hidden": hidden,
        "model_params": n_params,
        "kernel_note": ("use_kernel modes run the Pallas epilogue in "
                        "INTERPRET mode on CPU — a correctness artifact, "
                        "not a perf number; the fused-pass win is a TPU "
                        "property (see kernels/feddpc_project/kernel.py)"),
        "modes": results,
    }
    if mean("serial") and mean("vectorized"):
        payload["speedup_vectorized_vs_serial"] = \
            mean("serial") / mean("vectorized")
    if mean("vectorized") and mean("sharded"):
        payload["speedup_sharded_vs_vectorized"] = \
            mean("vectorized") / mean("sharded")
    if ing("vectorized") and ing("vectorized+prefetch") is not None:
        payload["ingest_reduction_prefetch"] = \
            1.0 - ing("vectorized+prefetch") / ing("vectorized")
    if mean("vectorized") and mean("sharded2d"):
        payload["speedup_sharded2d_vs_vectorized"] = \
            mean("vectorized") / mean("sharded2d")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    for key in ("speedup_vectorized_vs_serial", "speedup_sharded_vs_vectorized",
                "speedup_sharded2d_vs_vectorized",
                "ingest_reduction_prefetch"):
        if key in payload:
            print(f"{key}: {payload[key]:.3f}")
    print(f"-> {out}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--batches-per-client", type=int, default=4)
    # batch/dim/hidden default per sweep (cohort: 8/512/2048 compute-
    # bound; --ingest-sweep: 32/1024/512 transfer-visible) — None here
    # means "that sweep's default"
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--hidden", type=int, default=None)
    ap.add_argument("--algorithm", default="feddpc")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host devices (must be set before jax "
                         "initializes; handled at module import)")
    ap.add_argument("--model-shards", type=int, default=1,
                    help=">1 appends the two-axis (clients x model) "
                         "sweep; receipts default to "
                         "BENCH_cohort_2axis.json")
    ap.add_argument("--ingest-sweep", action="store_true",
                    help="run the staged-ingest receipt instead: "
                         "prefetch_depth {1,2,4,8} x {host,device} "
                         "staging -> BENCH_ingest.json (DESIGN.md §10)")
    ap.add_argument("--async-sweep", action="store_true",
                    help="run the buffered-async receipt instead: every "
                         "runtime model x {anchor, streaming} points -> "
                         "BENCH_async.json (DESIGN.md §11)")
    ap.add_argument("--codec-sweep", action="store_true",
                    help="run the delta-codec receipt instead: codec "
                         "{identity, bf16, int8, int8+ef} uplink/stage "
                         "byte accounting -> BENCH_codec.json "
                         "(DESIGN.md §13)")
    ap.add_argument("--multihost", action="store_true",
                    help="run the hierarchical edge-aggregation receipt "
                         "instead: flat vs E-edge two-level fold with "
                         "the split uplink accounting -> "
                         "BENCH_multihost.json (DESIGN.md §15)")
    ap.add_argument("--processes", type=int, default=None,
                    help="re-exec as an N-process jax.distributed job "
                         "via launch/distributed.spawn_local (handled "
                         "at module import, like --devices, which then "
                         "means the TOTAL device count split across "
                         "processes); process 0 writes the receipt")
    ap.add_argument("--block-sweep", action="store_true",
                    help="run the batched-epilogue block receipt "
                         "instead: row-block x K grid with exact VMEM "
                         "footprint arithmetic -> BENCH_blocks.json")
    ap.add_argument("--out", default=None,
                    help="defaults to BENCH_cohort_sharded.json, "
                         "BENCH_cohort_2axis.json with --model-shards, "
                         "BENCH_ingest.json with --ingest-sweep, "
                         "BENCH_async.json with --async-sweep, or "
                         "BENCH_codec.json with --codec-sweep")
    a = ap.parse_args(argv)
    # multi-process children (spawned at module import by --processes):
    # join the jax.distributed job before the first device query; a
    # no-op in single-process runs
    from repro.launch.distributed import maybe_initialize
    maybe_initialize()
    if a.block_sweep:
        run_block_sweep(rounds=a.rounds, warmup=a.warmup, out=a.out)
    elif a.multihost:
        run_multihost(clients=a.clients, rounds=a.rounds,
                      warmup=a.warmup,
                      batches_per_client=a.batches_per_client,
                      batch=a.batch, dim=a.dim, hidden=a.hidden,
                      algorithm=a.algorithm, out=a.out)
    elif a.codec_sweep:
        run_codec_sweep(clients=a.clients, rounds=a.rounds,
                        warmup=a.warmup,
                        batches_per_client=a.batches_per_client,
                        batch=a.batch, dim=a.dim, hidden=a.hidden,
                        algorithm=a.algorithm, out=a.out)
    elif a.async_sweep:
        run_async_sweep(clients=a.clients, rounds=a.rounds,
                        warmup=a.warmup,
                        batches_per_client=a.batches_per_client,
                        batch=a.batch, dim=a.dim, hidden=a.hidden,
                        algorithm=a.algorithm, out=a.out)
    elif a.ingest_sweep:
        run_ingest_sweep(clients=a.clients, rounds=a.rounds,
                         warmup=a.warmup,
                         batches_per_client=a.batches_per_client,
                         batch=a.batch, dim=a.dim, hidden=a.hidden,
                         algorithm=a.algorithm, out=a.out)
    else:
        run(clients=a.clients, rounds=a.rounds, warmup=a.warmup,
            batches_per_client=a.batches_per_client,
            batch=8 if a.batch is None else a.batch,
            dim=512 if a.dim is None else a.dim,
            hidden=2048 if a.hidden is None else a.hidden,
            algorithm=a.algorithm,
            model_shards=a.model_shards, out=a.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
