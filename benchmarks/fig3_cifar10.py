"""Paper Figure 3: CIFAR-10-like federated image classification with
LeNet5, FedDPC vs {FedProx, FedExP, FedGA, FedCM, FedVARP, FedAvg},
Dirichlet alpha in {0.2, 0.6}, partial participation.

Validated claim: FedDPC (red in the paper) reduces training loss and
increases test accuracy faster across rounds than every baseline.
"""
from __future__ import annotations

from benchmarks.common import (PAPER_CIFAR10, QUICK_CIFAR10, ascii_curves,
                               run_sweep, save_results)

ALGOS = ("fedavg", "fedprox", "fedexp", "fedga", "fedcm", "fedvarp", "feddpc")


def run(quick: bool = True, seed: int = 0):
    spec = QUICK_CIFAR10 if quick else PAPER_CIFAR10
    print(f"== Fig 3 (CIFAR10-like, LeNet5) — {spec.rounds} rounds, "
          f"{spec.num_clients} clients ==")
    res = run_sweep(spec, ALGOS, alphas=(0.2, 0.6), seed=seed)
    path = save_results("fig3_cifar10", res)
    print(ascii_curves(res, "loss"))

    # headline check: FedDPC best-acc >= every baseline's (paper Table 2 row)
    verdict = {}
    for alpha in (0.2, 0.6):
        dpc = res["algorithms"][f"feddpc@a{alpha}"]["best_acc"]
        others = {a: res["algorithms"][f"{a}@a{alpha}"]["best_acc"]
                  for a in ALGOS if a != "feddpc"}
        verdict[alpha] = {"feddpc": dpc, "best_baseline": max(others.values()),
                          "wins": dpc >= max(others.values())}
        print(f"alpha={alpha}: feddpc={dpc:.4f} vs best baseline "
              f"{max(others, key=others.get)}={max(others.values()):.4f} "
              f"-> {'WIN' if verdict[alpha]['wins'] else 'LOSS'}")
    res["verdict"] = verdict
    save_results("fig3_cifar10", res)
    return res


if __name__ == "__main__":
    import sys
    run(quick="--paper" not in sys.argv)
