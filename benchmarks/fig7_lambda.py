"""Paper Figure 7: sensitivity of FedDPC to the adaptive-scaling
hyper-parameter lambda, CIFAR10-like, alpha=0.2.

Validated claims: good accuracy for 0.1 < lambda <= 2; very poor for
negative lambda.
"""
from __future__ import annotations

from benchmarks.common import QUICK_CIFAR10, run_sweep, save_results

LAMBDAS = (3.0, 2.0, 1.0, 0.1, 0.0, -0.1, -0.5)


def run(quick: bool = True, seed: int = 0):
    spec = QUICK_CIFAR10
    print(f"== Fig 7 (lambda sensitivity) — {spec.rounds} rounds ==")
    out = {"lambdas": {}}
    for lam in LAMBDAS:
        res = run_sweep(spec, ("feddpc",), alphas=(0.2,), seed=seed,
                        lam=lam, verbose=False)
        r = res["algorithms"]["feddpc@a0.2"]
        out["lambdas"][str(lam)] = {"best_acc": r["best_acc"],
                                    "final_loss": r["loss"][-1]}
        print(f"  lambda={lam:5.1f}: best_acc={r['best_acc']:.4f} "
              f"final_loss={r['loss'][-1]:.4f}")
    good = [out["lambdas"][str(l)]["best_acc"] for l in (2.0, 1.0)]
    bad = [out["lambdas"][str(l)]["best_acc"] for l in (-0.1, -0.5)]
    out["claim_good_gt_bad"] = min(good) > max(bad)
    print(f"claim (0.1<lam<=2 beats negative lam): {out['claim_good_gt_bad']}")
    save_results("fig7_lambda", out)
    return out


if __name__ == "__main__":
    run()
