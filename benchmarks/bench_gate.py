"""Bench regression gate: diff a freshly generated BENCH_*.json against
its committed baseline (benchmarks/baselines/) with per-key tolerance
classes, so CI catches structural and simulation regressions without
flaking on shared-runner wall-clock noise.

Four key classes, decided by key NAME (the receipts already separate
them by naming convention):

  perf      wall-clock stats (``*_s`` suffixes) and derived ratios
            (``speedup_*``, ``*_reduction_*``): machine-dependent, gated
            by a multiplicative band — fresh must lie within
            [baseline / factor, baseline * factor] (``--perf-factor``,
            default 10; ratios can legitimately sit at 0.0/1.0 by
            construction, so an absolute slack of 1.0 is added for them)
  sim       simulation metrics (``staleness_*``, ``*train_loss``,
            ``waves_dispatched``, ``anchor_zero_staleness``):
            deterministic functions of the seed and the virtual-time
            engine — gated tightly (``--sim-rtol``, default 1e-3, which
            absorbs BLAS-order float differences across hosts)
  curve     per-round training curves (``*_curve`` suffix, lists of
            floats): length must match exactly, any NaN fails, and every
            round's loss must lie within the pointwise band
            |fresh - base| <= curve_rtol * |base| + curve_atol
            (``--curve-rtol``, default 5e-2) — a loss trajectory that
            regresses mid-run fails the lane even when the final loss
            happens to land close

  exact     everything else (config echoes, shapes, mode sets, flags):
            must match exactly — a missing mode or an ``error`` entry in
            any mode fails the gate outright

Key coverage is gated in BOTH directions: baseline keys missing from
the fresh payload fail (coverage regression), and fresh keys missing
from the baseline fail too, naming the key — a receipt that silently
grows fields is a receipt whose new fields are silently ungated, so
adding a field means regenerating its committed baseline in the same
change.

  PYTHONPATH=src python -m benchmarks.bench_gate \
      --fresh /tmp/bench_smoke.json \
      --baseline benchmarks/baselines/bench_smoke.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys

PERF_SUFFIXES = ("_s", "_ms")
PERF_PREFIXES = ("speedup_",)
PERF_SUBSTR = ("_reduction_",)
SIM_KEYS = ("staleness_mean", "staleness_max", "final_train_loss",
            "train_loss", "waves_dispatched", "anchor_zero_staleness",
            "heavytail_stream_staleness_mean")
# chaos-hardening health counters (DESIGN.md §12): integer tallies of a
# seed-deterministic fault plan — gated EXACTLY, never banded; a drift
# of even one quarantine/drop/restart means the guard or the plan
# derivation changed behavior
HEALTH_KEYS = ("quarantined", "clipped", "deadline_fired",
               "deadline_dropped", "ingest_restarts",
               "expected_quarantined", "quarantine_matches_plan",
               "params_finite", "all_injectors_fired",
               "unguarded_control_nonfinite")
# host-dependent context fields: echoed for humans, never gated (the
# committed receipts come from dev machines, CI runs elsewhere)
CONTEXT_KEYS = ("backend", "note", "kernel_note")


def classify(key: str) -> str:
    if key in CONTEXT_KEYS:
        return "context"
    if key in HEALTH_KEYS:
        return "exact"
    if key in SIM_KEYS:
        return "sim"
    if key.endswith("_curve"):
        return "curve"
    if (key.endswith(PERF_SUFFIXES) or key.startswith(PERF_PREFIXES)
            or any(s in key for s in PERF_SUBSTR)):
        return "perf"
    return "exact"


def check_curve(base, fresh, path, problems, *, curve_rtol,
                curve_atol=1e-6):
    if not (isinstance(base, list) and isinstance(fresh, list)):
        problems.append(f"{path}: curve must be a list (baseline "
                        f"{type(base).__name__}, fresh "
                        f"{type(fresh).__name__})")
        return
    if len(base) != len(fresh):
        problems.append(f"{path}: curve length {len(fresh)} != baseline "
                        f"{len(base)} (the run ended early or late)")
        return
    for i, (b, f) in enumerate(zip(base, fresh)):
        b, f = float(b), float(f)
        if not math.isfinite(f):
            problems.append(f"{path}[{i}]: non-finite loss {f!r}")
        elif not math.isfinite(b):
            problems.append(f"{path}[{i}]: non-finite BASELINE {b!r} "
                            "(regenerate from a healthy run)")
        elif abs(f - b) > curve_rtol * abs(b) + curve_atol:
            problems.append(
                f"{path}[{i}]: {f:.6g} outside curve band of baseline "
                f"{b:.6g} (rtol {curve_rtol})")


def check(base, fresh, path, problems, *, perf_factor, sim_rtol,
          curve_rtol=5e-2):
    key = path.rsplit(".", 1)[-1]
    cls = classify(key)
    if cls == "context":
        return
    if cls == "curve":
        check_curve(base, fresh, path, problems, curve_rtol=curve_rtol)
        return
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            problems.append(f"{path}: baseline is a dict, fresh is "
                            f"{type(fresh).__name__}")
            return
        for k in base:
            if k not in fresh:
                problems.append(f"{path}.{k}: missing from fresh payload "
                                "(coverage regression)")
                continue
            check(base[k], fresh[k], f"{path}.{k}", problems,
                  perf_factor=perf_factor, sim_rtol=sim_rtol,
                  curve_rtol=curve_rtol)
        for k in fresh:
            if k not in base:
                # loud by design: a fresh-only key is UNGATED — fail and
                # name it so the baseline gets regenerated alongside the
                # receipt change instead of drifting silently
                problems.append(f"{path}.{k}: fresh key missing from "
                                "baseline (regenerate the committed "
                                "baseline to gate it)")
        return
    if isinstance(base, bool) or isinstance(fresh, bool):
        # bools before numbers: isinstance(True, int) holds
        if bool(base) != bool(fresh):
            problems.append(f"{path}: {base!r} != {fresh!r} [{cls}]")
        return
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        b, f = float(base), float(fresh)
        if math.isnan(b) or math.isnan(f):
            problems.append(f"{path}: NaN (baseline {base}, fresh {fresh})")
        elif cls == "perf":
            lo, hi = b / perf_factor, b * perf_factor
            slack = 1.0 if not key.endswith(PERF_SUFFIXES) else 0.0
            if not (lo - slack <= f <= hi + slack):
                problems.append(
                    f"{path}: {f:.6g} outside perf band "
                    f"[{lo:.6g}, {hi:.6g}] (baseline {b:.6g}, "
                    f"factor {perf_factor})")
        elif cls == "sim":
            if not math.isclose(f, b, rel_tol=sim_rtol, abs_tol=sim_rtol):
                problems.append(
                    f"{path}: sim metric {f!r} != baseline {b!r} "
                    f"(rtol {sim_rtol})")
        else:
            if f != b:
                problems.append(f"{path}: {fresh!r} != {base!r} [exact]")
        return
    if base != fresh:
        problems.append(f"{path}: {fresh!r} != {base!r} [{cls}]")


def gate(baseline_path: str, fresh_path: str, *, perf_factor: float = 10.0,
         sim_rtol: float = 1e-3, curve_rtol: float = 5e-2) -> int:
    with open(baseline_path) as fh:
        base = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    problems = []
    # an error recorded in ANY fresh mode fails, even if the baseline
    # (wrongly) carries one too
    for mode, stats in fresh.get("modes", {}).items():
        if isinstance(stats, dict) and "error" in stats:
            problems.append(f"modes.{mode}: {stats['error']}")
    check(base, fresh, "$", problems,
          perf_factor=perf_factor, sim_rtol=sim_rtol,
          curve_rtol=curve_rtol)
    if problems:
        print(f"BENCH GATE FAILED ({len(problems)} problem(s)) "
              f"[{fresh_path} vs {baseline_path}]:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"bench gate OK: {fresh_path} within tolerance of "
          f"{baseline_path}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed reference receipt "
                         "(benchmarks/baselines/*.json)")
    ap.add_argument("--fresh", required=True,
                    help="receipt generated by this run")
    ap.add_argument("--perf-factor", type=float, default=10.0,
                    help="multiplicative band for wall-clock keys "
                         "(default 10: catches order-of-magnitude "
                         "regressions without flaking on runner noise)")
    ap.add_argument("--sim-rtol", type=float, default=1e-3,
                    help="relative tolerance for deterministic "
                         "simulation metrics (default 1e-3)")
    ap.add_argument("--curve-rtol", type=float, default=5e-2,
                    help="pointwise relative band for per-round "
                         "training curves (default 5e-2: loose enough "
                         "for cross-host BLAS drift, tight enough that "
                         "a spiked or diverging trajectory fails)")
    a = ap.parse_args(argv)
    return gate(a.baseline, a.fresh, perf_factor=a.perf_factor,
                sim_rtol=a.sim_rtol, curve_rtol=a.curve_rtol)


if __name__ == "__main__":
    raise SystemExit(main())
