"""Paper Figure 6 (ablation): FedDPC vs FedDPC-without-adaptive-scaling
(projection only) vs FedAvg-with-two-sided-LRs, CIFAR10-like, alpha=0.2.

Validated claim ordering: feddpc >= projection-only >= two-sided fedavg
on loss-reduction speed / best accuracy.
"""
from __future__ import annotations

from benchmarks.common import QUICK_CIFAR10, ascii_curves, run_sweep, \
    save_results

# feddpc_noscale == projection only; fedavg == two-sided-LR FedAvg
ALGOS = ("fedavg", "feddpc_noscale", "feddpc")


def run(quick: bool = True, seed: int = 0):
    spec = QUICK_CIFAR10
    print(f"== Fig 6 (ablation) — {spec.rounds} rounds ==")
    res = run_sweep(spec, ALGOS, alphas=(0.2,), seed=seed)
    accs = {a: res["algorithms"][f"{a}@a0.2"]["best_acc"] for a in ALGOS}
    res["ordering"] = accs
    ok = accs["feddpc"] >= accs["feddpc_noscale"] - 0.02
    print(f"ordering: {accs}  feddpc >= projection-only: {ok}")
    save_results("fig6_ablation", res)
    print(ascii_curves(res, "loss"))
    return res


if __name__ == "__main__":
    run()
