"""Deliverable (g): render the dry-run roofline JSON into the
EXPERIMENTS.md table (one row per arch x shape x mesh)."""
from __future__ import annotations

import json
import os
import sys


def _fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def render(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["| arch | shape | mesh | compute | memory | collective | "
           "dominant | useful frac | mem/dev GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} "
            f"| {_fmt_s(r['t_collective_s'])} | {r['dominant']} "
            f"| {r['useful_fraction']:.3f} "
            f"| {r['memory_per_device_bytes'] / 1e9:.1f} |")
    # summary stats
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    out.append("")
    out.append(f"Dominant-term census: {doms} over {len(rows)} combos.")
    return "\n".join(out)


def run(path: str = "results_roofline_single.json"):
    if not os.path.exists(path):
        print(f"(roofline JSON {path} not found — run "
              f"`python -m repro.launch.dryrun --all --mesh single --table "
              f"--out {path}` first)")
        return None
    table = render(path)
    print(table)
    return table


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "results_roofline_single.json")
