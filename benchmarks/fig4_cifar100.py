"""Paper Figure 4: CIFAR-100-like classification with ResNet18+GroupNorm.
Quick mode uses a width-16 ResNet18 and 20 classes to fit the CPU budget;
the optimization landscape (deep resnet + groupnorm + heterogeneous
clients) matches the paper's setting."""
from __future__ import annotations

from benchmarks.common import QUICK_CIFAR100, ascii_curves, run_sweep, \
    save_results

ALGOS = ("fedavg", "fedexp", "fedcm", "feddpc")      # quick subset


def run(quick: bool = True, seed: int = 0):
    spec = QUICK_CIFAR100
    if not quick:
        spec = spec.__class__(**{**spec.__dict__, "rounds": 800,
                                 "num_clients": 100, "width": 64,
                                 "num_classes": 100,
                                 "samples_per_class": 500})
    print(f"== Fig 4 (CIFAR100-like, ResNet18+GN) — {spec.rounds} rounds ==")
    res = run_sweep(spec, ALGOS, alphas=(0.2,), seed=seed)
    save_results("fig4_cifar100", res)
    print(ascii_curves(res, "loss"))
    return res


if __name__ == "__main__":
    import sys
    run(quick="--paper" not in sys.argv)
