"""Shared benchmark harness: builds the paper's federated tasks on
synthetic heterogeneous data and runs algorithm sweeps.

Quick mode (default) shrinks clients/rounds/dataset so the whole paper
reproduction runs on CPU in minutes; --paper uses the paper's exact
settings (k=100, 10% participation, batch 256, T=400/800) — sized for a
real cluster, not this container.
"""
from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.baselines import default_hyper
from repro.ingest import StreamingImageSource, build_federated_image_data
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


@dataclass
class TaskSpec:
    name: str
    family: str                  # lenet5 | resnet18
    num_classes: int
    image_size: int = 32
    width: int = 16              # resnet width (paper: 64; quick: 16)
    samples_per_class: int = 100
    test_per_class: int = 20
    num_clients: int = 30
    rounds: int = 25
    clients_per_round: int = 3
    batch_size: int = 64
    eta_l: float = 0.02
    eta_g: float = 0.02
    eval_every: int = 2
    noise: float = 1.2               # synthetic-image noise (higher = harder)
    # per-algorithm lr grid (paper §5.2.4 tunes eta per method; FedDPC's
    # adaptive scale >= lam+1 doubles its effective server step, so a
    # shared eta sits at a different point on each method's stability curve)
    eta_grid: tuple = (0.04, 0.02, 0.01, 0.005)


QUICK_CIFAR10 = TaskSpec("cifar10-like", "lenet5", 10, rounds=40)
QUICK_CIFAR100 = TaskSpec("cifar100-like", "resnet18", 12, width=8,
                          samples_per_class=30, rounds=8, eval_every=2,
                          eta_l=0.01, eta_g=0.01, num_clients=16,
                          eta_grid=(0.02, 0.01))
QUICK_TINYIMAGENET = TaskSpec("tinyimagenet-like", "resnet18", 10, width=8,
                              image_size=64, samples_per_class=16, rounds=6,
                              eval_every=2, eta_l=0.01, eta_g=0.01,
                              num_clients=12, eta_grid=(0.01,))

PAPER_CIFAR10 = TaskSpec("cifar10", "lenet5", 10, width=64,
                         samples_per_class=5000, test_per_class=1000,
                         num_clients=100, rounds=400, clients_per_round=10,
                         batch_size=256, eta_l=0.1, eta_g=0.1, eval_every=10)


def build_task(spec: TaskSpec, alpha: float, seed: int = 0):
    vc = VisionConfig(name=spec.name, family=spec.family,
                      num_classes=spec.num_classes,
                      image_size=spec.image_size, width=spec.width)
    data = build_federated_image_data(
        num_classes=spec.num_classes, num_clients=spec.num_clients,
        alpha=alpha, samples_per_class=spec.samples_per_class,
        test_per_class=spec.test_per_class, image_size=spec.image_size,
        seed=seed, noise=spec.noise)
    params = init_vision(vc, jax.random.PRNGKey(seed))
    loss_fn = functools.partial(vision_loss_fn, vc)
    source = StreamingImageSource(data, spec.batch_size)
    te_x = jnp.asarray(data.test_images)
    te_y = jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    return params, loss_fn, source, eval_fn, data


def run_sweep(spec: TaskSpec, algorithms: Sequence[str],
              alphas: Sequence[float], seed: int = 0,
              lam: float = 1.0, overrides: Optional[dict] = None,
              verbose: bool = True, vectorize: bool = True) -> Dict:
    """Same initial model + same client sampling across algorithms
    (paper §5.2.4 fairness protocol). Returns nested results dict.
    vectorize=False forces the serial per-client reference path (the
    cohort-fused round is the default; see benchmarks/bench_cohort.py for
    the latency comparison). ``overrides`` are extra ExecConfig kwargs."""
    exec_kw = {"vectorize": vectorize, **(overrides or {})}
    out = {"spec": {k: v for k, v in spec.__dict__.items()}, "algorithms": {},
           "lam": lam, "vectorize": exec_kw["vectorize"]}

    def hyper_for(algo):
        return default_hyper(algo, lam=lam)

    for alpha in alphas:
        params, loss_fn, source, eval_fn, _ = build_task(spec, alpha, seed)
        for algo in algorithms:
            # per-algorithm lr grid (best train loss + best acc, paper
            # protocol); short probe runs pick eta, then the full run
            best_eta, best_score = spec.eta_grid[0], -1e18
            if len(spec.eta_grid) > 1:
                probe_rounds = max(4, spec.rounds // 4)
                for eta in spec.eta_grid:
                    pcfg = ExecConfig(
                        rounds=probe_rounds,
                        clients_per_round=spec.clients_per_round,
                        batch_size=spec.batch_size, seed=seed,
                        eval_every=max(1, probe_rounds // 2), **exec_kw)
                    with FederatedTrainer(
                            loss_fn, params, spec.num_clients, source, pcfg,
                            eval_fn, algo=AlgoConfig(
                                name=algo, eta_l=eta, eta_g=eta,
                                hyper=hyper_for(algo))) as ptr:
                        phist = ptr.run()
                        pacc, _ = ptr.best_accuracy
                    score = (pacc or 0.0) - 0.05 * phist[-1].train_loss
                    if np.isfinite(phist[-1].train_loss) and score > best_score:
                        best_score, best_eta = score, eta
            cfg = ExecConfig(
                rounds=spec.rounds,
                clients_per_round=spec.clients_per_round,
                batch_size=spec.batch_size, seed=seed,
                eval_every=spec.eval_every, **exec_kw)
            t0 = time.perf_counter()
            with FederatedTrainer(
                    loss_fn, params, spec.num_clients, source, cfg, eval_fn,
                    algo=AlgoConfig(name=algo, eta_l=best_eta,
                                    eta_g=best_eta,
                                    hyper=hyper_for(algo))) as tr:
                hist = tr.run()
                dt = time.perf_counter() - t0
                best, at = tr.best_accuracy
            accs = [(r.round, r.test_accuracy) for r in hist
                    if r.test_accuracy is not None]
            thresh = 0.9 * max(a for _, a in accs) if accs else 0.0
            rounds_to = next((r for r, a in accs if a >= thresh), None)
            key = f"{algo}@a{alpha}"
            out["algorithms"][key] = {
                "algorithm": algo, "alpha": alpha,
                "loss": [r.train_loss for r in hist],
                "acc": accs,
                "best_acc": best, "best_round": at,
                "rounds_to_90pct_of_best": rounds_to,
                "sec_per_round": dt / spec.rounds,
                "eta": best_eta,
            }
            if verbose:
                print(f"  [{spec.name} a={alpha}] {algo:16s} "
                      f"best_acc={best:.4f}@{at}  "
                      f"final_loss={hist[-1].train_loss:.4f}  "
                      f"eta={best_eta}  ({dt / spec.rounds:.2f}s/round)")
    return out


def save_results(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def ascii_curves(results: dict, metric: str = "loss", width: int = 60):
    """Terminal sparkline-ish rendering of per-round curves."""
    lines = []
    for key, r in results["algorithms"].items():
        ys = r[metric] if metric == "loss" else [a for _, a in r["acc"]]
        if not ys:
            continue
        lo, hi = min(ys), max(ys)
        span = (hi - lo) or 1.0
        chars = "▁▂▃▄▅▆▇█"
        step = max(1, len(ys) // width)
        s = "".join(chars[int((y - lo) / span * 7)] for y in ys[::step])
        lines.append(f"{key:24s} {s}  [{lo:.3f},{hi:.3f}]")
    return "\n".join(lines)
