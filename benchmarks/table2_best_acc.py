"""Paper Table 2: best accuracy within the round budget per method and
heterogeneity level — aggregated from the fig3/4/5 sweeps."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, save_results


def run():
    rows = []
    for name in ("fig3_cifar10", "fig4_cifar100", "fig5_tinyimagenet"):
        path = os.path.join(RESULTS_DIR, f"{name}.json")
        if not os.path.exists(path):
            print(f"  (skipping {name}: run its benchmark first)")
            continue
        with open(path) as f:
            res = json.load(f)
        for key, r in res["algorithms"].items():
            rows.append({"task": name, "algorithm": r["algorithm"],
                         "alpha": r["alpha"], "best_acc": r["best_acc"],
                         "best_round": r["best_round"],
                         "sec_per_round": r["sec_per_round"]})
    if rows:
        print(f"{'task':22s} {'algo':16s} {'alpha':>5s} {'acc':>8s} "
              f"{'T':>5s} {'s/round':>8s}")
        for r in sorted(rows, key=lambda x: (x['task'], x['alpha'],
                                             -(x['best_acc'] or 0))):
            print(f"{r['task']:22s} {r['algorithm']:16s} {r['alpha']:5.1f} "
                  f"{r['best_acc'] or 0:8.4f} {r['best_round'] or 0:5d} "
                  f"{r['sec_per_round']:8.2f}")
    save_results("table2_best_acc", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
