"""Chaos-smoke receipt — the fault-injection harness driven end to end
(DESIGN.md §12): one seeded FaultPlan exercising EVERY injector kind
against the guarded trainer, per algorithm, plus the self-healing
checkpoint fallback and the guard-off control.

Per algorithm mode the receipt records the RoundRecord health counters
(quarantined / clipped / deadline_fired / deadline_dropped /
ingest_restarts) and the exactness signal ``quarantine_matches_plan``:
the guarded run must quarantine EXACTLY the plan's delta-fault target
set (``FaultPlan.delta_targets`` over the realized schedule) — no
misses, no false positives — and still finish with finite parameters.

Top-level checks:

  injectors_fired               every injector kind fired >= once
  unguarded_control_nonfinite   the same NaN plan with guard=False
                                poisons the params (the guard is
                                load-bearing, not decorative)
  ckpt_fallback                 truncate / bitflip / drop_digest each
                                corrupt the newest step; resume falls
                                back to the last intact one

All of it is virtual-time / seed-deterministic, so the bench gate holds
the counters EXACTLY (benchmarks/bench_gate.py HEALTH_KEYS); only the
wall-clock keys get the perf band.

  PYTHONPATH=src python -m benchmarks.bench_chaos --out /tmp/chaos.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
import warnings
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.baselines import default_hyper
from repro.core.faults import FaultPlan, corrupt_checkpoint
from repro.core.runtime import make_runtime
from repro.checkpoint import checkpoint as ckpt

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_chaos.json")

NUM_CLIENTS = 10
K = 4
ROUNDS = 6
ALGOS = ("feddpc", "fedavg", "fedvarp")

# one plan, every injector: delta faults on warm-threshold rounds (the
# guard's norm threshold needs one round of accepted history), hangs on
# their own round so the quarantine oracle stays clean of deadline drops
PLAN_KW = dict(nan_rate=0.5, nan_rounds=(2,),
               explode_rate=0.5, explode_rounds=(3,),
               hang_rate=0.5, hang_rounds=(4,),
               ingest_crash_rounds=(1,))


def build_task(seed: int = 0):
    r = np.random.RandomState(seed)
    params = {"w1": jnp.asarray(r.randn(8, 16) * 0.3, jnp.float32),
              "b1": jnp.zeros((16,), jnp.float32),
              "w2": jnp.asarray(r.randn(16, 4) * 0.3, jnp.float32),
              "b2": jnp.zeros((4,), jnp.float32)}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["w1"] + p["b1"])
        pred = h @ p["w2"] + p["b2"]
        return jnp.mean((pred - b["y"]) ** 2)

    def batch_fn(c, t):
        rc = np.random.RandomState(1000 * c + t)
        return [{"x": rc.randn(8, 8).astype(np.float32),
                 "y": rc.randn(8, 4).astype(np.float32)}
                for _ in range((c % 2) + 1)]

    return params, loss_fn, batch_fn


def _trainer(algorithm: str, plan, *, guard: bool):
    params, loss_fn, batch_fn = build_task()
    cfg = ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=5,
                     eval_every=10 ** 9, guard=guard, guard_min_history=1,
                     round_deadline=10.0, ingest_max_restarts=2)
    algo = AlgoConfig(name=algorithm, eta_l=0.05, eta_g=0.1,
                      hyper=default_hyper(algorithm, lam=1.0))
    return FederatedTrainer(loss_fn, params, NUM_CLIENTS, batch_fn, cfg,
                            algo=algo, fault_plan=plan,
                            runtime=make_runtime("deterministic",
                                                 NUM_CLIENTS))


def run_mode(algorithm: str, plan) -> Dict:
    tic = time.perf_counter()
    with _trainer(algorithm, plan, guard=True) as tr:
        recs = tr.run()
        sched = [np.asarray(s) for s in tr.schedule]
        finite = bool(all(np.all(np.isfinite(np.asarray(leaf)))
                          for leaf in jax.tree.leaves(tr.params)))
    expected_q = sum(int(plan.delta_targets(t, sched[t]).sum())
                     for t in range(ROUNDS))
    got_q = sum(r.quarantined for r in recs)
    return {
        "quarantined": got_q,
        "clipped": sum(r.clipped for r in recs),
        "deadline_fired": sum(r.deadline_fired for r in recs),
        "deadline_dropped": sum(r.deadline_dropped for r in recs),
        "ingest_restarts": sum(r.ingest_restarts for r in recs),
        "expected_quarantined": expected_q,
        "quarantine_matches_plan": bool(got_q == expected_q),
        "params_finite": finite,
        "final_train_loss": float(recs[-1].train_loss),
        "mean_s": (time.perf_counter() - tic) / ROUNDS,
    }


def run_control(plan) -> bool:
    """guard=False under the NaN plan: the params MUST go non-finite —
    proof the counters above measure a real defense."""
    with _trainer("fedavg", plan, guard=False) as tr:
        tr.run()
        return bool(any(not np.all(np.isfinite(np.asarray(leaf)))
                        for leaf in jax.tree.leaves(tr.params)))


def run_ckpt_fallback() -> Dict[str, bool]:
    """Corrupt the newest step each way; resolve_step must fall back to
    the older intact step (and never pick the damaged one)."""
    out = {}
    for mode in ("truncate", "bitflip", "drop_digest"):
        with tempfile.TemporaryDirectory() as d:
            with _trainer("fedavg", FaultPlan.seeded(0), guard=True) as tr:
                for t in range(4):
                    tr.run_round(t)
                    if t in (1, 3):
                        tr.save(d, keep=5)
            corrupt_checkpoint(d, 4, mode)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                out[mode] = bool(ckpt.resolve_step(d) == 2)
    return out


def run(out: str = None) -> Dict:
    plan = FaultPlan.seeded(7, **PLAN_KW)
    fired = {
        "nan_delta": False, "explode_delta": False, "client_hang": False,
        "ingest_crash": bool(plan.ingest_crash(1)),
    }
    modes = {}
    for algorithm in ALGOS:
        print(f"[chaos] {algorithm} ...")
        modes[algorithm] = run_mode(algorithm, plan)
    # which delta/hang injectors actually fired, from the realized
    # schedule of the last run (all runs share seed + sampler => same
    # schedule); codes: 1 = nan, 2 = explode
    with _trainer("fedavg", plan, guard=True) as tr:
        tr.run()
        sched = [np.asarray(s) for s in tr.schedule]
    for t in range(ROUNDS):
        codes = plan.delta_codes(t, sched[t])
        fired["nan_delta"] |= bool((codes == 1).any())
        fired["explode_delta"] |= bool((codes == 2).any())
        fired["client_hang"] |= bool(plan.latency_boost(t, sched[t]).any())
    payload = {
        "bench": "chaos_smoke",
        "num_clients": NUM_CLIENTS, "clients_per_round": K,
        "rounds": ROUNDS, "plan": plan.config_dict(),
        "modes": modes,
        "injectors_fired": fired,
        "all_injectors_fired": bool(all(fired.values())),
        "unguarded_control_nonfinite": run_control(plan),
        "ckpt_fallback": run_ckpt_fallback(),
        "backend": jax.default_backend(),
    }
    out = out or DEFAULT_OUT
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    print(f"[chaos] wrote {out}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help=f"receipt path (default {DEFAULT_OUT})")
    a = ap.parse_args(argv)
    payload = run(out=a.out)
    ok = (payload["all_injectors_fired"]
          and payload["unguarded_control_nonfinite"]
          and all(payload["ckpt_fallback"].values())
          and all(m["quarantine_matches_plan"] and m["params_finite"]
                  for m in payload["modes"].values()))
    print("chaos smoke OK" if ok else "chaos smoke FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
