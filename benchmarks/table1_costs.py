"""Paper Table 1: computation costs of the server and client steps.

Two parts:
  (a) measured wall-time of each server rule on a d ~= 1M-param update
      stack (k'=10 clients) — validates the paper's cost ordering
      (FedDPC server ~ O(4k'd) elementwise vs FedAvg O(k'd)).
  (b) the FUSED Pallas epilogue vs the naive multi-pass server math —
      the beyond-paper win: bytes-per-round accounting (6d -> 4d reads
      for the scalars, 4d -> 3d for the epilogue).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_results
from repro.core.baselines import get_algorithm


def _bench(fn, *args, iters=5):
    fn(*args)                                     # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(d: int = 1_000_000, kprime: int = 10):
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (d,))}
    deltas = {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                     (kprime, d))}
    ids = jnp.arange(kprime, dtype=jnp.int32)

    out = {"d": d, "k_prime": kprime, "server_ms": {}}
    for name in ("fedavg", "fedexp", "fedvarp", "feddpc", "feddpc_noscale"):
        algo = get_algorithm(name)
        state = algo.init(params, 100)
        step = jax.jit(lambda s, p, dd: algo.step(s, p, dd, ids, 1.0, 0))
        ms = _bench(step, state, params, deltas) * 1e3
        out["server_ms"][name] = ms
        print(f"  server {name:16s}: {ms:8.2f} ms / round "
              f"(k'={kprime}, d={d:.0e})")

    # (b) fused kernel epilogue vs unfused tree math, one client update
    from repro.kernels.feddpc_project import ops as k_ops
    d1 = jax.random.normal(key, (d,))
    p1 = jax.random.normal(jax.random.fold_in(key, 2), (d,))

    fused = jax.jit(lambda a, b: k_ops.project_and_scale_flat(a, b, 1.0))
    from repro.core import projection as proj
    unfused = jax.jit(lambda a, b: proj.project_and_scale(
        {"w": a}, {"w": b}, 1.0)[0]["w"])
    t_f = _bench(fused, d1, p1) * 1e3
    t_u = _bench(unfused, d1, p1) * 1e3
    out["epilogue_ms"] = {"fused_pallas_interpret": t_f, "unfused_jnp": t_u}
    print(f"  epilogue fused(interpret)={t_f:.2f} ms, unfused jnp={t_u:.2f} ms"
          f"  (interpret mode measures correctness, not TPU perf; the"
          f" structural win is 10d->7d HBM bytes/update — see EXPERIMENTS)")

    # cost ordering claim from Table 1: feddpc server cost is O(4k'd), i.e.
    # same asymptotic class as fedavg (both linear in k'd)
    out["ordering_ok"] = out["server_ms"]["feddpc"] < \
        out["server_ms"]["fedavg"] * 25
    save_results("table1_costs", out)
    return out


if __name__ == "__main__":
    run()
