"""Paper Figure 5: TinyImageNet-like classification (64x64 images,
ResNet18+GN). Quick mode: 20 classes, width-16."""
from __future__ import annotations

from benchmarks.common import QUICK_TINYIMAGENET, ascii_curves, run_sweep, \
    save_results

ALGOS = ("fedavg", "fedcm", "feddpc")


def run(quick: bool = True, seed: int = 0):
    spec = QUICK_TINYIMAGENET
    if not quick:
        spec = spec.__class__(**{**spec.__dict__, "rounds": 800,
                                 "num_clients": 100, "width": 64,
                                 "num_classes": 200,
                                 "samples_per_class": 500})
    print(f"== Fig 5 (TinyImageNet-like 64px, ResNet18+GN) — "
          f"{spec.rounds} rounds ==")
    res = run_sweep(spec, ALGOS, alphas=(0.2,), seed=seed)
    save_results("fig5_tinyimagenet", res)
    print(ascii_curves(res, "loss"))
    return res


if __name__ == "__main__":
    import sys
    run(quick="--paper" not in sys.argv)
