"""Run-health receipt — the health monitor + curve gate driven end to
end (DESIGN.md §14): a short deterministic training run with the monitor
on, per server optimizer (sgd / fedadam / fedyogi), recording the
per-round ``train_loss_curve`` and the monitor's verdict counters.

The receipt is the CI ``health-smoke`` lane's subject, in both
directions:

  clean run      must gate PASS against the committed baseline
                 (benchmarks/baselines/bench_health_smoke.json) — the
                 curves are seed-deterministic simulation output, held
                 pointwise by bench_gate's curve class
  --inject-spike the same run with a seeded mid-run delta explosion
                 (guard OFF, so the spike reaches the aggregate) must
                 make the SAME gate command exit nonzero: the spiked
                 curve leaves the baseline band and the monitor's alarm
                 counters leave their exact-match zeros — proof the
                 curve gate actually fails on a regressing trajectory,
                 not just on structural drift

``alarmed_rounds`` / ``spike_rounds`` / ``stopped_early`` are
seed-deterministic integers gated exactly; ``*_s`` keys get the perf
band like every other receipt.

  PYTHONPATH=src python -m benchmarks.bench_health --out /tmp/health.json
  PYTHONPATH=src python -m benchmarks.bench_health --inject-spike \
      --out /tmp/health_spiked.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.faults import FaultPlan

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_health.json")

NUM_CLIENTS = 10
K = 4
ROUNDS = 12
SPIKE_ROUND = 8          # past health_min_history, so the detector is armed
SERVER_OPTS = ("sgd", "fedadam", "fedyogi")


def build_task(seed: int = 0):
    r = np.random.RandomState(seed)
    params = {"w1": jnp.asarray(r.randn(8, 16) * 0.3, jnp.float32),
              "b1": jnp.zeros((16,), jnp.float32),
              "w2": jnp.asarray(r.randn(16, 4) * 0.3, jnp.float32),
              "b2": jnp.zeros((4,), jnp.float32)}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["w1"] + p["b1"])
        pred = h @ p["w2"] + p["b2"]
        return jnp.mean((pred - b["y"]) ** 2)

    def batch_fn(c, t):
        rc = np.random.RandomState(1000 * c + t)
        return [{"x": rc.randn(8, 8).astype(np.float32),
                 "y": rc.randn(8, 4).astype(np.float32)}
                for _ in range((c % 2) + 1)]

    return params, loss_fn, batch_fn


def run_mode(server_opt: str, plan) -> Dict:
    params, loss_fn, batch_fn = build_task()
    cfg = ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=5,
                     eval_every=10 ** 9, server_opt=server_opt,
                     health=True, health_min_history=4,
                     health_spike_mult=3.0)
    algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)
    tic = time.perf_counter()
    with FederatedTrainer(loss_fn, params, NUM_CLIENTS, batch_fn, cfg,
                          algo=algo, fault_plan=plan) as tr:
        recs = tr.run()
        rep = tr.health_report
    return {
        "mean_s": (time.perf_counter() - tic) / max(1, len(recs)),
        "rounds_completed": len(recs),
        "stopped_early": bool(len(recs) < ROUNDS),
        "final_train_loss": float(recs[-1].train_loss),
        "train_loss_curve": [float(r.train_loss) for r in recs],
        # monitor verdict: seed-deterministic integers, gated exactly
        "alarmed_rounds": int(rep.alarmed_rounds),
        "spike_rounds": int(rep.spike_rounds),
        "nonfinite_rounds": int(rep.nonfinite_rounds),
        "healthy_at_end": bool(rep.healthy),
    }


def run(out: str = None, inject_spike: bool = False) -> Dict:
    # guard OFF: the injected explosion must REACH the aggregate — this
    # lane proves the detector and the curve gate catch what the guard
    # would normally absorb
    plan = (FaultPlan.seeded(7, explode_rate=1.0,
                             explode_rounds=(SPIKE_ROUND,),
                             explode_magnitude=200.0)
            if inject_spike else None)
    modes = {}
    for server_opt in SERVER_OPTS:
        print(f"[health] {server_opt} ...")
        modes[server_opt] = run_mode(server_opt, plan)
        m = modes[server_opt]
        print(f"  rounds {m['rounds_completed']:3d}  final loss "
              f"{m['final_train_loss']:.5f}  alarmed {m['alarmed_rounds']}")
    payload = {
        "bench": "health_monitor",
        "num_clients": NUM_CLIENTS, "clients_per_round": K,
        "rounds": ROUNDS,
        "spike_injected": bool(inject_spike),
        "modes": modes,
        "backend": jax.default_backend(),
        "note": ("train_loss_curve is seed-deterministic simulation "
                 "output held pointwise by bench_gate's curve class; "
                 "the alarm counters are exact-match integers "
                 "(DESIGN.md §14)"),
    }
    out = out or DEFAULT_OUT
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    print(f"[health] wrote {out}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help=f"receipt path (default {DEFAULT_OUT})")
    ap.add_argument("--inject-spike", action="store_true",
                    help="seeded delta explosion (guard off) at round "
                         f"{SPIKE_ROUND}: the receipt must then FAIL the "
                         "gate vs the clean baseline")
    a = ap.parse_args(argv)
    payload = run(out=a.out, inject_spike=a.inject_spike)
    if a.inject_spike:
        # the spike must actually register, otherwise the inverted CI
        # check would "pass" vacuously on a dead injector
        ok = any(m["alarmed_rounds"] > 0 or m["stopped_early"]
                 for m in payload["modes"].values())
        print("health spike registered" if ok
              else "health spike FAILED to register")
        return 0 if ok else 1
    ok = all(m["healthy_at_end"] and m["rounds_completed"] == ROUNDS
             for m in payload["modes"].values())
    print("health smoke OK" if ok else "health smoke FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
