"""Benchmark orchestrator: one harness per paper table/figure plus the
roofline report.

  PYTHONPATH=src python -m benchmarks.run             # quick mode (CPU)
  PYTHONPATH=src python -m benchmarks.run --paper     # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --only fig3_cifar10,table1_costs
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args(argv)

    from benchmarks import (fig3_cifar10, fig4_cifar100, fig5_tinyimagenet,
                            fig6_ablation, fig7_lambda, roofline_report,
                            table1_costs, table2_best_acc)
    all_benches = [
        ("fig3_cifar10", lambda: fig3_cifar10.run(quick=not args.paper)),
        ("fig4_cifar100", lambda: fig4_cifar100.run(quick=not args.paper)),
        ("fig5_tinyimagenet",
         lambda: fig5_tinyimagenet.run(quick=not args.paper)),
        ("fig6_ablation", lambda: fig6_ablation.run(quick=not args.paper)),
        ("fig7_lambda", lambda: fig7_lambda.run(quick=not args.paper)),
        ("table1_costs", lambda: table1_costs.run()),
        ("table2_best_acc", lambda: table2_best_acc.run()),
        ("roofline", lambda: roofline_report.run()),
    ]
    sel = set(args.only.split(",")) if args.only else None
    failures = []
    for name, fn in all_benches:
        if sel and name not in sel:
            continue
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            fn()
            print(f"[bench {name} OK, {time.time() - t0:.1f}s]")
        except Exception as e:
            import traceback
            traceback.print_exc()
            failures.append(name)
            print(f"[bench {name} FAILED: {e}]")
    print(f"\n== benchmarks done; {len(failures)} failures {failures} ==")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
