import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import roofline_table_entry

rows = []
def run(arch, shape, **kw):
    try:
        rl = roofline_table_entry(arch, shape, verbose=False, **kw)
        d = rl.as_dict(); d["config"] = str(kw)
        rows.append(d)
        print(f"[OK] {arch} x {shape} {kw}: coll={rl.t_collective:.3f}s "
              f"mem={rl.t_memory:.3f}s mem/dev={rl.memory_per_device/1e9:.1f}GB "
              f"dominant={rl.dominant}")
    except Exception as e:
        print(f"[FAIL] {arch} x {shape}: {e}")

# MoE archs with expert-parallel shard_map + microbatching (train)
for arch in ("kimi-k2-1t-a32b", "deepseek-v2-236b"):
    run(arch, "train_4k", moe_impl="ep", step_kwargs={"microbatches": 8})
    run(arch, "prefill_32k", moe_impl="ep")
    run(arch, "decode_32k", moe_impl="ep")
# dense decode rows (sdpa dispatch fix + donation are now defaults)
for arch in ("starcoder2-3b", "minitron-8b", "llava-next-mistral-7b",
             "phi4-mini-3.8b", "command-r-35b", "whisper-base"):
    run(arch, "decode_32k")
    run(arch, "long_500k")
run("jamba-1.5-large-398b", "decode_32k")
run("jamba-1.5-large-398b", "long_500k")
run("falcon-mamba-7b", "decode_32k")

# context-managed like the trainer-driven scripts: no leaked handles
with open("/root/repo/results_roofline_optimized.json", "w") as f:
    json.dump(rows, f, indent=1)
print(f"{len(rows)} optimized rows written")
