"""Batched multi-architecture serving example: prefill + decode a batch of
requests against three different architecture families (dense GQA, pure
SSM, MoE+MLA) through the same serve API.

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.configs.base import get_config
from repro.launch.serve import serve_lm


def main():
    for arch in ("starcoder2-3b", "falcon-mamba-7b", "deepseek-v2-236b"):
        cfg = get_config(arch, smoke=True)
        tokens, stats = serve_lm(cfg, batch=4, prompt_len=24, gen=12)
        print(f"{arch:24s} [{cfg.arch_type:6s}] -> {tokens.shape} tokens, "
              f"{stats['tok_per_s']:.1f} tok/s (prefill "
              f"{stats['prefill_s']:.2f}s)")
        assert tokens.shape == (4, 12)
    print("\nall three families served through one API (explicit "
          "cache/state pytrees; ring-buffer KV for dense, O(1) state for "
          "SSM, compressed-latent cache for MLA).")


if __name__ == "__main__":
    main()
