"""Multi-pod dry-run demo: lower + compile one (arch x shape) on the
production mesh and print its roofline — without any TPU hardware.

  PYTHONPATH=src python examples/dryrun_demo.py --arch deepseek-v2-236b \
      --shape prefill_32k --multi-pod

NOTE: must run as its own process (the 512 placeholder devices lock at
jax init), which is why this demo shells into repro.launch.dryrun.
"""
import argparse
import os
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", args.arch, "--shape", args.shape,
           "--mesh", "multi" if args.multi_pod else "single", "--table"]
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
