"""Quickstart: FedDPC vs FedAvg on a heterogeneous federated image task,
on the composable engine surface (DESIGN.md §3).

  PYTHONPATH=src python examples/quickstart.py

Trains LeNet5 over 15 federated rounds with Dirichlet(0.2)-partitioned
synthetic images, 10 of 30 clients participating per round — the paper's
setting at laptop scale — and shows FedDPC's faster loss reduction.

The pieces compose explicitly:
  * ``StreamingImageSource`` streams per-client batches into the cohort
    prefetcher (no pre-materialized lists);
  * ``UniformSampler`` is the paper's participation model — swap in
    ``WeightedSampler(source.client_weights(), 10)`` or
    ``MarkovSampler(30, 10)`` to study other participation regimes;
  * ``AlgoConfig(name=..., hyper=...)`` resolves the server rule through
    the algorithm registry (``FedDPCHyper(lam=...)`` etc.);
  * ``ExecConfig`` carries the execution levers (the fused vectorized
    round is the default; ``vectorize=False`` restores the serial
    reference path — see benchmarks/bench_cohort.py for the gap).

Each round runs as ONE fused jit'd program: the 10-client cohort is
stacked on the client axis and local training is vmapped over it.
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.baselines import FedDPCHyper
from repro.core.samplers import UniformSampler
from repro.ingest import StreamingImageSource, build_federated_image_data
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)


def main(rounds: int = 15, num_clients: int = 30, cohort: int = 10):
    vc = VisionConfig(name="quickstart", family="lenet5", num_classes=10)
    data = build_federated_image_data(
        num_classes=10, num_clients=num_clients, alpha=0.2,  # heterogeneous!
        samples_per_class=100, test_per_class=20, seed=0)
    params = init_vision(vc, jax.random.PRNGKey(0))
    loss_fn = functools.partial(vision_loss_fn, vc)
    source = StreamingImageSource(data, batch_size=64)

    te_x, te_y = jnp.asarray(data.test_images), jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))

    for name in ("fedavg", "feddpc"):
        algo = AlgoConfig(name=name, eta_l=0.02, eta_g=0.02,
                          hyper=FedDPCHyper(lam=1.0) if name == "feddpc"
                          else None)
        cfg = ExecConfig(rounds=rounds, clients_per_round=cohort,
                         eval_every=5)
        with FederatedTrainer(loss_fn, params, data.num_clients, source,
                              cfg, eval_fn, algo=algo,
                              sampler=UniformSampler(data.num_clients,
                                                     cohort)) as trainer:
            hist = trainer.run(verbose=True)
            best, at = trainer.best_accuracy
        # median over post-round-0 rounds: robust to the rounds that
        # recompile when the minibatch bucket grows past its round-0
        # value (round 0 alone when --rounds 1)
        timed = hist[1:] or hist
        sec = sorted(r.seconds for r in timed)[(len(timed) - 1) // 2]
        print(f"--> {name}: best test acc {best:.4f} @ round {at}, "
              f"final loss {hist[-1].train_loss:.4f}, "
              f"{sec * 1e3:.1f} ms/round (median)\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--cohort", type=int, default=10)
    a = ap.parse_args()
    main(a.rounds, a.clients, a.cohort)
