"""Quickstart: FedDPC vs FedAvg on a heterogeneous federated image task.

  PYTHONPATH=src python examples/quickstart.py

Trains LeNet5 over 15 federated rounds with Dirichlet(0.2)-partitioned
synthetic images, 10 of 30 clients participating per round — the paper's
setting at laptop scale — and shows FedDPC's faster loss reduction.

Each round runs as ONE fused jit'd program: the 10-client cohort is
stacked on the client axis and local training is vmapped over it
(FLConfig(vectorize=False) restores the serial per-client path; see
benchmarks/bench_cohort.py for the latency gap).
"""
import functools

import jax
import jax.numpy as jnp

from repro.core.api import FLConfig, FederatedTrainer
from repro.data.pipeline import build_federated_image_data, client_batches
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)


def main():
    vc = VisionConfig(name="quickstart", family="lenet5", num_classes=10)
    data = build_federated_image_data(
        num_classes=10, num_clients=30, alpha=0.2,       # heterogeneous!
        samples_per_class=100, test_per_class=20, seed=0)
    params = init_vision(vc, jax.random.PRNGKey(0))
    loss_fn = functools.partial(vision_loss_fn, vc)

    def batch_fn(client, round_num):
        return list(client_batches(data, client, 64, round_num))

    te_x, te_y = jnp.asarray(data.test_images), jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))

    for algo in ("fedavg", "feddpc"):
        cfg = FLConfig(algorithm=algo, rounds=15, clients_per_round=10,
                       eta_l=0.02, eta_g=0.02, lam=1.0, eval_every=5)
        trainer = FederatedTrainer(loss_fn, params, data.num_clients,
                                   batch_fn, cfg, eval_fn)
        hist = trainer.run(verbose=True)
        best, at = trainer.best_accuracy
        # median: robust to the rounds that recompile when the minibatch
        # bucket (_max_batches) grows past its round-0 value
        sec = sorted(r.seconds for r in hist[1:])[(len(hist) - 1) // 2]
        print(f"--> {algo}: best test acc {best:.4f} @ round {at}, "
              f"final loss {hist[-1].train_loss:.4f}, "
              f"{sec * 1e3:.1f} ms/round (median)\n")


if __name__ == "__main__":
    main()
