"""Heterogeneity & participation study: how FedDPC's advantage over the
baselines scales with (a) data heterogeneity (Dirichlet alpha), (b) the
client participation RATE, and (c) the participation REGIME — the axes
the paper targets, plus the partial-participation regimes the FedVARP
comparison (arXiv:2207.14130) and the participation review
(arXiv:2506.02887) motivate.

Part 1 sweeps alpha x participation-rate for fedavg vs feddpc (the
paper's grid). Part 2 holds (alpha, rate) at the hard corner and sweeps
the participation REGIME — uniform vs cyclic block schedules vs Markov
on/off availability (core/samplers.py) — for fedavg vs fedvarp vs
feddpc: variance-reduction methods are exactly the ones whose gap the
regime is supposed to move.

  PYTHONPATH=src python examples/heterogeneity_study.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.samplers import (CyclicSampler, MarkovSampler,
                                 UniformSampler)
from repro.data.dirichlet import partition_stats
from repro.ingest import StreamingImageSource, build_federated_image_data
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)

ROUNDS = 12
NUM_CLIENTS = 20


def make_sampler(name, cohort, seed=0):
    if name == "uniform":
        return UniformSampler(NUM_CLIENTS, cohort)
    if name == "cyclic":
        return CyclicSampler(NUM_CLIENTS, cohort)
    if name == "markov":
        # sticky availability: a client that is up tends to stay up
        return MarkovSampler(NUM_CLIENTS, cohort, p_on=0.3, p_off=0.2)
    raise ValueError(name)


def run_one(alpha, participation, algo, sampler="uniform", seed=0):
    vc = VisionConfig(name="study", family="lenet5", num_classes=8)
    data = build_federated_image_data(
        num_classes=8, num_clients=NUM_CLIENTS, alpha=alpha,
        samples_per_class=60, test_per_class=15, seed=seed)
    params = init_vision(vc, jax.random.PRNGKey(seed))
    loss_fn = functools.partial(vision_loss_fn, vc)
    source = StreamingImageSource(data, batch_size=48)
    te_x, te_y = jnp.asarray(data.test_images), jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    cohort = max(1, int(NUM_CLIENTS * participation))
    cfg = ExecConfig(rounds=ROUNDS, clients_per_round=cohort,
                     eval_every=3, seed=seed)
    with FederatedTrainer(loss_fn, params, NUM_CLIENTS, source, cfg, eval_fn,
                          algo=AlgoConfig(name=algo, eta_l=0.02,
                                          eta_g=0.02),
                          sampler=make_sampler(sampler, cohort,
                                               seed)) as tr:
        tr.run()
        best, _ = tr.best_accuracy
    tv = partition_stats(data.train_labels,
                         data.client_indices)["mean_tv_from_uniform"]
    return best, tv


def heterogeneity_sweep():
    print(f"{'alpha':>6s} {'part.':>6s} {'TV-skew':>8s} "
          f"{'fedavg':>8s} {'feddpc':>8s} {'gain':>7s}")
    for alpha in (0.1, 0.5, 5.0):
        for part in (0.15, 0.5):
            accs = {}
            for algo in ("fedavg", "feddpc"):
                accs[algo], tv = run_one(alpha, part, algo)
            gain = accs["feddpc"] - accs["fedavg"]
            print(f"{alpha:6.1f} {part:6.2f} {tv:8.3f} "
                  f"{accs['fedavg']:8.4f} {accs['feddpc']:8.4f} "
                  f"{gain:+7.4f}")
    print("\nexpected pattern: FedDPC's gain is largest at small alpha "
          "(high heterogeneity) and low participation — the two variance "
          "sources it controls.\n")


def participation_regime_sweep(alpha=0.1, part=0.15):
    """FedDPC vs FedVARP vs FedAvg under uniform / cyclic / Markov
    participation at the hard (skewed, sparse) corner of the grid."""
    algos = ("fedavg", "fedvarp", "feddpc")
    print(f"participation-regime sweep @ alpha={alpha}, "
          f"participation={part}")
    print(f"{'sampler':>8s} " + " ".join(f"{a:>8s}" for a in algos)
          + f" {'best':>8s}")
    for sampler in ("uniform", "cyclic", "markov"):
        accs = {}
        for algo in algos:
            accs[algo], _ = run_one(alpha, part, algo, sampler=sampler)
        best = max(accs, key=accs.get)
        print(f"{sampler:>8s} "
              + " ".join(f"{accs[a]:8.4f}" for a in algos)
              + f" {best:>8s}")
    print("\nexpected pattern: under the non-uniform regimes (cyclic "
          "blocks, sticky Markov availability) the variance-handling "
          "methods (feddpc, fedvarp) hold up while plain fedavg "
          "degrades — participation noise is exactly what they damp.")


def main():
    heterogeneity_sweep()
    participation_regime_sweep()


if __name__ == "__main__":
    main()
