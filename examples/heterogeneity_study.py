"""Heterogeneity & participation study: how FedDPC's advantage over FedAvg
scales with (a) data heterogeneity (Dirichlet alpha) and (b) the client
participation rate — the two axes the paper targets.

  PYTHONPATH=src python examples/heterogeneity_study.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.data.dirichlet import partition_stats
from repro.data.pipeline import StreamingImageSource, \
    build_federated_image_data
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)

ROUNDS = 12


def run_one(alpha, participation, algo, seed=0):
    vc = VisionConfig(name="study", family="lenet5", num_classes=8)
    data = build_federated_image_data(
        num_classes=8, num_clients=20, alpha=alpha, samples_per_class=60,
        test_per_class=15, seed=seed)
    params = init_vision(vc, jax.random.PRNGKey(seed))
    loss_fn = functools.partial(vision_loss_fn, vc)
    source = StreamingImageSource(data, batch_size=48)
    te_x, te_y = jnp.asarray(data.test_images), jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    cfg = ExecConfig(rounds=ROUNDS,
                     clients_per_round=max(1, int(20 * participation)),
                     eval_every=3, seed=seed)
    with FederatedTrainer(loss_fn, params, 20, source, cfg, eval_fn,
                          algo=AlgoConfig(name=algo, eta_l=0.02,
                                          eta_g=0.02)) as tr:
        tr.run()
        best, _ = tr.best_accuracy
    tv = partition_stats(data.train_labels,
                         data.client_indices)["mean_tv_from_uniform"]
    return best, tv


def main():
    print(f"{'alpha':>6s} {'part.':>6s} {'TV-skew':>8s} "
          f"{'fedavg':>8s} {'feddpc':>8s} {'gain':>7s}")
    for alpha in (0.1, 0.5, 5.0):
        for part in (0.15, 0.5):
            accs = {}
            for algo in ("fedavg", "feddpc"):
                accs[algo], tv = run_one(alpha, part, algo)
            gain = accs["feddpc"] - accs["fedavg"]
            print(f"{alpha:6.1f} {part:6.2f} {tv:8.3f} "
                  f"{accs['fedavg']:8.4f} {accs['feddpc']:8.4f} "
                  f"{gain:+7.4f}")
    print("\nexpected pattern: FedDPC's gain is largest at small alpha "
          "(high heterogeneity) and low participation — the two variance "
          "sources it controls.")


if __name__ == "__main__":
    main()
