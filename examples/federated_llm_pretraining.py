"""End-to-end driver (deliverable b): federated pretraining of a ~100M-
parameter GPT-style LM for a few hundred rounds with FedDPC.

  PYTHONPATH=src python examples/federated_llm_pretraining.py            # ~100M, 200 rounds
  PYTHONPATH=src python examples/federated_llm_pretraining.py --tiny     # CI-sized

This is the beyond-paper scenario the framework exists for: cross-silo
federated LLM training where each client is a data silo with a topic-
skewed corpus (Dirichlet-partitioned Zipf LM streams). The model is the
starcoder2 family config scaled to ~100M params; the server runs FedDPC
(projection + adaptive scaling) and checkpoints every 25 rounds.
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.baselines import FedDPCHyper
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_lm_dataset
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/feddpc_llm_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("starcoder2-3b", smoke=True)
        rounds = args.rounds or 8
        clients, part, seq, bsz = 8, 4, 64, 4
        docs = 256
        eta = 0.01      # the smoke config diverges at the full-run LR
    else:
        # ~100M params: 12 layers x d_model 768, vocab 16384
        cfg = get_config("starcoder2-3b").with_(
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            d_ff=3072, vocab_size=16384, max_seq_len=512)
        rounds = args.rounds or 200
        clients, part, seq, bsz = 20, 5, 256, 8
        docs = 2000
        eta = 0.05

    params = tf.init_lm(cfg, jax.random.PRNGKey(0), jnp.float32)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params, {cfg.num_layers}L d={cfg.d_model}")

    tokens, topics = make_lm_dataset(docs, seq + 1, cfg.vocab_size, seed=0)
    parts = dirichlet_partition(topics, clients, alpha=0.3, seed=0,
                                min_size=1)
    sizes = [len(p) for p in parts]
    print(f"{clients} clients, doc counts: min={min(sizes)} max={max(sizes)}")

    def loss_fn(p, batch):
        return tf.loss_fn(cfg, p, batch)

    def batch_fn(c, t):
        idx = parts[c]
        rng = np.random.RandomState(hash((c, t)) % (2 ** 31))
        sel = idx[rng.permutation(len(idx))]
        sel = np.concatenate([sel] * (bsz // max(len(sel), 1) + 1))[:bsz]
        tk = tokens[sel]
        return [{"tokens": jnp.asarray(tk[:, :-1]),
                 "labels": jnp.asarray(tk[:, 1:])}]

    holdout = jnp.asarray(tokens[: 4 * bsz])

    @jax.jit
    def eval_fn(p):
        l = loss_fn(p, {"tokens": holdout[:, :-1], "labels": holdout[:, 1:]})
        return -l                       # "accuracy" slot = -holdout loss

    algo = AlgoConfig(name="feddpc", eta_l=eta, eta_g=eta,
                      hyper=FedDPCHyper(lam=1.0))
    exec_cfg = ExecConfig(rounds=rounds, clients_per_round=part,
                          eval_every=10,
                          # this example prints the holdout NLL inline with
                          # its round, so keep eval on the blocking path
                          async_eval=False)
    t0 = time.time()
    with FederatedTrainer(loss_fn, params, clients, batch_fn, exec_cfg,
                          eval_fn, algo=algo) as tr:
        for t in range(rounds):
            rec = tr.run_round(t)
            if t % 10 == 0 or t == rounds - 1:
                ho = f"  holdout_nll={-rec.test_accuracy:.4f}" \
                    if rec.test_accuracy is not None else ""
                print(f"round {t:4d} loss={rec.train_loss:.4f}{ho} "
                      f"({rec.seconds:.1f}s)")
            if t and t % 25 == 0:
                # full TrainerState: `FederatedTrainer.resume(ckpt_dir,
                # ...)` continues this run exactly where it stopped
                tr.save(args.ckpt_dir)
        print(f"done in {time.time()-t0:.0f}s; "
              f"loss {tr.history[0].train_loss:.3f} -> "
              f"{tr.history[-1].train_loss:.3f}")
        assert tr.history[-1].train_loss < tr.history[0].train_loss


if __name__ == "__main__":
    main()
