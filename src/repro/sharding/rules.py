"""PartitionSpec rules for every parameter / activation / cache class.

Name-based rules (DESIGN.md §8) with divisibility guards: a dim is only
sharded if it divides evenly by the axis size; otherwise that dim falls
back to replication. Rules are written *from the end* of the shape so the
same rule covers plain leaves and lax.scan-stacked leaves (leading G dim
from the grouped layer stack) and vmapped encoder/decoder stacks.

Default layout (the paper-faithful baseline; §Perf iterates on this):
  * Megatron TP over ``model``: QKV/up/gate column-, wo/down row-sharded.
  * MoE expert tensors sharded (experts over ``expert_axis``, ffn dim over
    ``model``) — expert_axis defaults to ``data`` on the production mesh,
    giving expert parallelism + per-device bytes /= |data|·|model|.
  * Activations: batch over (pod, data); model dim replicated.
  * KV caches: batch over (pod, data); kv-heads over model when divisible,
    else cache capacity over model.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclass(frozen=True)
class ShardingPolicy:
    model_axis: str = "model"
    batch_axes: Tuple[str, ...] = ("data",)      # ("pod","data") multi-pod
    # single axis name, or a TUPLE of axes (multi-pod expert parallelism
    # spans pod x data); None -> experts over model only
    expert_axis: Optional[object] = "data"
    shard_embed: bool = True
    # perf-iteration levers
    seq_axis: Optional[str] = None               # sequence parallelism (unused by default)
    fsdp_dense: bool = False                     # shard dense d_ff/vocab over data too


# rule := (regex over "/"-joined path, spec-from-end)
# spec entries: axis name, None, or special tokens "EXPERT"
def _rules(pol: ShardingPolicy):
    m = pol.model_axis
    e = pol.expert_axis if pol.expert_axis else m
    d0 = pol.batch_axes[-1] if pol.fsdp_dense else None
    return [
        # --- embeddings / head ---
        (r"(^|/)embed$",                 (m, None)),         # (V, D)
        (r"lm_head/w$",                  (d0, m)),           # (D, V)
        (r"head/w$",                     (None, None)),      # vision head (tiny)
        # --- attention (GQA + MLA + cross) ---
        (r"(wq|wk|wv)/w$",               (d0, m)),
        (r"(wq|wk|wv)/b$",               (m,)),
        (r"wo/w$",                       (m, d0)),
        (r"q_up/w$",                     (None, m)),
        (r"(k_up|v_up)/w$",              (None, m)),
        (r"(q_down|kv_down)/w$",         (None, None)),
        # --- MoE experts (E, D, F) / (E, F, D) ---
        (r"mlp/(gate|up)$",              ("EXPERT", None, m)),
        (r"mlp/down$",                   ("EXPERT", m, None)),
        (r"router/w$",                   (None, None)),
        (r"shared/(gate|up)/w$",         (d0, m)),
        (r"shared/down/w$",              (m, d0)),
        # --- dense MLP ---
        (r"(gate|up)/w$",                (d0, m)),
        (r"(gate|up)/b$",                (m,)),
        (r"down/w$",                     (m, d0)),
        # --- mamba ---
        (r"in_proj/w$",                  (None, m)),
        (r"conv_w$",                     (None, m)),
        (r"conv_b$",                     (m,)),
        (r"x_proj/w$",                   (m, None)),
        (r"dt_proj/w$",                  (None, m)),
        (r"dt_proj/b$",                  (m,)),
        (r"a_log$",                      (m, None)),
        (r"d_skip$",                     (m,)),
        (r"out_proj/w$",                 (m, None)),
    ]


def _leaf_spec(path: str, shape, pol: ShardingPolicy, axis_sizes) -> P:
    for pat, spec_end in _rules(pol):
        if re.search(pat, path):
            spec_end = list(spec_end)
            # resolve EXPERT token
            spec_end = [pol.expert_axis if s == "EXPERT" else s
                        for s in spec_end]
            n = len(shape)
            k = len(spec_end)
            if k > n:
                spec_end = spec_end[k - n:]
            full = [None] * (n - len(spec_end)) + spec_end
            # divisibility + duplicate-axis guards (ax may be a tuple of
            # mesh axes, e.g. experts over ("pod", "data"))
            used = set()
            out = []
            for dim, ax in zip(shape, full):
                axes = ax if isinstance(ax, tuple) else (ax,)
                if ax is None or any(a in used for a in axes):
                    out.append(None)
                    continue
                size = 1
                ok = True
                for a in axes:
                    s_a = axis_sizes.get(a)
                    if s_a is None:
                        ok = False
                        break
                    size *= s_a
                if not ok or dim % size != 0:
                    out.append(None)
                else:
                    out.append(ax)
                    used.update(axes)
            return P(*out)
    return P()          # default: replicate (norms, scalars, small biases)


def param_specs(params: PyTree, mesh: Mesh,
                pol: Optional[ShardingPolicy] = None) -> PyTree:
    pol = pol or ShardingPolicy(batch_axes=tuple(
        a for a in mesh.axis_names if a != "model"))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_str(k) for k in path)
        specs.append(_leaf_spec(pstr, np.shape(leaf), pol, axis_sizes))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------- activations / inputs ----------------

def _batch_spec(batch_size: int, pol: ShardingPolicy, axis_sizes):
    """Largest prefix of batch_axes whose product divides batch_size."""
    axes = []
    prod = 1
    for ax in pol.batch_axes:
        if batch_size % (prod * axis_sizes[ax]) == 0:
            axes.append(ax)
            prod *= axis_sizes[ax]
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def batch_specs(batch: PyTree, mesh: Mesh,
                pol: Optional[ShardingPolicy] = None) -> PyTree:
    """Inputs (tokens/labels/frames/patch_embeds): shard dim0 over batch axes."""
    pol = pol or ShardingPolicy(batch_axes=tuple(
        a for a in mesh.axis_names if a != "model"))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(leaf):
        shape = np.shape(leaf)
        if not shape:
            return P()
        b = _batch_spec(shape[0], pol, axis_sizes)
        return P(b, *([None] * (len(shape) - 1)))

    return jax.tree.map(one, batch)


def state_specs(states: PyTree, mesh: Mesh,
                pol: Optional[ShardingPolicy] = None) -> PyTree:
    """Serve states (KV caches / SSM states / MLA latent caches).

    Leaf classes recognized by path name:
      k/v      (…, B, C, KV, HD): batch over batch_axes; KV over model if
               divisible else C over model
      pos      (…, B, C): batch only
      c_kv/k_rope (…, B, C, R): batch; C over model
      conv     (…, B, W, d_in): batch; d_in over model
      h        (…, B, d_in, st): batch; d_in over model
      idx      scalar: replicated
    Leading scan/stack dims (group, period-index) are unsharded.
    """
    pol = pol or ShardingPolicy(batch_axes=tuple(
        a for a in mesh.axis_names if a != "model"))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = axis_sizes[pol.model_axis]
    flat, treedef = jax.tree_util.tree_flatten_with_path(states)

    def spec_for(pstr: str, shape) -> P:
        name = pstr.split("/")[-1]
        nd = len(shape)
        if nd == 0:
            return P()
        if name in ("k", "v") and nd >= 4:
            b, c, kv, hd = shape[-4:]
            bspec = _batch_spec(b, pol, axis_sizes)
            if kv % msize == 0:
                end = [bspec, None, pol.model_axis, None]
            elif c % msize == 0:
                end = [bspec, pol.model_axis, None, None]
            else:
                end = [bspec, None, None, None]
        elif name == "pos" and nd >= 2:
            end = [_batch_spec(shape[-2], pol, axis_sizes), None]
        elif name in ("c_kv", "k_rope") and nd >= 3:
            b, c, r = shape[-3:]
            end = [_batch_spec(b, pol, axis_sizes),
                   pol.model_axis if c % msize == 0 else None, None]
        elif name == "conv" and nd >= 3:
            b, w, din = shape[-3:]
            end = [_batch_spec(b, pol, axis_sizes), None,
                   pol.model_axis if din % msize == 0 else None]
        elif name == "h" and nd >= 3:
            b, din, st = shape[-3:]
            end = [_batch_spec(b, pol, axis_sizes),
                   pol.model_axis if din % msize == 0 else None, None]
        elif name == "enc_out" and nd >= 3:
            end = [_batch_spec(shape[-3], pol, axis_sizes), None, None]
        else:
            return P(*([None] * nd))
        full = [None] * (nd - len(end)) + end
        return P(*full)

    specs = [spec_for("/".join(_key_str(k) for k in path), np.shape(leaf))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------- cohort (simulation FL) round ----------------

def local_row_range(sharding: NamedSharding, nrows: int) -> Tuple[int, int]:
    """[lo, hi) of the leading-axis rows this PROCESS owns under a
    client-sharded layout (DESIGN.md §15).

    On a process-spanning clients mesh each host's ingest pipeline reads,
    decodes, and device-stages only its local shard — this is the lookup
    that tells it which cohort rows those are. Derived from the
    sharding's device→index map restricted to the addressable devices;
    the cohort layouts keep every process's rows CONTIGUOUS (the mesh
    enumerates devices in process order), and anything else is rejected
    rather than silently mis-staged. Single-process: (0, nrows).
    """
    probe = jax.ShapeDtypeStruct((nrows,), np.int32)
    imap = sharding.devices_indices_map(probe.shape)
    local = {d for d in sharding.mesh.devices.flat
             if d.process_index == jax.process_index()}
    rows = set()
    for dev, idx in imap.items():
        if dev not in local:
            continue
        sl = idx[0]
        lo = 0 if sl.start is None else sl.start
        hi = nrows if sl.stop is None else sl.stop
        rows.update(range(lo, hi))
    if not rows:
        raise ValueError("local_row_range: no addressable rows — the "
                         "sharding's mesh carries none of this process's "
                         "devices")
    lo, hi = min(rows), max(rows) + 1
    if rows != set(range(lo, hi)):
        raise ValueError(
            f"local_row_range: this process's rows {sorted(rows)} are not "
            "contiguous; the multi-host ingest contract needs the clients "
            "axis laid out in process order (launch/mesh.make_cohort_mesh)")
    return lo, hi


def cohort_param_specs(params: PyTree, mesh: Mesh,
                       client_axis: str = "clients",
                       model_axis: str = "model",
                       pol: Optional[ShardingPolicy] = None) -> PyTree:
    """Per-leaf ``model``-axis specs for the two-axis cohort round
    (DESIGN.md §2): the §8 name-based rules apply first (Megatron layout
    for LM-named leaves), and any leaf they leave fully replicated falls
    back to sharding its LAST model-divisible dim over ``model_axis`` —
    generic parameter trees (the paper's vision models, test MLPs) still
    partition, which is the whole point of the model axis (>HBM params).
    The client axis never appears in a param spec: the global model is
    identical across the cohort by definition.
    """
    pol = pol or ShardingPolicy(model_axis=model_axis,
                                batch_axes=(client_axis,), expert_axis=None)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = axis_sizes.get(model_axis, 1)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_str(k) for k in path)
        shape = np.shape(leaf)
        spec = _leaf_spec(pstr, shape, pol, axis_sizes)
        if msize > 1 and not any(s is not None for s in spec):
            out = [None] * len(shape)
            for i in range(len(shape) - 1, -1, -1):
                if shape[i] % msize == 0:
                    out[i] = model_axis
                    break
            spec = P(*out)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def cohort_state_specs(server_state: PyTree, params: PyTree, mesh: Mesh,
                       client_axis: str = "clients",
                       model_axis: str = "model",
                       pol: Optional[ShardingPolicy] = None) -> PyTree:
    """Model-axis specs for server state, co-varying with the param layout
    (DESIGN.md §2): every server rule's state is either a tree mirroring
    the params (``delta_prev``, FedDPC-M/FedAdam moments — same spec as
    the matching param leaf), a PER-CLIENT table with a leading
    ``num_clients`` dim over param-shaped rows (FedVARP's ``y`` — leading
    dim replicated, trailing dims take the param leaf's spec), or a
    scalar (replicated). Matching is by path: ``<key>/<param path>``
    looks up ``<param path>`` in the params tree.
    """
    pspecs = cohort_param_specs(params, mesh, client_axis, model_axis, pol)
    pflat, _ = jax.tree_util.tree_flatten_with_path(params)
    sflat = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    by_path = {"/".join(_key_str(k) for k in path): (np.shape(leaf), spec)
               for (path, leaf), spec in zip(pflat, sflat)}
    flat, treedef = jax.tree_util.tree_flatten_with_path(server_state)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_str(k) for k in path)
        rest = pstr.split("/", 1)[1] if "/" in pstr else None
        shape = np.shape(leaf)
        ent = by_path.get(rest)
        if ent is None:
            specs.append(P())                       # scalars / unknown leaves
        elif shape == ent[0]:
            specs.append(ent[1])                    # mirrors the param leaf
        elif shape[1:] == ent[0]:
            specs.append(P(None, *ent[1]))          # per-client table row
        else:
            specs.append(P())
    return jax.tree_util.tree_unflatten(treedef, specs)


def cohort_round_shardings(mesh: Mesh, client_axis: str = "clients", *,
                           model_axis: str = "model",
                           params: Optional[PyTree] = None,
                           server_state: Optional[PyTree] = None):
    """In/out sharding trees for the fused cohort round (core/round.py
    ``make_cohort_round``), signature
    (server_state, params, batches, masks, client_ids) ->
    (new_params, new_state, losses, diag).

    The client-stacked inputs (batches/masks/ids: leading axis K) shard
    over ``client_axis``. What happens to params / server state depends
    on the mesh:

    * 1-D client mesh (no ``model_axis``, or axis size 1): params and
      server state REPLICATE — FedDPC's epilogue then lowers to 4 scalar
      all-reduces + one all-reduce for the client mean (DESIGN.md §2).
      Prefix shardings apply to every leaf, so the same pair covers any
      batch pytree / server-state shape and no templates are needed.
    * two-axis (clients × model) mesh: params and server state get
      PER-LEAF ``model``-sharded specs (``cohort_param_specs`` /
      ``cohort_state_specs``), so each client slice holds only 1/|model|
      of the weights — the >HBM regime. This needs the actual
      ``params`` / ``server_state`` templates (shapes only are read);
      omitting them on a two-axis mesh fails loudly here rather than
      silently replicating a model that does not fit.

    Returns (in_shardings, out_shardings) ready for jax.jit.
    """
    if client_axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {client_axis!r} axis")
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    two_axis = axis_sizes.get(model_axis, 1) > 1
    if two_axis and (params is None or server_state is None):
        raise ValueError(
            f"mesh carries a {model_axis!r} axis of size "
            f"{axis_sizes[model_axis]}: the two-axis cohort round needs "
            "params/server_state templates for per-leaf specs (pass "
            "shard_templates= to make_cohort_round)")
    rep = NamedSharding(mesh, P())
    cli = NamedSharding(mesh, P(client_axis))
    if not two_axis:
        # losses (K,) stay client-sharded; diagnostics are scalars ->
        # replicated
        return (rep, rep, cli, cli, cli), (rep, rep, cli, rep)
    p_sh = to_named(cohort_param_specs(params, mesh, client_axis,
                                       model_axis), mesh)
    s_sh = to_named(cohort_state_specs(server_state, params, mesh,
                                       client_axis, model_axis), mesh)
    return (s_sh, p_sh, cli, cli, cli), (p_sh, s_sh, cli, rep)


def codec_payload_specs(param_specs: PyTree, lead, *,
                        is_leaf=None) -> PyTree:
    """Payload-structured specs for a codec wire dict (repro/codec):
    ``{"q": <param-shaped tree with a leading client/buffer axis>,
    "scale"/"zero": <per-leaf (K,) vectors>}``. ``param_specs`` is the
    per-leaf model-layout spec tree for the UNDERLYING deltas; ``lead``
    names the leading-axis sharding (``client_axis`` for cohort stacks,
    ``None`` for the arrival buffer). The quantized codes keep the model
    layout of the leaf they encode; the per-leaf scale/zero vectors only
    carry the leading axis.
    """
    is_leaf = is_leaf or (lambda x: isinstance(x, P))
    return {
        "q": jax.tree.map(lambda s: P(lead, *s), param_specs,
                          is_leaf=is_leaf),
        "scale": jax.tree.map(lambda s: P(lead), param_specs,
                              is_leaf=is_leaf),
        "zero": jax.tree.map(lambda s: P(lead), param_specs,
                             is_leaf=is_leaf),
    }


def async_round_shardings(mesh: Mesh, client_axis: str = "clients", *,
                          model_axis: str = "model",
                          params: Optional[PyTree] = None,
                          server_state: Optional[PyTree] = None,
                          codec_payload: bool = False):
    """Sharding trees for the TWO jits of the buffered-async engine
    (core/async_engine.py, DESIGN.md §11), which splits the fused round
    at the arrival buffer:

      wave_update (params, server_state, batches, masks)
                  -> (deltas (Kp, ...), losses (Kp,))
      fold        (server_state, params, deltas (B, ...), ids, weights)
                  -> (new_params, new_state, diag)

    The wave side mirrors the sync round: cohort-stacked inputs and the
    per-client delta outputs shard over ``client_axis``. The FOLD side
    differs: its leading axis is the ARRIVAL BUFFER (size B, arrival-
    ordered, unrelated to the device count), so the buffered deltas
    replicate their leading dim — only the trailing model dims stay
    partitioned on a two-axis mesh. ids/weights are tiny (B,) vectors
    and replicate.

    ``codec_payload=True`` declares that the delta slots carry a codec
    wire dict (``{"q","scale","zero"}`` trees, repro/codec) instead of a
    raw params tree. On a 1-D client mesh nothing changes — prefix
    shardings already cover any pytree — but the two-axis branch builds
    per-leaf specs from the params template, so the delta slots get
    payload-STRUCTURED spec trees (``codec_payload_specs``): q leaves
    keep the model layout, scale/zero vectors carry only the leading
    axis.

    Returns (wave_in, wave_out, fold_in, fold_out), each ready for
    jax.jit's in_shardings/out_shardings.
    """
    if client_axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {client_axis!r} axis")
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    two_axis = axis_sizes.get(model_axis, 1) > 1
    rep = NamedSharding(mesh, P())
    cli = NamedSharding(mesh, P(client_axis))
    if not two_axis:
        return ((rep, rep, cli, cli), (cli, cli),
                (rep, rep, rep, rep, rep), (rep, rep, rep))
    if params is None or server_state is None:
        raise ValueError(
            f"mesh carries a {model_axis!r} axis of size "
            f"{axis_sizes[model_axis]}: the two-axis async round needs "
            "params/server_state templates for per-leaf specs")
    is_spec = lambda x: isinstance(x, P)
    pspecs = cohort_param_specs(params, mesh, client_axis, model_axis)
    p_sh = to_named(pspecs, mesh)
    s_sh = to_named(cohort_state_specs(server_state, params, mesh,
                                       client_axis, model_axis), mesh)
    if codec_payload:
        # wave output / fold input carry the codec wire dict
        d_sh = to_named(codec_payload_specs(pspecs, client_axis), mesh)
        buf_sh = to_named(codec_payload_specs(pspecs, None), mesh)
    else:
        # wave deltas: client axis leading, param layout trailing
        d_sh = to_named(jax.tree.map(lambda s: P(client_axis, *s), pspecs,
                                     is_leaf=is_spec), mesh)
        # buffered deltas: leading B (arrival buffer) replicated
        buf_sh = to_named(jax.tree.map(lambda s: P(None, *s), pspecs,
                                       is_leaf=is_spec), mesh)
    return ((p_sh, s_sh, cli, cli), (d_sh, cli),
            (s_sh, p_sh, buf_sh, rep, rep), (p_sh, s_sh, rep))


