from repro.sharding.rules import (ShardingPolicy, param_specs, batch_specs,
                                  state_specs, cohort_round_shardings,
                                  cohort_param_specs, cohort_state_specs)
