"""Phi-4-mini 3.8B [arXiv:2412.08905] — dense, RoPE, SwiGLU, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", arch_type="dense", source="arXiv:2412.08905",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=200064,
    attention="gqa", use_rope=True, rope_theta=1e4,
    mlp="swiglu", norm="rmsnorm", tie_embeddings=True,
    max_seq_len=131072,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, max_seq_len=512,
)
