"""Kimi-K2 1T-A32B [arXiv:2501.kimi2] — trillion-param MoE 384e top-8 (+1 shared)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", arch_type="moe", source="arXiv:2501.kimi2",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=18432,                      # dense first layer
    vocab_size=163840,
    attention="gqa", use_rope=True, rope_theta=5e4,
    moe=True, num_experts=384, num_shared_experts=1, top_k=8,
    moe_d_ff=2048, first_dense_layers=1, moe_every=1,
    mlp="swiglu", norm="rmsnorm",
    max_seq_len=131072,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    num_experts=4, num_shared_experts=1, top_k=2, moe_d_ff=128,
    first_dense_layers=1, max_seq_len=512,
)
