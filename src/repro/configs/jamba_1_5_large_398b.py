"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention (1:7) + MoE 16e top-2.

72 layers in 9 groups of 8 (7 Mamba + 1 attention); MoE every 2nd layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid", source="arXiv:2403.19887",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    attention="gqa", use_rope=False,       # jamba: no positional encoding
    attn_every=8,                          # 1 attention per 8 layers (1:7)
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    moe=True, num_experts=16, num_shared_experts=0, top_k=2,
    moe_d_ff=24576, moe_every=2, first_dense_layers=1,
    mlp="swiglu", norm="rmsnorm",
    max_seq_len=262144,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, attn_every=2, ssm_state=8,
    num_experts=4, top_k=2, moe_d_ff=512, moe_every=2, first_dense_layers=1,
    max_seq_len=512,
)
