"""Minitron-8B [arXiv:2407.14679] — pruned Nemotron-4: GQA kv=8, RoPE, squared-ReLU MLP."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", arch_type="dense", source="arXiv:2407.14679",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    attention="gqa", use_rope=True, rope_theta=1e4,
    mlp="relu2", norm="layernorm",
    max_seq_len=4096,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, max_seq_len=512,
)
