"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01] — dense GQA kv=8, no-bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", arch_type="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    attention="gqa", use_rope=True, rope_theta=8e6,
    attn_bias=False, mlp_bias=False,
    mlp="swiglu", norm="layernorm", tie_embeddings=True,
    max_seq_len=131072,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, max_seq_len=512,
)
