"""ResNet18 + GroupNorm — the paper's CIFAR-100 / TinyImageNet model (FedDPC §5.2.1)."""
from repro.models.vision import VisionConfig

CONFIG = VisionConfig(
    name="resnet18-gn", family="resnet18",
    image_size=32, channels=3, num_classes=100,
    width=64, groups=8,
)

SMOKE = VisionConfig(
    name="resnet18-gn-smoke", family="resnet18",
    image_size=16, channels=3, num_classes=10,
    width=16, groups=4,
)
