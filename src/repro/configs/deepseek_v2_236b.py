"""DeepSeek-V2 236B [arXiv:2405.04434] — MLA (kv_lora=512) + MoE 160e top-6 + 2 shared."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", arch_type="moe", source="arXiv:2405.04434",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288,                      # dense layers (first_dense_layers)
    vocab_size=102400,
    attention="mla", use_rope=True, rope_theta=1e4,
    kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    moe=True, num_experts=160, num_shared_experts=2, top_k=6,
    moe_d_ff=1536, first_dense_layers=1, moe_every=1,
    mlp="swiglu", norm="rmsnorm",
    max_seq_len=131072,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512,
    kv_lora_rank=64, q_lora_rank=96,
    qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
    num_experts=4, num_shared_experts=1, top_k=2, moe_d_ff=128,
    first_dense_layers=1, max_seq_len=512,
)
