"""LeNet5 — the paper's CIFAR-10 model (FedDPC §5.2.1)."""
from repro.models.vision import VisionConfig

CONFIG = VisionConfig(
    name="lenet5", family="lenet5",
    image_size=32, channels=3, num_classes=10,
)

SMOKE = VisionConfig(
    name="lenet5-smoke", family="lenet5",
    image_size=32, channels=3, num_classes=10,
)
