"""Assigned input shapes.

Each shape names a step kind:
  train_4k    -> train_step      (global_batch x seq_len tokens + labels)
  prefill_32k -> serve_prefill   (build a KV cache / SSM state)
  decode_32k  -> serve_decode    (ONE new token against a seq_len cache)
  long_500k   -> serve_decode    (sub-quadratic attention required; dense
                                  archs use the sliding-window variant,
                                  SSM/hybrid decode natively — DESIGN.md §7)
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode
    long_context: bool = False


SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode", long_context=True),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]
