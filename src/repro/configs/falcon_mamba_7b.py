"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba-1, attention-free."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", arch_type="ssm", source="arXiv:2410.05355",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    attention="none", use_rope=False,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    norm="rmsnorm", mlp="swiglu",     # mlp unused: mamba block is the whole layer
    max_seq_len=1_048_576,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, vocab_size=512, ssm_state=8, max_seq_len=512,
)
