"""Architecture configuration system.

Every assigned architecture gets one ``configs/<id>.py`` exporting
``CONFIG`` (the exact assigned full-size config) and ``SMOKE`` (a reduced
variant of the same family: <=2 layers, d_model<=512, <=4 experts) used by
CPU smoke tests.  ``registry()`` maps arch-id -> module.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                     # citation for the config numbers

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention flavour
    attention: str = "gqa"               # gqa | mla | none
    rope_theta: float = 10000.0
    use_rope: bool = True
    attn_bias: bool = False
    mlp_bias: bool = False
    logit_soft_cap: float = 0.0

    # MLA (DeepSeek-V2 style latent attention)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                    # per-expert hidden dim
    first_dense_layers: int = 0          # leading dense layers before MoE stack
    moe_every: int = 1                   # MoE layer every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                 # 0 -> ceil(d_model / 16)

    # hybrid (jamba): one attention layer every `attn_every` layers, rest SSM
    attn_every: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500          # post-conv audio frames

    # modality frontend (stubbed per assignment)
    modality: str = "text"               # text | vision | audio
    num_patches: int = 0                 # vlm: image patch embeddings per example

    # misc
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    mlp: str = "swiglu"                  # swiglu | gelu | relu2
    tie_embeddings: bool = False
    max_seq_len: int = 8192
    sliding_window: int = 0              # 0 = full attention
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, (self.d_model + 15) // 16)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string: 'attn' | 'ssm' for the mixer of layer i."""
        kinds = []
        for i in range(self.num_layers):
            if self.arch_type == "ssm":
                kinds.append("ssm")
            elif self.attn_every > 0:
                # jamba: attention at position (attn_every - 1) within each group
                kinds.append("attn" if (i % self.attn_every) == (self.attn_every - 1) else "ssm")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def layer_is_moe(self, i: int) -> bool:
        if not self.moe:
            return False
        if i < self.first_dense_layers:
            return False
        return ((i - self.first_dense_layers) % self.moe_every) == 0

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (analytic, for roofline MODEL_FLOPS)
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        per_attn = 0
        if self.attention == "mla":
            qdim = self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            per_attn = (d * self.q_lora_rank + self.q_lora_rank * qdim
                        + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                        + self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                        + self.num_heads * self.v_head_dim * d)
        elif self.attention == "gqa":
            per_attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        per_dense_mlp = (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
        mult = 3 if self.mlp == "swiglu" else 2
        per_moe_mlp = ((self.num_experts + self.num_shared_experts) * mult * d * self.moe_d_ff
                       + d * self.num_experts)
        per_moe_active = ((self.top_k + self.num_shared_experts) * mult * d * self.moe_d_ff
                          + d * self.num_experts)
        d_in, st = self.ssm_d_inner, self.ssm_state
        per_ssm = (d * 2 * d_in + d_in * self.ssm_conv
                   + d_in * (self.resolved_dt_rank + 2 * st)
                   + self.resolved_dt_rank * d_in + d_in * st + d_in + d_in * d)
        total = embed + (0 if self.tie_embeddings else embed)
        active = total
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            mixer = per_ssm if kind == "ssm" else per_attn
            if self.layer_is_moe(i):
                total += mixer + per_moe_mlp
                active += mixer + per_moe_active
            else:
                total += mixer + per_dense_mlp
                active += mixer + per_dense_mlp
        if self.is_encoder_decoder:
            # encoder self-attn + mlp, decoder cross-attn
            total += self.encoder_layers * (per_attn + per_dense_mlp)
            total += self.num_layers * per_attn  # cross attention
            active = total
        return {"total": int(total), "active": int(active)}


ARCH_IDS = (
    "starcoder2-3b", "minitron-8b", "llava-next-mistral-7b", "falcon-mamba-7b",
    "phi4-mini-3.8b", "deepseek-v2-236b", "command-r-35b", "whisper-base",
    "jamba-1.5-large-398b", "kimi-k2-1t-a32b",
)

_MOD = {
    "starcoder2-3b": "starcoder2_3b",
    "minitron-8b": "minitron_8b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "command-r-35b": "command_r_35b",
    "whisper-base": "whisper_base",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    # paper-native models (vision CNNs for the faithful reproduction)
    "lenet5": "paper_lenet5",
    "resnet18-gn": "paper_resnet18",
}


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_arch_ids():
    return ARCH_IDS
