"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: the Mistral-7B language trunk consumes pre-projected anyres patch
embeddings from the (stubbed) vision tower — see DESIGN.md §7.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    attention="gqa", use_rope=True, rope_theta=1e4,
    mlp="swiglu", norm="rmsnorm",
    modality="vision", num_patches=576,   # anyres base tile = 24x24 patches
    max_seq_len=32768,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, num_patches=16, max_seq_len=512,
)
