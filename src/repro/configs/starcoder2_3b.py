"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA kv=2, RoPE, GeLU MLP w/ bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", arch_type="dense", source="arXiv:2402.19173",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    attention="gqa", use_rope=True, rope_theta=1e5,
    attn_bias=True, mlp_bias=True, mlp="gelu", norm="layernorm",
    max_seq_len=16384,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, max_seq_len=512,
)
