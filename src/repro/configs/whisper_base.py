"""Whisper-base [arXiv:2212.04356] — encoder-decoder; conv/mel frontend STUBBED.

The assignment exercises the transformer backbone only: ``input_specs()``
feeds precomputed post-conv frame embeddings (B, 1500, 512).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", arch_type="audio", source="arXiv:2212.04356",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    attention="gqa", use_rope=False,     # whisper uses learned/sinusoidal pos
    attn_bias=True, mlp_bias=True,
    is_encoder_decoder=True, encoder_layers=6, encoder_seq_len=1500,
    modality="audio",
    mlp="gelu", norm="layernorm",
    max_seq_len=448,
)

SMOKE = CONFIG.with_(
    num_layers=2, encoder_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, encoder_seq_len=64, max_seq_len=128,
)
