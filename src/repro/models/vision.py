"""The paper's own models: LeNet5 (CIFAR-10) and ResNet18 + GroupNorm
(CIFAR-100 / TinyImageNet), in pure JAX (NHWC).

These are what the faithful FedDPC reproduction trains (FedDPC §5.2.1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import group_norm, init_group_norm


@dataclass(frozen=True)
class VisionConfig:
    name: str
    family: str                 # lenet5 | resnet18
    image_size: int = 32
    channels: int = 3
    num_classes: int = 10
    width: int = 64             # resnet stem width
    groups: int = 8             # groupnorm groups
    arch_type: str = "vision"


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * math.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------- LeNet5 ----------------

def init_lenet5(cfg: VisionConfig, key):
    ks = jax.random.split(key, 5)
    flat = ((cfg.image_size // 4 - 3) ** 2) * 16    # 32 -> 5*5*16 = 400
    return {
        "c1": _conv_init(ks[0], 5, 5, cfg.channels, 6),
        "c2": _conv_init(ks[1], 5, 5, 6, 16),
        "f1": {"w": jax.random.normal(ks[2], (flat, 120)) * math.sqrt(2.0 / flat),
               "b": jnp.zeros(120)},
        "f2": {"w": jax.random.normal(ks[3], (120, 84)) * math.sqrt(2.0 / 120),
               "b": jnp.zeros(84)},
        "f3": {"w": jax.random.normal(ks[4], (84, cfg.num_classes)) * math.sqrt(2.0 / 84),
               "b": jnp.zeros(cfg.num_classes)},
    }


def lenet5_forward(cfg: VisionConfig, p, x):
    """x: (B, H, W, C) -> logits (B, classes)."""
    h = jax.nn.relu(_conv(x, p["c1"], padding="VALID"))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(_conv(h, p["c2"], padding="VALID"))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["f1"]["w"] + p["f1"]["b"])
    h = jax.nn.relu(h @ p["f2"]["w"] + p["f2"]["b"])
    return h @ p["f3"]["w"] + p["f3"]["b"]


# ---------------- ResNet18 + GroupNorm ----------------

def _init_block(key, cin, cout, stride, groups):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, 3, cin, cout),
        "gn1": init_group_norm(groups, cout),
        "conv2": _conv_init(ks[1], 3, 3, cout, cout),
        "gn2": init_group_norm(groups, cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
        p["gn_proj"] = init_group_norm(groups, cout)
    return p


def _block(p, x, stride, groups):
    h = _conv(x, p["conv1"], stride)
    h = jax.nn.relu(group_norm(p["gn1"], h, groups))
    h = _conv(h, p["conv2"], 1)
    h = group_norm(p["gn2"], h, groups)
    if "proj" in p:
        x = group_norm(p["gn_proj"], _conv(x, p["proj"], stride), groups)
    return jax.nn.relu(h + x)


def init_resnet18(cfg: VisionConfig, key):
    w = cfg.width
    ks = jax.random.split(key, 10)
    widths = [w, w, 2 * w, 2 * w, 4 * w, 4 * w, 8 * w, 8 * w]
    strides = [1, 1, 2, 1, 2, 1, 2, 1]
    blocks = []
    cin = w
    for i in range(8):
        blocks.append(_init_block(ks[1 + i], cin, widths[i], strides[i], cfg.groups))
        cin = widths[i]
    return {
        "stem": _conv_init(ks[0], 3, 3, cfg.channels, w),
        "gn_stem": init_group_norm(cfg.groups, w),
        "blocks": blocks,
        "head": {"w": jax.random.normal(ks[9], (8 * w, cfg.num_classes))
                 * math.sqrt(2.0 / (8 * w)),
                 "b": jnp.zeros(cfg.num_classes)},
    }


def resnet18_forward(cfg: VisionConfig, p, x):
    strides = [1, 1, 2, 1, 2, 1, 2, 1]
    h = jax.nn.relu(group_norm(p["gn_stem"], _conv(x, p["stem"], 1), cfg.groups))
    for bp, s in zip(p["blocks"], strides):
        h = _block(bp, h, s, cfg.groups)
    h = h.mean(axis=(1, 2))
    return h @ p["head"]["w"] + p["head"]["b"]


# ---------------- unified ----------------

def init_vision(cfg: VisionConfig, key):
    if cfg.family == "lenet5":
        return init_lenet5(cfg, key)
    return init_resnet18(cfg, key)


def vision_forward(cfg: VisionConfig, p, x):
    if cfg.family == "lenet5":
        return lenet5_forward(cfg, p, x)
    return resnet18_forward(cfg, p, x)


def vision_loss_fn(cfg: VisionConfig, p, batch):
    """batch: {images (B,H,W,C), labels (B,)} -> mean CE (paper §5.2.2)."""
    logits = vision_forward(cfg, p, batch["images"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


def vision_accuracy(cfg: VisionConfig, p, images, labels):
    pred = jnp.argmax(vision_forward(cfg, p, images), axis=-1)
    return (pred == labels).mean()
