"""Mixture-of-Experts layer: top-k router + capacity-slotted gather/scatter
dispatch.

Design notes (DESIGN.md §2, §8):

* One-hot einsum dispatch (GShard style) costs O(T * E * C * D) FLOPs —
  quadratic in group token count. For the 384-expert Kimi-K2 config that
  would exceed the model's real FLOPs by >10x and poison the roofline.
  Instead we build an explicit slot table (cumsum-over-one-hot for
  positions, batched scatter for ``slot -> token``), then dispatch with a
  *gather* and combine with a *scatter-add*: zero matmul FLOPs, O(E*C*D)
  bytes moved.

* Tokens are processed in G groups (G = number of token shards on the
  mesh). The gather/scatter is batched over G so it stays device-local
  under GSPMD; the reshard of the dispatched activations from
  group-sharding to expert-sharding is what becomes the expert-parallel
  all-to-all. Capacity is per (group, expert): C = ceil(T_g*k/E*cf),
  floored at ``min_capacity``.

* Shared experts (DeepSeek-V2 / Kimi-K2) run densely on every token.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear

MIN_CAPACITY = 4


def init_moe(key, cfg, dtype):
    d, dff = cfg.d_model, cfg.moe_d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": init_linear(ks[0], d, e, jnp.float32),
        "gate": (jax.random.normal(ks[1], (e, d, dff)) * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, dff)) * scale).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, dff, d)) / math.sqrt(dff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = {
            "gate": init_linear(jax.random.fold_in(ks[4], 0), d,
                                cfg.num_shared_experts * dff, dtype),
            "up": init_linear(jax.random.fold_in(ks[4], 1), d,
                              cfg.num_shared_experts * dff, dtype),
            "down": init_linear(jax.random.fold_in(ks[4], 2),
                                cfg.num_shared_experts * dff, d, dtype),
        }
    return p


def capacity_per_group(cfg, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    c = max(c, MIN_CAPACITY)
    # round up to a TPU-friendly multiple (also keeps C shardable by the
    # model axis when C is large)
    mult = 128 if c >= 128 else 8
    return ((c + mult - 1) // mult) * mult


def _route(cfg, logits):
    """logits: (G, T, E) f32 -> gates (G, T, k), idx (G, T, k), aux scalar."""
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss, averaged over groups. Assignment
    # counts via scatter-add (bincount), NOT one-hot: one-hot would be
    # O(T*k*E) memory (terabytes for the 1M-token x 384-expert shapes).
    e = cfg.num_experts
    g, t, k = idx.shape
    me = probs.mean(axis=1)                                     # (G, E)
    flat_e = idx.reshape(g, t * k)

    def counts_one(fe):
        return jnp.zeros((e,), jnp.float32).at[fe].add(1.0)

    ce = jax.vmap(counts_one)(flat_e)                           # (G, E)
    ce = ce / jnp.maximum(ce.sum(-1, keepdims=True), 1.0)
    aux = (e * (me * ce).sum(-1)).mean()
    return gates, idx, aux


def _slot_tables(cfg, idx, gates, cap):
    """Build slot->token and slot->gate tables per group.

    idx/gates: (G, T, k). Returns slot_token (G, E*C) int32 in [0, T]
    (T = dummy/empty) and slot_gate (G, E*C) f32.

    Position-within-expert is computed with a SORT-based rank (stable
    argsort over expert ids, rank = index - segment start), which is
    O(T*k log) compute and O(T*k) memory — the cumsum-over-one-hot
    alternative is O(T*k*E) memory and unusable at 384 experts x 1M
    tokens. Sorts are per-group, so with groups == token shards they
    stay device-local under GSPMD.
    """
    g, t, k = idx.shape
    e = cfg.num_experts
    tk = t * k
    flat_e = idx.reshape(g, tk)                 # expert of each assignment

    def pos_one(fe):
        order = jnp.argsort(fe, stable=True)                    # (Tk,)
        counts = jnp.zeros((e,), jnp.int32).at[fe].add(1)
        starts = jnp.cumsum(counts) - counts                    # (E,)
        sorted_e = fe[order]
        rank_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[sorted_e]
        return jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)

    pos = jax.vmap(pos_one)(flat_e)                             # (G, Tk)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)         # dropped -> dummy slot
    token_of_assign = jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[None, :, None], (g, t, k)).reshape(g, tk)

    def scatter_one(slots_g, tok_g, gate_g):
        st = jnp.full((e * cap + 1,), t, jnp.int32).at[slots_g].set(tok_g)
        sg = jnp.zeros((e * cap + 1,), jnp.float32).at[slots_g].set(gate_g)
        return st[:-1], sg[:-1]

    slot_token, slot_gate = jax.vmap(scatter_one)(
        slot, token_of_assign, gates.reshape(g, tk))
    return slot_token, slot_gate


def moe_forward(cfg, p, x, *, groups: int = 1,
                shard_fn: Optional[Callable] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    groups: token groups for dispatch locality (set to the number of token
    shards on the mesh). shard_fn(tensor, role) applies sharding
    constraints; roles: "dispatched" (G,E,C,D) pre-FFN, "expert_hidden".
    """
    b, s, d = x.shape
    t_total = b * s
    g = groups if t_total % groups == 0 else 1
    tg = t_total // g
    shard_fn = shard_fn or (lambda z, role: z)

    xt = x.reshape(g, tg, d)
    logits = linear(p["router"], xt.astype(jnp.float32))
    gates, idx, aux = _route(cfg, logits)

    cap = capacity_per_group(cfg, tg)
    slot_token, slot_gate = _slot_tables(cfg, idx, gates, cap)  # (G, E*C)

    # dispatch: local batched gather (dummy token T -> zeros via padded row)
    xt_pad = jnp.concatenate([xt, jnp.zeros((g, 1, d), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(xt_pad, slot_token[..., None], axis=1)  # (G, E*C, D)
    xe = xe.reshape(g, cfg.num_experts, cap, d)
    xe = shard_fn(xe, "dispatched")         # reshard G->experts = all-to-all

    h = jnp.einsum("gecd,edf->gecf", xe, p["gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["up"])
    h = shard_fn(h, "expert_hidden")
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"])              # (G, E, C, D)
    ye = ye * slot_gate.reshape(g, cfg.num_experts, cap, 1).astype(ye.dtype)
    ye = shard_fn(ye.reshape(g, cfg.num_experts * cap, d), "combine")

    # combine: local batched scatter-add back to token order
    def combine_one(y_g, st_g):
        return jnp.zeros((tg + 1, d), y_g.dtype).at[st_g].add(y_g)[:-1]

    out = jax.vmap(combine_one)(ye, slot_token)                  # (G, T_g, D)
    out = out.reshape(b, s, d)

    if cfg.num_shared_experts:
        sh = p["shared"]
        xs = x.reshape(t_total, d)
        hs = jax.nn.silu(linear(sh["gate"], xs)) * linear(sh["up"], xs)
        out = out + linear(sh["down"], hs).reshape(b, s, d)

    return out, cfg.router_aux_coef * aux
