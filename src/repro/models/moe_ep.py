"""Expert-parallel MoE FFN via shard_map — explicit all-to-all dispatch.

WHY (EXPERIMENTS.md §Perf, kimi-k2 x train_4k): under plain GSPMD the
capacity-slotted dispatch is a dynamic gather from the token-sharded
activations into the expert-sharded slot table. The partitioner cannot
prove locality of the gather indices, so it REPLICATES the dispatched
tensor (384 experts x 27k slots x 7168 = 150 GB per MoE layer) to every
device — 8.7 TB/device of all-gather per training step for kimi-k2.

This module is the classic DeepSpeed-MoE / MaxText pattern written with
shard_map + jax.lax collectives, the TPU-native translation of the GPU
NCCL all-to-all (DESIGN.md hardware-adaptation):

  * tokens are sharded over the `expert_axis` (= data); experts are
    sharded over the SAME axis: shard s owns experts [s*E/S, (s+1)*E/S)
  * each shard routes its local tokens, packs a fixed-capacity send
    buffer bucketed by destination shard, and exchanges it with ONE
    ppermute-free `lax.all_to_all`
  * expert FFN runs locally; the expert ffn dim is column-sharded over
    `model` with a psum for the down-projection (Megatron style)
  * a reverse all_to_all returns expert outputs; a local scatter-add
    combines them.

Collective volume per layer per device: 2 x (S x C_send x D) ~ the ideal
token movement, instead of the full dispatched tensor. Same math as
models/moe.py (validated equal in tests/test_moe_ep.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import linear
from repro.models.moe import MIN_CAPACITY, _route


def _send_capacity(cfg, tokens_per_shard: int, num_shards: int) -> int:
    """Capacity of the (src shard -> dst shard) bucket."""
    c = int(math.ceil(tokens_per_shard * cfg.top_k / num_shards
                      * cfg.capacity_factor))
    c = max(c, MIN_CAPACITY)
    mult = 128 if c >= 128 else 8
    return ((c + mult - 1) // mult) * mult


def moe_forward_ep(cfg, p, x, *, mesh: Mesh, expert_axis=None,
                   model_axis: str = "model"):
    """x: (B, S, D) sharded batch-over-expert_axis. Returns (out, aux).

    Expert weight layout expected (sharding/rules.py `expert_axis` rules):
      gate/up: (E, D, F) with E over expert_axis, F over model_axis
      down:    (E, F, D) with E over expert_axis, F over model_axis
    """
    b, s, d = x.shape
    if expert_axis is None:
        # span every non-model axis (pod AND data on the multi-pod mesh)
        axes = tuple(a for a in mesh.axis_names if a != model_axis)
        expert_axis = axes if len(axes) > 1 else axes[0]
    ax_list = expert_axis if isinstance(expert_axis, tuple) else (expert_axis,)
    n_shards = int(np.prod([mesh.shape[a] for a in ax_list]))
    e_total = cfg.num_experts
    e_local = e_total // n_shards
    assert e_total % n_shards == 0, (e_total, n_shards)
    t_total = b * s
    assert t_total % n_shards == 0
    t_local = t_total // n_shards
    cap = _send_capacity(cfg, t_local, n_shards)

    def local_block(xt, router_w, gate_w, up_w, down_w):
        """Per-(expert_axis x model_axis) shard body.
        xt: (T_l, D) local tokens; gate_w: (E_l, D, F_l) local experts with
        the model-axis column slice of the ffn dim."""
        tl = xt.shape[0]
        logits = (xt.astype(jnp.float32) @ router_w)          # (T_l, E)
        gates, idx, aux = _route(cfg, logits[None])           # add group dim
        gates, idx = gates[0], idx[0]                         # (T_l, k)
        aux = jax.lax.pmean(aux, expert_axis)

        flat_e = idx.reshape(-1)                              # (T_l*k,)
        dest = flat_e // e_local                              # shard of expert
        # rank within destination bucket (sort-based, O(Tk log Tk))
        tk = flat_e.shape[0]
        order = jnp.argsort(dest, stable=True)
        counts = jnp.zeros((n_shards,), jnp.int32).at[dest].add(1)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[dest[order]]
        rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)
        keep = rank < cap
        slot = jnp.where(keep, dest * cap + rank, n_shards * cap)

        token_of = jnp.broadcast_to(
            jnp.arange(tl, dtype=jnp.int32)[:, None],
            (tl, cfg.top_k)).reshape(-1)
        # send buffers: tokens, (local expert id, gate) metadata
        send_tok = jnp.full((n_shards * cap + 1,), tl, jnp.int32
                            ).at[slot].set(token_of)[:-1]
        send_exp = jnp.zeros((n_shards * cap + 1,), jnp.int32
                             ).at[slot].set(flat_e % e_local)[:-1]
        send_gate = jnp.zeros((n_shards * cap + 1,), jnp.float32
                              ).at[slot].set(gates.reshape(-1))[:-1]
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
        send_x = xt_pad[send_tok]                             # (S*C, D)

        # exchange: (n_shards, C, D) -> recv (n_shards, C, D)
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_shards, cap, d), expert_axis, 0, 0, tiled=False)
        recv_exp = jax.lax.all_to_all(
            send_exp.reshape(n_shards, cap), expert_axis, 0, 0)
        recv_valid = jax.lax.all_to_all(
            (send_tok < tl).reshape(n_shards, cap), expert_axis, 0, 0)

        # local expert FFN: gather per-slot expert weights via one-hot-free
        # take (E_l is small per shard)
        rx = recv_x.reshape(n_shards * cap, d)
        rexp = recv_exp.reshape(-1)
        rvalid = recv_valid.reshape(-1)
        # (T_r, D) x (E_l, D, F_l): batched by expert id via segment matmul:
        # sort by expert, run dense per-expert matmul with fixed capacity
        per_e_cap = n_shards * cap // e_local if e_local else 0
        per_e_cap = max(per_e_cap, MIN_CAPACITY)
        mult = 128 if per_e_cap >= 128 else 8
        per_e_cap = ((per_e_cap + mult - 1) // mult) * mult
        # sort key sends INVALID slots to a sentinel segment (e_local) so
        # they never pollute a real expert's rank sequence
        key2 = jnp.where(rvalid, rexp, e_local)
        order2 = jnp.argsort(key2, stable=True)
        counts2 = jnp.zeros((e_local + 1,), jnp.int32).at[key2].add(1)
        starts2 = jnp.cumsum(counts2) - counts2
        rank2_sorted = (jnp.arange(rexp.shape[0], dtype=jnp.int32)
                        - starts2[key2[order2]])
        rank2 = jnp.zeros_like(rank2_sorted).at[order2].set(rank2_sorted)
        keep2 = (rank2 < per_e_cap) & rvalid
        slot2 = jnp.where(keep2, rexp * per_e_cap + rank2,
                          e_local * per_e_cap)
        tbl = jnp.full((e_local * per_e_cap + 1,), rexp.shape[0], jnp.int32
                       ).at[slot2].set(jnp.arange(rexp.shape[0],
                                                  dtype=jnp.int32))[:-1]
        rx_pad = jnp.concatenate([rx, jnp.zeros((1, d), rx.dtype)], 0)
        xe = rx_pad[tbl].reshape(e_local, per_e_cap, d)

        h = jnp.einsum("ecd,edf->ecf", xe, gate_w)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, up_w)
        ye = jnp.einsum("ecf,efd->ecd", h, down_w,
                        preferred_element_type=jnp.float32)
        # NOTE: ye is PARTIAL over the model axis (ffn dim is column-
        # sharded). The psum is DEFERRED to after the token combine —
        # psum'ing here costs E_l x C x D per layer (18.8 GB for kimi);
        # after combine it is T_l x D (1.9 GB): 10x less all-reduce
        # volume, and the reverse all-to-all carries bf16 partials.
        ye = ye.astype(xt.dtype)

        # un-sort back to recv slot order, reverse all_to_all
        ye_flat = ye.reshape(e_local * per_e_cap, d)
        ye_pad = jnp.concatenate([ye_flat, jnp.zeros((1, d), ye_flat.dtype)],
                                 0)
        back = ye_pad[jnp.where(keep2, slot2, e_local * per_e_cap)]
        back = jnp.where(keep2[:, None], back, 0.0)
        ret = jax.lax.all_to_all(
            back.reshape(n_shards, cap, d), expert_axis, 0, 0)

        # combine locally with gates (f32 accumulate), THEN one token-level
        # psum over model completes the row-parallel down projection
        ret_flat = ret.reshape(n_shards * cap, d).astype(jnp.float32)
        weighted = ret_flat * send_gate[:, None]
        out = jnp.zeros((tl + 1, d), jnp.float32
                        ).at[send_tok].add(weighted)[:-1]
        out = jax.lax.psum(out.astype(xt.dtype), model_axis)
        return out, aux

    xt = x.reshape(t_total, d)
    spec_tok = P(expert_axis, None)
    out, aux = shard_map(
        local_block, mesh=mesh,
        in_specs=(spec_tok, P(), P(expert_axis, None, model_axis),
                  P(expert_axis, None, model_axis),
                  P(expert_axis, model_axis, None)),
        out_specs=(spec_tok, P()),
        check_rep=False,
    )(xt, p["router"]["w"], p["gate"], p["up"], p["down"])
    out = out.reshape(b, s, d)

    if cfg.num_shared_experts:
        sh = p["shared"]
        xs = x.reshape(t_total, d)
        hs = jax.nn.silu(linear(sh["gate"], xs)) * linear(sh["up"], xs)
        out = out + linear(sh["down"], hs).reshape(b, s, d)
    return out, cfg.router_aux_coef * aux
