"""Whisper-style encoder-decoder backbone.

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
is STUBBED: the model consumes precomputed frame embeddings
(B, encoder_seq_len, d_model) from frontend.audio_frame_stub / input_specs.

Encoder: bidirectional self-attention + MLP, sinusoidal positions.
Decoder: causal self-attention (cached) + cross-attention over the encoder
output + MLP. Cross K/V are computed once at prefill and carried in the
serve state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import (apply_mlp, apply_norm, init_linear, init_mlp,
                                 init_norm, linear, sinusoidal_positions)


def _init_xattn(key, cfg, dtype):
    return attn_mod.init_gqa(key, cfg, dtype)


def init_encdec(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn_mod.init_gqa(k1, cfg, dtype),
            "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": init_mlp(k2, cfg.mlp, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_bias),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
            "self_attn": attn_mod.init_gqa(k1, cfg, dtype),
            "norm_x": init_norm(cfg.norm, cfg.d_model, dtype),
            "cross_attn": _init_xattn(k2, cfg, dtype),
            "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": init_mlp(k3, cfg.mlp, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_bias),
        }

    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02
                  ).astype(dtype),
        "encoder": jax.vmap(enc_layer)(jax.random.split(ks[1], cfg.encoder_layers)),
        "enc_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "decoder": jax.vmap(dec_layer)(jax.random.split(ks[2], cfg.num_layers)),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "lm_head": init_linear(ks[3], cfg.d_model, cfg.vocab_size, dtype),
    }


def encode(cfg, params, frames, attn_impl="auto", scan_unroll=1):
    """frames: (B, T_enc, D) stubbed conv output -> (B, T_enc, D)."""
    attn_unroll = True if scan_unroll not in (1, False) else 1
    b, t, d = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = frames + sinusoidal_positions(t, d).astype(frames.dtype)[None]

    def body(xc, lp):
        h = apply_norm(cfg.norm, lp["norm1"], xc)
        # encoder is bidirectional: call sdpa directly with permissive
        # query positions (q_pos = t) so the causal mask passes everywhere
        full = jnp.full_like(pos, t)
        hd = cfg.resolved_head_dim
        q = linear(lp["attn"]["wq"], h).reshape(b, t, cfg.num_heads, hd)
        k = linear(lp["attn"]["wk"], h).reshape(b, t, cfg.num_kv_heads, hd)
        v = linear(lp["attn"]["wv"], h).reshape(b, t, cfg.num_kv_heads, hd)
        o = attn_mod.sdpa(q, k, v, full, pos, impl=attn_impl,
                          unroll=attn_unroll)
        xc = xc + linear(lp["attn"]["wo"], o.reshape(b, t, cfg.num_heads * hd))
        h2 = apply_norm(cfg.norm, lp["norm2"], xc)
        return xc + apply_mlp(cfg.mlp, lp["mlp"], h2), None

    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=scan_unroll)
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _cross_attention(cfg, lp, x, enc_out, attn_impl, unroll=1):
    b, s, _ = x.shape
    t = enc_out.shape[1]
    hd = cfg.resolved_head_dim
    q = linear(lp["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = linear(lp["wk"], enc_out).reshape(b, t, cfg.num_kv_heads, hd)
    v = linear(lp["wv"], enc_out).reshape(b, t, cfg.num_kv_heads, hd)
    q_pos = jnp.full((b, s), t, jnp.int32)          # everything visible
    k_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    o = attn_mod.sdpa(q, k, v, q_pos, k_pos, impl=attn_impl, unroll=unroll)
    return linear(lp["wo"], o.reshape(b, s, cfg.num_heads * hd))


def decode(cfg, params, tokens, enc_out, positions=None, *, states=None,
           window: int = 0, attn_impl="auto", scan_unroll=1):
    """tokens: (B, S); enc_out: (B, T_enc, D). states: stacked self-attn KV
    caches (None for teacher-forced training). Returns (logits, new_states).
    """
    b, s = tokens.shape
    d = cfg.d_model
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    attn_unroll = True if scan_unroll not in (1, False) else 1
    x = params["embed"][tokens]
    # sinusoidal decoder positions (tables would not scale to the 32k/500k
    # cache-capacity stress shapes; whisper's learned table is 448)
    half = d // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = x + pe.astype(x.dtype)

    def body(carry, xs):
        xc = carry
        lp, st = xs
        h = apply_norm(cfg.norm, lp["norm1"], xc)
        att, nst = attn_mod.gqa_forward(cfg, lp["self_attn"], h, positions,
                                        window=window, cache=st, impl=attn_impl,
                                        unroll=attn_unroll)
        xc = xc + att
        hx = apply_norm(cfg.norm, lp["norm_x"], xc)
        xc = xc + _cross_attention(cfg, lp["cross_attn"], hx, enc_out,
                                   attn_impl, attn_unroll)
        h2 = apply_norm(cfg.norm, lp["norm2"], xc)
        xc = xc + apply_mlp(cfg.mlp, lp["mlp"], h2)
        return xc, nst

    if states is None:
        dummy = jnp.zeros((cfg.num_layers,), jnp.float32)

        def body_nostate(xc, lp):
            out, _ = body(xc, (lp, None))
            return out, None

        x, _ = jax.lax.scan(body_nostate, x, params["decoder"],
                            unroll=scan_unroll)
        new_states = None
    else:
        x, new_states = jax.lax.scan(body, x, (params["decoder"], states),
                                     unroll=scan_unroll)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    return linear(params["lm_head"], x), new_states


def init_decoder_states(cfg, batch, capacity, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)

    def one(_):
        return attn_mod.init_kv_cache(cfg, batch, capacity, dtype)

    return jax.vmap(one)(jnp.arange(cfg.num_layers))


def encdec_loss_fn(cfg, params, batch, attn_impl="auto", scan_unroll=1):
    enc_out = encode(cfg, params, batch["frames"], attn_impl, scan_unroll)
    logits, _ = decode(cfg, params, batch["tokens"], enc_out,
                       attn_impl=attn_impl, scan_unroll=scan_unroll)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.where(valid, logz - gold, 0.0)
    return ce.sum() / jnp.maximum(valid.sum(), 1)
