"""Shared layer primitives (pure-functional: init_* return param pytrees,
apply functions are stateless)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False, scale=None):
    p = {"w": _dense_init(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------- norms ----------------

def init_norm(kind, d, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(kind, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def init_group_norm(groups, channels, dtype=jnp.float32):
    return {"scale": jnp.ones((channels,), dtype), "bias": jnp.zeros((channels,), dtype)}


def group_norm(p, x, groups, eps=1e-5):
    # x: (B, H, W, C)
    b, h, w, c = x.shape
    xf = x.astype(jnp.float32).reshape(b, h, w, groups, c // groups)
    mean = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(b, h, w, c)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------- MLP ----------------

def init_mlp(key, cfg_mlp: str, d_model: int, d_ff: int, dtype, bias=False):
    ks = jax.random.split(key, 3)
    if cfg_mlp == "swiglu":
        return {
            "gate": init_linear(ks[0], d_model, d_ff, dtype, bias),
            "up": init_linear(ks[1], d_model, d_ff, dtype, bias),
            "down": init_linear(ks[2], d_ff, d_model, dtype, bias),
        }
    return {
        "up": init_linear(ks[0], d_model, d_ff, dtype, bias),
        "down": init_linear(ks[1], d_ff, d_model, dtype, bias),
    }


def apply_mlp(cfg_mlp: str, p, x):
    if cfg_mlp == "swiglu":
        return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
    h = linear(p["up"], x)
    if cfg_mlp == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return linear(p["down"], h)


# ---------------- RoPE ----------------

def rope_frequencies(head_dim: int, theta: float, positions: jnp.ndarray):
    """positions: (..., S) int32 -> cos/sin (..., S, head_dim/2) f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos_ - xf2 * sin_, xf2 * cos_ + xf1 * sin_], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(max_len: int, d_model: int):
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((max_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe
