"""Decoder-only transformer stack built from an ArchConfig.

Supports every assigned family: dense GQA, MLA, MoE, pure-SSM, hybrid
(jamba 1:7 attn:mamba interleave), and the VLM variant (prefix patch
embeddings from the stubbed frontend).

Layer-stack compilation strategy (DESIGN.md §7): the per-layer spec
(mixer kind, MoE?) is analysed into (prefix_layers, period P, groups G)
and the periodic part is executed with ``lax.scan`` over G stacked groups
— one compiled body regardless of depth, keeping 512-device dry-run HLO
small. Examples: dense -> P=1; deepseek/kimi -> 1 dense prefix + P=1 MoE
scan; jamba -> P=8 (7 mamba + 1 attn, MoE every other layer), G=9.

Modes:
  train   full-sequence forward, no cache, returns logits for CE loss
  prefill full-sequence forward writing caches/states
  decode  S==1 step against caches/states
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_norm, init_linear, init_mlp, init_norm,
                                 apply_mlp, linear, sinusoidal_positions)

PyTree = Any


# ---------------- stack plan ----------------

def layer_specs(cfg) -> Tuple[Tuple[str, bool], ...]:
    kinds = cfg.layer_kinds()
    return tuple((kinds[i], cfg.layer_is_moe(i)) for i in range(cfg.num_layers))


def stack_plan(cfg) -> Tuple[int, int, int]:
    """-> (prefix_layers, period, groups) with prefix + period*groups == L."""
    specs = layer_specs(cfg)
    n = len(specs)
    for prefix in range(0, n):
        rest = specs[prefix:]
        if not rest:
            break
        for period in range(1, min(len(rest), 16) + 1):
            if len(rest) % period:
                continue
            if all(rest[i] == rest[i % period] for i in range(len(rest))):
                return prefix, period, len(rest) // period
    return n, 0, 0          # fully heterogeneous: all layers in prefix


# ---------------- single layer ----------------

def _init_layer(key, cfg, spec, dtype):
    kind, is_moe = spec
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind == "attn":
        p["mixer"] = attn_mod.init_attention(ks[0], cfg, dtype)
    else:
        p["mixer"] = ssm_mod.init_mamba(ks[0], cfg, dtype)
    if cfg.arch_type != "ssm":          # pure-SSM archs: mamba block IS the layer
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if is_moe:
            p["mlp"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.mlp, cfg.d_model, cfg.d_ff, dtype,
                                cfg.mlp_bias)
    return p


def _layer_forward(cfg, spec, p, x, positions, state, *, window, attn_impl,
                   moe_groups, shard_fn, attn_unroll=1, moe_impl="gshard",
                   moe_mesh=None):
    kind, is_moe = spec
    h = apply_norm(cfg.norm, p["norm1"], x)
    if kind == "attn":
        mixed, new_state = attn_mod.attention_forward(
            cfg, p["mixer"], h, positions, window=window, cache=state,
            impl=attn_impl, unroll=attn_unroll)
    else:
        mixed, new_state = ssm_mod.mamba_forward(cfg, p["mixer"], h, state=state)
    x = x + mixed
    aux = jnp.zeros((), jnp.float32)
    if cfg.arch_type != "ssm":
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if is_moe:
            if moe_impl == "ep":
                from repro.models import moe_ep
                out, aux = moe_ep.moe_forward_ep(cfg, p["mlp"], h2,
                                                 mesh=moe_mesh)
            else:
                out, aux = moe_mod.moe_forward(cfg, p["mlp"], h2,
                                               groups=moe_groups,
                                               shard_fn=shard_fn)
        else:
            out = apply_mlp(cfg.mlp, p["mlp"], h2)
        x = x + out
    return x, new_state, aux


def _init_layer_state(cfg, spec, batch, capacity, dtype):
    kind, _ = spec
    if kind == "attn":
        return attn_mod.init_cache(cfg, batch, capacity, dtype)
    return ssm_mod.init_ssm_state(cfg, batch, dtype)


# ---------------- full model ----------------

def init_lm(cfg, key, dtype=None) -> PyTree:
    dtype = dtype or jnp.dtype(cfg.dtype)
    specs = layer_specs(cfg)
    prefix, period, groups = stack_plan(cfg)
    k_embed, k_prefix, k_stack, k_head = jax.random.split(key, 4)

    params: Dict[str, PyTree] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype)

    params["prefix_layers"] = tuple(
        _init_layer(jax.random.fold_in(k_prefix, i), cfg, specs[i], dtype)
        for i in range(prefix))

    if groups:
        def init_group(gkey):
            return tuple(
                _init_layer(jax.random.fold_in(gkey, j), cfg,
                            specs[prefix + j], dtype)
                for j in range(period))
        params["stack"] = jax.vmap(init_group)(
            jax.random.split(k_stack, groups))
    else:
        params["stack"] = ()
    return params


def init_states(cfg, batch, capacity, dtype=None) -> PyTree:
    """Stacked caches/states matching the stack plan (for prefill/decode)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    specs = layer_specs(cfg)
    prefix, period, groups = stack_plan(cfg)
    prefix_states = tuple(
        _init_layer_state(cfg, specs[i], batch, capacity, dtype)
        for i in range(prefix))
    if groups:
        def one_group(_):
            return tuple(
                _init_layer_state(cfg, specs[prefix + j], batch, capacity, dtype)
                for j in range(period))
        stack_states = jax.vmap(one_group)(jnp.arange(groups))
    else:
        stack_states = ()
    return {"prefix": prefix_states, "stack": stack_states}


def _embed_inputs(cfg, params, tokens, embeds):
    x = params["embed"][tokens]
    if cfg.modality == "vision" and embeds is not None:
        # VLM: stubbed vision tower supplies pre-projected patch embeddings,
        # prepended to the text tokens (anyres tiles flattened).
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def lm_forward(cfg, params, tokens, positions=None, *, embeds=None,
               states: Optional[PyTree] = None, window: int = 0,
               attn_impl: str = "auto", moe_groups: int = 1,
               shard_fn: Optional[Callable] = None,
               remat: str = "none", logits_slice_last: bool = False,
               scan_unroll: int = 1, moe_impl: str = "gshard",
               moe_mesh=None):
    """Returns (logits, new_states, aux_loss).

    tokens: (B, S) int32. embeds: (B, P, D) for VLM. states: from
    init_states (prefill fills them; decode S==1 steps them).
    """
    specs = layer_specs(cfg)
    prefix, period, groups = stack_plan(cfg)
    attn_unroll = True if scan_unroll not in (1, False) else 1
    x = _embed_inputs(cfg, params, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    aux_total = jnp.zeros((), jnp.float32)
    new_prefix_states = []
    for i in range(prefix):
        st = states["prefix"][i] if states is not None else None
        x, nst, aux = _layer_forward(cfg, specs[i], params["prefix_layers"][i],
                                     x, positions, st, window=window,
                                     attn_impl=attn_impl, moe_groups=moe_groups,
                                     shard_fn=shard_fn,
                                     attn_unroll=attn_unroll,
                                     moe_impl=moe_impl, moe_mesh=moe_mesh)
        new_prefix_states.append(nst)
        aux_total = aux_total + aux

    if groups:
        def group_apply(xc, auxc, gparams, gstates):
            new_gstates = []
            for j in range(period):
                st = gstates[j] if gstates is not None else None
                xc, nst, aux = _layer_forward(
                    cfg, specs[prefix + j], gparams[j], xc, positions, st,
                    window=window, attn_impl=attn_impl, moe_groups=moe_groups,
                    shard_fn=shard_fn, attn_unroll=attn_unroll,
                    moe_impl=moe_impl, moe_mesh=moe_mesh)
                new_gstates.append(nst)
                auxc = auxc + aux
            return xc, auxc, tuple(new_gstates)

        def _maybe_remat(fn):
            if remat == "full":
                return jax.checkpoint(fn)
            if remat == "dots":
                return jax.checkpoint(
                    fn,
                    policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
            return fn

        gstates_in = states["stack"] if states is not None else None
        if gstates_in is None:
            @_maybe_remat
            def body(carry, gparams):
                xc, auxc, _ = group_apply(carry[0], carry[1], gparams, None)
                return (xc, auxc), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             params["stack"],
                                             unroll=scan_unroll)
        else:
            @_maybe_remat
            def body(carry, xs):
                gparams, gstates = xs
                xc, auxc, new_gstates = group_apply(carry[0], carry[1],
                                                    gparams, gstates)
                return (xc, auxc), new_gstates

            (x, aux_total), new_stack_states = jax.lax.scan(
                body, (x, aux_total), (params["stack"], gstates_in),
                unroll=scan_unroll)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    if logits_slice_last:
        x = x[:, -1:, :]
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = linear(params["lm_head"], x)

    if states is None:
        new_states = None
    else:
        new_states = {"prefix": tuple(new_prefix_states),
                      "stack": new_stack_states if groups else ()}
    return logits, new_states, aux_total


def loss_fn(cfg, params, batch, **fw_kw):
    """Causal-LM cross entropy (+ MoE aux). batch: tokens (B,S), labels (B,S)
    with -100 = ignore; VLM adds patch_embeds."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    embeds = batch.get("patch_embeds")
    logits, _, aux = lm_forward(cfg, params, tokens, embeds=embeds, **fw_kw)
    if embeds is not None:
        # logits cover [patches + text]; labels only for text part
        logits = logits[:, embeds.shape[1]:, :]
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.where(valid, logz - gold, 0.0)
    loss = ce.sum() / jnp.maximum(valid.sum(), 1)
    return loss + aux
