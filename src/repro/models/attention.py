"""Attention: GQA (with optional sliding window) and MLA (DeepSeek-V2 latent).

Pure-functional; caches are explicit pytrees so serve_decode is a pure step:

  cache = {"k": (B, C, KV, HD), "v": (B, C, KV, HD),
           "pos": (B, C) int32 (-1 = empty), "idx": () int32 next-slot}

Capacity C == seq_len for full attention, C == window for the sliding-window
(long-context) variant: the cache is a ring buffer, so a 500k-token stream
costs O(window) memory (DESIGN.md §7).

Full-sequence attention has three implementations:
  * "reference": plain einsum (small smoke shapes)
  * "blocked":   lax.scan online-softmax flash attention in pure jnp —
                 O(S * block) memory; used when lowering 32k prefill
  * "pallas":    kernels/flash_attention (TPU target; interpret-mode on CPU)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_linear, linear, rope_frequencies

NEG_INF = -1e30
BLOCKED_THRESHOLD = 2048  # use blocked flash attention above this seq len


# =====================  GQA  =====================

def init_gqa(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.num_heads * hd, dtype, cfg.attn_bias),
        "wk": init_linear(ks[1], d, cfg.num_kv_heads * hd, dtype, cfg.attn_bias),
        "wv": init_linear(ks[2], d, cfg.num_kv_heads * hd, dtype, cfg.attn_bias),
        "wo": init_linear(ks[3], cfg.num_heads * hd, d, dtype, cfg.attn_bias),
    }


def _mask_bias(q_pos, k_pos, window):
    """q_pos: (..., Sq), k_pos: (..., Sk) -> additive bias (..., Sq, Sk)."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    ok &= k_pos[..., None, :] >= 0
    if window and window > 0:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa_reference(q, k, v, bias, soft_cap=0.0):
    """q: (B,Sq,H,D) k/v: (B,Sk,KV,D) bias: (B,Sq,Sk) -> (B,Sq,H,D).

    K/V stay in their storage dtype; f32 happens in the MXU accumulator
    (preferred_element_type) — an .astype(f32) would MATERIALIZE an f32
    copy of the whole KV cache every decode step (2x cache traffic,
    §Perf hillclimb 2 iteration 3)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qr = q.reshape(b, sq, kv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(d)
    if soft_cap:
        s = soft_cap * jnp.tanh(s / soft_cap)
    s = s + bias[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _sdpa_blocked(q, k, v, q_pos, k_pos, window, soft_cap=0.0, block=None,
                  unroll=1):
    """Online-softmax flash attention in pure jnp (lax.scan over KV blocks).

    Memory: O(Sq * block) instead of O(Sq * Sk). Mirrors the Pallas kernel
    in kernels/flash_attention (which is the TPU-target implementation).
    ``unroll`` unrolls the KV-block scan (cost-model runs: XLA counts
    while-loop bodies once, so rooflines need the unrolled form).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if block is None:
        block = min(8192, max(512, sk // 64))   # <= ~64 scan iterations
    g = h // kv
    pad = (-sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nblk = (sk + pad) // block
    qf = q.reshape(b, sq, kv, g, d).astype(jnp.float32) / jnp.sqrt(d)
    kb = k.reshape(b, nblk, block, kv, d).swapaxes(0, 1)
    vb = v.reshape(b, nblk, block, kv, d).swapaxes(0, 1)
    pb = k_pos.reshape(b, nblk, block).swapaxes(0, 1)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, posblk = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kblk.astype(jnp.float32))
        if soft_cap:
            s = soft_cap * jnp.tanh(s / soft_cap)
        bias = _mask_bias(q_pos, posblk, window)          # (b, sq, block)
        s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb),
                                  unroll=unroll)
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def sdpa(q, k, v, q_pos, k_pos, window=0, soft_cap=0.0, impl="auto",
         unroll=1):
    """Dispatch full-sequence attention.

    auto policy: blocked (flash) only when BOTH sequence sides are long.
    For decode (Sq==1..8) the full (B, Sq, Sk) score tensor is small and
    the blocked path's (nblk, block, ...) reshape of the KV cache defeats
    the SPMD partitioner (it replicates the cache: 'involuntary full
    rematerialization') — §Perf hillclimb 2 measured 170x collective
    reduction from this dispatch rule."""
    if impl == "auto":
        impl = ("blocked" if (k.shape[1] > BLOCKED_THRESHOLD
                              and q.shape[1] > 8) else "reference")
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, q_pos, k_pos, window=window,
                                      soft_cap=soft_cap)
    if impl == "blocked":
        return _sdpa_blocked(q, k, v, q_pos, k_pos, window, soft_cap,
                             unroll=unroll)
    bias = _mask_bias(q_pos, k_pos, window)
    return _sdpa_reference(q, k, v, bias, soft_cap)


def init_kv_cache(cfg, batch, capacity, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, capacity, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, capacity, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def gqa_forward(cfg, p, x, positions, *, window=0, cache=None, impl="auto",
                unroll=1):
    """x: (B, S, D). positions: (B, S) int32 absolute positions.

    cache=None  -> full-sequence self attention (train / prefill w/o cache)
    cache given -> append S tokens (prefill fills, decode S=1), attend to cache.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = linear(p["wk"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.use_rope:
        cos, sin = rope_frequencies(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if cache is None:
        out = sdpa(q, k, v, positions, positions, window=window,
                   soft_cap=cfg.logit_soft_cap, impl=impl, unroll=unroll)
        new_cache = None
    else:
        cap = cache["k"].shape[1]
        slots = (cache["idx"] + jnp.arange(s, dtype=jnp.int32)) % cap
        k_cache = cache["k"].at[:, slots].set(k)
        v_cache = cache["v"].at[:, slots].set(v)
        pos_cache = cache["pos"].at[:, slots].set(positions)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache,
                     "idx": cache["idx"] + s}
        out = sdpa(q, k_cache, v_cache, positions, pos_cache, window=window,
                   soft_cap=cfg.logit_soft_cap, impl=impl, unroll=unroll)
    return linear(p["wo"], out.reshape(b, s, cfg.num_heads * hd)), new_cache


# =====================  MLA (DeepSeek-V2)  =====================

def init_mla(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "q_down": init_linear(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": {"scale": jnp.ones((cfg.q_lora_rank,), dtype)},
        "q_up": init_linear(ks[1], cfg.q_lora_rank, h * qk, dtype),
        "kv_down": init_linear(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora_rank,), dtype)},
        "k_up": init_linear(ks[3], cfg.kv_lora_rank, h * cfg.qk_nope_head_dim, dtype),
        "v_up": init_linear(ks[4], cfg.kv_lora_rank, h * cfg.v_head_dim, dtype),
        "wo": init_linear(ks[5], h * cfg.v_head_dim, d, dtype),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def init_mla_cache(cfg, batch, capacity, dtype):
    return {
        "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def _mla_q(cfg, p, x, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = linear(p["q_up"], _rms(linear(p["q_down"], x), p["q_norm"]["scale"]))
    q = q.reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_frequencies(rope, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_kv_compress(cfg, p, x, positions):
    kv = linear(p["kv_down"], x)
    c_kv = _rms(kv[..., :cfg.kv_lora_rank], p["kv_norm"]["scale"])
    k_rope = kv[..., cfg.kv_lora_rank:]
    cos, sin = rope_frequencies(cfg.qk_rope_head_dim, cfg.rope_theta, positions)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(cfg, p, x, positions, *, window=0, cache=None, impl="auto",
                unroll=1):
    """MLA attention. Cache stores the COMPRESSED kv (c_kv + shared k_rope):
    576 dims/token for DeepSeek-V2 instead of 2*128*192 — the paper's 93.3%
    cache reduction. Decode uses the absorbed-matmul trick so per-head K/V
    are never materialized against the full cache.
    """
    b, s, _ = x.shape
    h, nope, rope, vd = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_kv_compress(cfg, p, x, positions)

    if cache is not None:
        cap = cache["c_kv"].shape[1]
        slots = (cache["idx"] + jnp.arange(s, dtype=jnp.int32)) % cap
        c_all = cache["c_kv"].at[:, slots].set(c_kv)
        r_all = cache["k_rope"].at[:, slots].set(k_rope)
        pos_all = cache["pos"].at[:, slots].set(positions)
        new_cache = {"c_kv": c_all, "k_rope": r_all, "pos": pos_all,
                     "idx": cache["idx"] + s}
        k_pos = pos_all
    else:
        c_all, r_all, k_pos, new_cache = c_kv, k_rope, positions, None

    if s == 1 and cache is not None:
        # absorbed decode: fold k_up into q, v_up into the output projection
        k_up = p["k_up"]["w"].reshape(cfg.kv_lora_rank, h, nope)
        v_up = p["v_up"]["w"].reshape(cfg.kv_lora_rank, h, vd)
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope.astype(jnp.float32),
                           k_up.astype(jnp.float32))          # (b,1,h,lora)
        scores = (jnp.einsum("bshl,btl->bhst", q_lat, c_all.astype(jnp.float32))
                  + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                               r_all.astype(jnp.float32)))
        scores = scores / jnp.sqrt(nope + rope)
        bias = _mask_bias(positions, k_pos, window)            # (b, 1, cap)
        scores = scores + bias[:, None, :, :]
        w = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", w, c_all.astype(jnp.float32))
        out = jnp.einsum("bshl,lhv->bshv", o_lat, v_up.astype(jnp.float32))
        out = out.astype(x.dtype).reshape(b, s, h * vd)
    else:
        # prefill / train: materialize per-head K and V from the latent
        t = c_all.shape[1]
        k_nope = linear(p["k_up"], c_all).reshape(b, t, h, nope)
        v = linear(p["v_up"], c_all).reshape(b, t, h, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(r_all[:, :, None, :], (b, t, h, rope))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad V up to qk dim so we can reuse the shared sdpa, then slice
        if vd < nope + rope:
            v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rope - vd)))
        else:
            v_p = v
        out = sdpa(q, k, v_p, positions, k_pos, window=window, impl=impl,
                   unroll=unroll)
        out = out[..., :vd].reshape(b, s, h * vd)

    return linear(p["wo"], out), new_cache


# =====================  unified entry  =====================

def init_attention(key, cfg, dtype):
    if cfg.attention == "mla":
        return init_mla(key, cfg, dtype)
    return init_gqa(key, cfg, dtype)


def attention_forward(cfg, p, x, positions, *, window=0, cache=None,
                      impl="auto", unroll=1):
    if cfg.attention == "mla":
        return mla_forward(cfg, p, x, positions, window=window, cache=cache,
                           impl=impl, unroll=unroll)
    return gqa_forward(cfg, p, x, positions, window=window, cache=cache,
                       impl=impl, unroll=unroll)


def init_cache(cfg, batch, capacity, dtype):
    if cfg.attention == "mla":
        return init_mla_cache(cfg, batch, capacity, dtype)
    return init_kv_cache(cfg, batch, capacity, dtype)
