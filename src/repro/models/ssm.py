"""Mamba-1 selective SSM block (Falcon-Mamba / Jamba mixer).

Forward recurrence per channel c and state n:
    h_t = exp(dt_t * A[c,n]) * h_{t-1} + dt_t * B_t[n] * u_t[c]
    y_t = sum_n C_t[n] * h_t[c,n] + D[c] * u_t[c]

Training/prefill uses an associative scan (parallel prefix over the
(decay, increment) pairs) — TPU friendly; decode is the O(1) recurrence
with explicit carried state {conv window, ssm state}.

The TPU-target blocked kernel lives in kernels/ssm_scan (same math,
chunked over time with VMEM-resident state).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear


def init_mamba(key, cfg, dtype):
    d, d_in = cfg.d_model, cfg.ssm_d_inner
    st, dtr, cw = cfg.ssm_state, cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    return {
        "in_proj": init_linear(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (cw, d_in)) / math.sqrt(cw)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": init_linear(ks[2], d_in, dtr + 2 * st, dtype),
        "dt_proj": {"w": (jax.random.normal(ks[3], (dtr, d_in)) / math.sqrt(dtr)).astype(dtype),
                    "b": jnp.full((d_in,), -4.6, dtype)},      # softplus^-1(0.01)
        "a_log": jnp.log(a_init),                               # f32, A = -exp(a_log)
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_linear(ks[4], d_in, d, dtype),
    }


def init_ssm_state(cfg, batch, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner), dtype),
        "h": jnp.zeros((batch, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32),
    }


def _ssm_params(cfg, p, u):
    """u: (..., d_in) -> dt (..., d_in) f32, B/C (..., st) f32."""
    st, dtr = cfg.ssm_state, cfg.resolved_dt_rank
    proj = linear(p["x_proj"], u)
    dt = jax.nn.softplus(
        proj[..., :dtr].astype(jnp.float32) @ p["dt_proj"]["w"].astype(jnp.float32)
        + p["dt_proj"]["b"].astype(jnp.float32))
    b = proj[..., dtr:dtr + st].astype(jnp.float32)
    c = proj[..., dtr + st:].astype(jnp.float32)
    return dt, b, c


def _scan_full(cfg, p, u, h0=None):
    """u: (B, S, d_in) post-conv/silu. Associative scan over time.
    h0: optional initial state (B, d_in, st) from a previous chunk —
    folded in via the cumulative decay product (prefill continuation)."""
    a = -jnp.exp(p["a_log"])                                   # (d_in, st)
    dt, bmat, cmat = _ssm_params(cfg, p, u)                    # (B,S,d_in) (B,S,st)
    uf = u.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * a)                         # (B,S,d_in,st)
    inc = (dt * uf)[..., None] * bmat[..., None, :]            # (B,S,d_in,st)

    def op(l, r):
        dl, il = l
        dr, ir = r
        return dl * dr, il * dr + ir

    cum_decay, h = jax.lax.associative_scan(op, (decay, inc), axis=1)
    if h0 is not None:
        h = h + cum_decay * h0[:, None]                        # carry-in
    y = jnp.einsum("bsdn,bsn->bsd", h, cmat)
    return (y + uf * p["d_skip"]).astype(u.dtype), h[:, -1]


def mamba_forward(cfg, p, x, *, state: Optional[dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, D). state None -> full-sequence scan (train/prefill,
    returns state for continuation); state given with S==1 -> one decode step.
    """
    b, s, d = x.shape
    d_in, cw = cfg.ssm_d_inner, cfg.ssm_conv
    xz = linear(p["in_proj"], x)
    u, z = xz[..., :d_in], xz[..., d_in:]

    if state is None or s > 1:
        prev = state["conv"] if state is not None else jnp.zeros((b, cw - 1, d_in), u.dtype)
        u_ext = jnp.concatenate([prev, u], axis=1)
        conv_in = u_ext[:, -(s + cw - 1):]
        u_c = jax.nn.silu(_conv_causal_from(p, conv_in, s, cw))
        h0 = state["h"] if state is not None else None
        y, h_last = _scan_full(cfg, p, u_c, h0=h0)
        new_state = {"conv": u_ext[:, -(cw - 1):].astype(u.dtype), "h": h_last}
    else:
        # decode: one token
        conv_window = jnp.concatenate([state["conv"], u], axis=1)  # (B, cw, d_in)
        u_c = jax.nn.silu(
            jnp.einsum("bwd,wd->bd", conv_window, p["conv_w"]) + p["conv_b"])[:, None, :]
        a = -jnp.exp(p["a_log"])
        dt, bmat, cmat = _ssm_params(cfg, p, u_c)
        uf = u_c.astype(jnp.float32)
        decay = jnp.exp(dt[:, 0, :, None] * a)                 # (B, d_in, st)
        inc = (dt[:, 0] * uf[:, 0])[..., None] * bmat[:, 0, None, :]
        h = state["h"] * decay + inc
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None, :]
        y = (y + uf * p["d_skip"]).astype(u.dtype)
        new_state = {"conv": conv_window[:, 1:], "h": h}

    out = y * jax.nn.silu(z)
    return linear(p["out_proj"], out), new_state


def _conv_causal_from(p, u_ext, s, window):
    """u_ext: (B, S + window - 1, d_in) already left-extended."""
    out = sum(u_ext[:, i:i + s, :] * p["conv_w"][i] for i in range(window))
    return out + p["conv_b"]
