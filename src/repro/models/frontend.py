"""Modality frontend STUBS (the one sanctioned stub — DESIGN.md §7).

The assignment exercises the language/decoder transformer backbone; the
vision tower (ViT/SigLIP + projector) and the audio codec (mel + conv) are
represented by functions that produce embeddings of exactly the shape the
real frontend would emit. ``input_specs`` in launch/dryrun uses the same
shapes as ShapeDtypeStructs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vision_patch_stub(key, batch: int, num_patches: int, d_model: int,
                      dtype=jnp.bfloat16):
    """Pre-projected anyres patch embeddings a LLaVA-NeXT vision tower +
    mm-projector would produce: (B, P, D)."""
    return (jax.random.normal(key, (batch, num_patches, d_model)) * 0.02
            ).astype(dtype)


def audio_frame_stub(key, batch: int, frames: int, d_model: int,
                     dtype=jnp.bfloat16):
    """Post-conv (stride-2) mel frame embeddings a Whisper conv frontend
    would produce: (B, T_enc, D)."""
    return (jax.random.normal(key, (batch, frames, d_model)) * 0.02
            ).astype(dtype)
