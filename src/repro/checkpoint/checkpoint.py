"""Flat-npz pytree checkpointing with rotation.

Paths are keyed ``step_<n>/state.npz``; pytree structure is recorded via
jax.tree_util key paths so restore round-trips arbitrary nested
dict/tuple/list states (FL server state = {params, delta_prev, round}).

Two sidecar channels ride along with the device state (both written
atomically into the same step dir, both rotated with it):

  * ``aux_arrays`` -> aux.npz — HOST-side numpy state round-tripped with
    exact dtypes (no jnp casting: e.g. the trainer's MT19937 key vector
    must come back uint32 and the cached gaussian float64);
  * ``aux_json``   -> aux.json — JSON-able metadata (round history,
    sampler state, config echoes).

This is what makes ``FederatedTrainer.save()/resume()`` reproduce an
uninterrupted run: params/server_state go through the pytree channel,
RNG/round/schedule/history through the sidecars (DESIGN.md §3).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import warnings
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

# files covered by the manifest's content digests (aux sidecars are
# optional; only files actually written are digested)
_DIGESTED = ("state.npz", "aux.npz", "aux.json")


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(part) for part in path) for path, _ in flat]
    vals = [leaf for _, leaf in flat]
    return keys, vals, treedef


def save(ckpt_dir: str, step: int, state: PyTree, keep: int = 3,
         aux_arrays: Optional[dict] = None, aux_json: Any = None) -> str:
    """Atomic: everything lands in ``step_<n>.tmp`` first and is renamed
    into place only after the last byte is written, so a crash mid-save
    leaves the previous intact steps selectable by ``latest_step`` (the
    ``step_(\\d+)`` pattern never matches a torn .tmp dir)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    keys, vals, _ = _flatten_with_paths(state)
    arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(vals)}
    np.savez(os.path.join(tmp, "state.npz"), **arrays)
    if aux_arrays is not None:
        np.savez(os.path.join(tmp, "aux.npz"),
                 **{k: np.asarray(v) for k, v in aux_arrays.items()})
    if aux_json is not None:
        with open(os.path.join(tmp, "aux.json"), "w") as f:
            json.dump(aux_json, f, default=float)
    digests = {name: _sha256(os.path.join(tmp, name))
               for name in _DIGESTED
               if os.path.exists(os.path.join(tmp, name))}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "keys": keys, "digests": digests}, f)
    old = None
    if os.path.exists(path):        # re-saving the same step: keep the
        old = path + ".old"         # previous copy until the new one is
        shutil.rmtree(old, ignore_errors=True)   # fully in place
        os.rename(path, old)
    os.rename(tmp, path)
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)
    _rotate(ckpt_dir, keep)
    return path


def load_aux(ckpt_dir: str, step: Optional[int] = None):
    """Load the (aux_arrays, aux_json) sidecars for ``step`` (latest if
    None). Missing sidecars come back as ({}, None)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    arrays = {}
    npz_path = os.path.join(path, "aux.npz")
    if os.path.exists(npz_path):
        with np.load(npz_path) as z:
            arrays = {k: z[k] for k in z.files}
    meta = None
    json_path = os.path.join(path, "aux.json")
    if os.path.exists(json_path):
        with open(json_path) as f:
            meta = json.load(f)
    return arrays, meta


def _steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    # crash recovery for the re-save window in save(): if the process
    # died after the old copy was set aside but before the new one was
    # renamed in, step_N is absent and step_N.old holds the only intact
    # copy — restore it so the step stays selectable
    for d in os.listdir(ckpt_dir):
        if re.fullmatch(r"step_(\d+)\.old", d):
            bare = os.path.join(ckpt_dir, d[:-4])
            if not os.path.exists(bare):
                os.rename(os.path.join(ckpt_dir, d), bare)
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "state.npz")):
            out.append(int(m.group(1)))
    return sorted(out)


def _rotate(ckpt_dir: str, keep: int):
    steps = _steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _steps(ckpt_dir)
    return steps[-1] if steps else None


def verify_step(ckpt_dir: str, step: int) -> Optional[str]:
    """Content-integrity check for one step (DESIGN.md §12). Returns
    None when the step verifies, else a human-readable reason.

    A missing manifest is corruption (save() always writes one); a
    legacy manifest without ``digests`` is trusted as-is so pre-digest
    checkpoints keep restoring. Every digested file must still exist
    and hash to its recorded sha256 — truncation, bit-flips, and
    deleted sidecars all surface here instead of as silent bad math."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.isdir(path):
        return f"step dir {path} does not exist"
    man_path = os.path.join(path, "manifest.json")
    if not os.path.exists(man_path):
        return "manifest.json missing"
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        return f"manifest.json unreadable: {e}"
    digests = manifest.get("digests")
    if digests is None:      # legacy (pre-digest) checkpoint: trusted
        return None
    for name, want in digests.items():
        fpath = os.path.join(path, name)
        if not os.path.exists(fpath):
            return f"{name} missing (recorded in manifest)"
        got = _sha256(fpath)
        if got != want:
            return (f"{name} digest mismatch: manifest {want[:12]}..., "
                    f"file {got[:12]}... (truncated or corrupted)")
    return None


def resolve_step(ckpt_dir: str, step: Optional[int] = None) -> int:
    """Pick the step to restore, verifying content digests.

    Explicit ``step``: verified and returned, or ValueError with the
    corruption reason — an explicit request never silently falls back.
    ``step=None``: walk the available steps newest-first and return the
    first that verifies (self-healing last-good fallback), warning for
    each corrupt step skipped; FileNotFoundError when none survive."""
    if step is not None:
        reason = verify_step(ckpt_dir, step)
        if reason is not None:
            raise ValueError(
                f"checkpoint step {step} in {ckpt_dir} failed "
                f"verification: {reason}")
        return step
    steps = _steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    for s in reversed(steps):
        reason = verify_step(ckpt_dir, s)
        if reason is None:
            return s
        warnings.warn(
            f"skipping corrupt checkpoint step {s} in {ckpt_dir}: "
            f"{reason}", RuntimeWarning)
    raise FileNotFoundError(
        f"no checkpoint step in {ckpt_dir} passed verification "
        f"(tried {list(reversed(steps))})")


def restore(ckpt_dir: str, like: PyTree, step: Optional[int] = None) -> PyTree:
    """Restore into the structure of `like` (shape/dtype validated)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "state.npz")) as z:
        arrays = [z[f"a{i}"] for i in range(len(z.files))]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves) != len(arrays):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"expected {len(leaves)}")
    out = []
    for ref, arr in zip(leaves, arrays):
        if tuple(np.shape(ref)) != arr.shape:
            raise ValueError(f"shape mismatch {np.shape(ref)} vs {arr.shape}")
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
