"""Flat-npz pytree checkpointing with rotation.

Paths are keyed ``step_<n>/state.npz``; pytree structure is recorded via
jax.tree_util key paths so restore round-trips arbitrary nested
dict/tuple/list states (FL server state = {params, delta_prev, round}).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(part) for part in path) for path, _ in flat]
    vals = [leaf for _, leaf in flat]
    return keys, vals, treedef


def save(ckpt_dir: str, step: int, state: PyTree, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    keys, vals, _ = _flatten_with_paths(state)
    arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(vals)}
    np.savez(os.path.join(path, "state.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"step": step, "keys": keys}, f)
    _rotate(ckpt_dir, keep)
    return path


def _steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "state.npz")):
            out.append(int(m.group(1)))
    return sorted(out)


def _rotate(ckpt_dir: str, keep: int):
    steps = _steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: PyTree, step: Optional[int] = None) -> PyTree:
    """Restore into the structure of `like` (shape/dtype validated)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "state.npz")) as z:
        arrays = [z[f"a{i}"] for i in range(len(z.files))]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves) != len(arrays):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"expected {len(leaves)}")
    out = []
    for ref, arr in zip(leaves, arrays):
        if tuple(np.shape(ref)) != arr.shape:
            raise ValueError(f"shape mismatch {np.shape(ref)} vs {arr.shape}")
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
