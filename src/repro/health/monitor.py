"""Run-health monitor over the RoundRecord stream (DESIGN.md §14).

Long unattended runs need automated judgment calls, not just per-round
receipts: a loss spike, a NaN, a staleness ramp, or a rising quarantine
rate each want an alarm (and, configured, an early stop) the moment
they appear — hours before a human reads the bench receipts. The
monitor is a pure host-side consumer of the ``RoundRecord`` stream the
trainer already emits, so it works identically under every execution
regime (serial / vectorized / sharded / buffered-async) and never
touches the compiled round.

Detectors (all windowed over the last ``window`` consumed rounds):

  loss spike      train_loss > spike_mult x rolling MEDIAN loss (the
                  median ignores the spike itself — a mean would chase
                  it), armed once ``min_history`` rounds are in window
  non-finite      train_loss NaN/inf — always an alarm, no warm-up
  staleness trend staleness_mean > staleness_mult x rolling median
                  staleness (buffered-async regime; a deepening queue
                  shows up here rounds before the loss does)
  quarantine rate mean quarantined-per-round over the window exceeds
                  ``quarantine_rate`` x clients_per_round — the guard
                  is eating a sustained fraction of the cohort

``observe(record)`` returns a ``HealthReport``; ``should_stop`` goes
True after ``patience`` CONSECUTIVE alarmed rounds (None disables the
early-stop hook; non-finite loss trips it immediately when
``stop_on_nonfinite``). State is a plain dict of floats/ints —
``state_dict()/load_state_dict()`` round-trip it bitwise through the
trainer's aux sidecar so a resumed run's detector picks up mid-window
instead of re-warming blind.

Verdicts can STREAM to an external tracker through a pluggable ``sink``
with the wandb-style interface ``log(data: dict, step: int)`` (plus an
optional ``close()``): every observe() emits its report as a flat dict,
so an unattended run's health trace lands somewhere a human (or a
dashboard) watches while the run is still going — not only in the
receipts read after the fact. ``JsonlHealthSink`` is the file-backed
reference implementation (one JSON object per line, append-only,
flushed per verdict so a crashed run keeps everything emitted); a real
``wandb.run`` object satisfies the same duck type directly. Sink
failures never take the training loop down — they disable the sink and
warn once.
"""
from __future__ import annotations

import json
import math
import warnings
from collections import deque
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional


@dataclass(frozen=True)
class HealthConfig:
    window: int = 32            # rolling-median window (rounds)
    min_history: int = 8        # rounds before the ratio alarms arm
    spike_mult: float = 3.0     # loss spike: > mult x median loss
    staleness_mult: float = 3.0  # staleness trend: > mult x median
    quarantine_rate: float = 0.25  # sustained quarantined / cohort
    clients_per_round: int = 10    # normalizes the quarantine rate
    patience: Optional[int] = None  # consecutive alarmed rounds -> stop
    stop_on_nonfinite: bool = True  # NaN/inf loss stops immediately

    def config_dict(self) -> Dict[str, Any]:
        return {"window": self.window, "min_history": self.min_history,
                "spike_mult": self.spike_mult,
                "staleness_mult": self.staleness_mult,
                "quarantine_rate": self.quarantine_rate,
                "clients_per_round": self.clients_per_round,
                "patience": self.patience,
                "stop_on_nonfinite": self.stop_on_nonfinite}


@dataclass
class HealthReport:
    """One round's verdict + the monitor's running tallies."""
    round: int
    train_loss: float
    loss_median: float            # rolling median BEFORE this round
    alarms: List[str] = field(default_factory=list)
    should_stop: bool = False
    # cumulative counters (whole run, survive resume)
    spike_rounds: int = 0
    nonfinite_rounds: int = 0
    alarmed_rounds: int = 0
    consecutive_alarmed: int = 0

    @property
    def healthy(self) -> bool:
        return not self.alarms


def _median(values) -> float:
    s = sorted(values)
    n = len(s)
    if n == 0:
        return math.nan
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


class JsonlHealthSink:
    """Append-only JSONL tracker file: one verdict per line, flushed
    immediately (a killed run keeps every verdict emitted before the
    kill). The file opens lazily at the first ``log`` so constructing a
    monitor with a sink configured but never observed leaves no file."""

    def __init__(self, path: str):
        self.path = str(path)
        self._fh = None

    def log(self, data: Dict[str, Any], step: Optional[int] = None) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        row = {"step": step, **data} if step is not None else dict(data)
        self._fh.write(json.dumps(row) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class HealthMonitor:
    """Feed every consumed RoundRecord (in round order) to ``observe``;
    read the verdict from the returned HealthReport (also kept as
    ``last_report``). Host-side and regime-agnostic by construction.

    ``sink``: optional wandb-style tracker (``log(data, step)``); every
    report streams to it as a flat dict keyed by the report fields,
    with the alarm list joined to a comma string (flat scalar values
    only — the common denominator of tracker backends)."""

    def __init__(self, config: Optional[HealthConfig] = None, *,
                 sink=None):
        self.config = config or HealthConfig()
        self._sink = sink
        w = self.config.window
        self._loss = deque(maxlen=w)
        self._stale = deque(maxlen=w)
        self._quar = deque(maxlen=w)
        self._spike_rounds = 0
        self._nonfinite_rounds = 0
        self._alarmed_rounds = 0
        self._streak = 0              # consecutive alarmed rounds
        self._stopped = False
        self.last_report: Optional[HealthReport] = None

    # ---- detection ----

    def observe(self, record) -> HealthReport:
        """record: anything with .round/.train_loss/.staleness_mean/
        .quarantined (a RoundRecord). Returns this round's report."""
        cfg = self.config
        loss = float(record.train_loss)
        loss_med = _median(self._loss)
        alarms: List[str] = []
        if not math.isfinite(loss):
            alarms.append("nonfinite_loss")
            self._nonfinite_rounds += 1
        elif (len(self._loss) >= cfg.min_history
                and math.isfinite(loss_med)
                and loss > cfg.spike_mult * max(loss_med, 1e-12)):
            alarms.append("loss_spike")
            self._spike_rounds += 1
        stale = float(getattr(record, "staleness_mean", 0.0))
        stale_med = _median(self._stale)
        if (len(self._stale) >= cfg.min_history
                and math.isfinite(stale_med)
                and stale > cfg.staleness_mult * max(stale_med, 1e-12)):
            alarms.append("staleness_trend")
        quar = float(getattr(record, "quarantined", 0))
        self._quar.append(quar)
        if (len(self._quar) >= cfg.min_history
                and (sum(self._quar) / len(self._quar))
                > cfg.quarantine_rate * cfg.clients_per_round):
            alarms.append("quarantine_rate")
        # a non-finite loss never enters the median window (it would
        # poison every later comparison); spikes DO enter — a sustained
        # plateau at the new level stops alarming once the median catches
        # up, which is what distinguishes a spike from a regime change
        if math.isfinite(loss):
            self._loss.append(loss)
        self._stale.append(stale)
        if alarms:
            self._alarmed_rounds += 1
            self._streak += 1
        else:
            self._streak = 0
        stop = self._stopped
        if cfg.stop_on_nonfinite and "nonfinite_loss" in alarms:
            stop = True
        if cfg.patience is not None and self._streak >= cfg.patience:
            stop = True
        self._stopped = stop
        self.last_report = HealthReport(
            round=int(record.round), train_loss=loss, loss_median=loss_med,
            alarms=alarms, should_stop=stop,
            spike_rounds=self._spike_rounds,
            nonfinite_rounds=self._nonfinite_rounds,
            alarmed_rounds=self._alarmed_rounds,
            consecutive_alarmed=self._streak)
        if self._sink is not None:
            row = asdict(self.last_report)
            row["alarms"] = ",".join(alarms)
            row["healthy"] = not alarms
            try:
                self._sink.log(row, step=self.last_report.round)
            except Exception as e:       # pragma: no cover - defensive
                # a broken tracker must not take the run down: drop the
                # sink and keep training (the receipt path still records
                # everything)
                self._sink = None
                warnings.warn(f"health sink failed and was disabled: {e}",
                              RuntimeWarning, stacklevel=2)
        return self.last_report

    def close_sink(self) -> None:
        """Release the sink's file handle (if it has one)."""
        if self._sink is not None and hasattr(self._sink, "close"):
            self._sink.close()

    # ---- checkpointing (rides the trainer's aux sidecar) ----

    def state_dict(self) -> Dict[str, Any]:
        return {"loss": list(self._loss), "stale": list(self._stale),
                "quar": list(self._quar),
                "spike_rounds": self._spike_rounds,
                "nonfinite_rounds": self._nonfinite_rounds,
                "alarmed_rounds": self._alarmed_rounds,
                "streak": self._streak, "stopped": self._stopped}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        w = self.config.window
        self._loss = deque([float(x) for x in state["loss"]], maxlen=w)
        self._stale = deque([float(x) for x in state["stale"]], maxlen=w)
        self._quar = deque([float(x) for x in state["quar"]], maxlen=w)
        self._spike_rounds = int(state["spike_rounds"])
        self._nonfinite_rounds = int(state["nonfinite_rounds"])
        self._alarmed_rounds = int(state["alarmed_rounds"])
        self._streak = int(state["streak"])
        self._stopped = bool(state["stopped"])
