"""Blocked causal/sliding-window GQA flash attention (Pallas, TPU target).

TPU adaptation of the FlashAttention tiling: the grid is
(batch, kv_head, q_block, kv_block) with the KV dimension INNERMOST and
declared "arbitrary" so the online-softmax state (m, l, acc) lives in VMEM
scratch across kv iterations of the same q block. Block shapes are
(BQ, head_dim) / (BK, head_dim) — head_dim is lane-aligned (128 for every
assigned arch) and BQ/BK default to MXU-friendly 128/256 tiles.

GQA: all G = H/KV query heads of one kv head are processed together as a
(BQ, G*D)-shaped q block — the kernel reshapes to (BQ, G, D), giving the
MXU a (BQ*G, BK) logits matmul per step, amortizing the K/V loads across
the query group exactly like the GQA-aware TPU kernels in production
serving stacks.

Masking: positions are explicit int32 vectors (supports the ring-buffer
decode cache where k positions are arbitrary): causal (k_pos <= q_pos),
validity (k_pos >= 0), sliding window (q_pos - k_pos < window).

Validated against ref.py in interpret mode (CPU) over shape/dtype sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref,
               o_ref, m_scr, l_scr, acc_scr, *, window: int, soft_cap: float,
               g: int, d: int, nk: int):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    bq = q_ref.shape[-2]
    bk = k_ref.shape[-2]
    q = q_ref[0, 0].astype(jnp.float32).reshape(bq, g, d) / math.sqrt(d)
    k = k_ref[0, 0].astype(jnp.float32)                      # (BK, D)
    s = jnp.einsum("qgd,kd->qgk", q, k)                      # (BQ, G, BK)
    if soft_cap:
        s = soft_cap * jnp.tanh(s / soft_cap)

    qp = qpos_ref[0]                                         # (BQ,)
    kp = kpos_ref[0]                                         # (BK,)
    ok = (kp[None, :] <= qp[:, None]) & (kp[None, :] >= 0)
    if window > 0:
        ok &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(ok[:, None, :], s, NEG_INF)

    m_prev = m_scr[...]                                      # (BQ, G)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    # fully-masked rows: keep exp(NEG_INF - NEG_INF)=1 from poisoning l
    p = jnp.where(ok[:, None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)                      # (BK, D)
    acc_scr[...] = (acc_scr[...] * corr[..., None]
                    + jnp.einsum("qgk,kd->qgd", p, v))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kv_i == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[..., None]).reshape(bq, g * d
                                                            ).astype(o_ref.dtype)


def flash_attention_gqa(q, k, v, q_pos, k_pos, *, window: int = 0,
                        soft_cap: float = 0.0, block_q: int = 128,
                        block_k: int = 256, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D); q_pos: (B, Sq); k_pos: (B, Sk).
    Returns (B, Sq, H, D). Sq % block_q == 0 and Sk % block_k == 0 required
    (ops.py pads)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = sq // block_q
    nk = sk // block_k

    # layout: (B, KV, Sq, G*D) so one block holds a whole query group
    qr = q.reshape(b, sq, kv, g * d).transpose(0, 2, 1, 3)
    kr = k.transpose(0, 2, 1, 3)                             # (B, KV, Sk, D)
    vr = v.transpose(0, 2, 1, 3)

    grid = (b, kv, nq, nk)
    kernel = functools.partial(_fa_kernel, window=window, soft_cap=soft_cap,
                               g=g, d=d, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, g * d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, block_q), lambda bi, hi, qi, ki: (bi, qi)),
            pl.BlockSpec((1, block_k), lambda bi, hi, qi, ki: (bi, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, g * d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, sq, g * d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, g), jnp.float32),      # m (running max)
            pltpu.VMEM((block_q, g), jnp.float32),      # l (running sum)
            pltpu.VMEM((block_q, g, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qr, kr, vr, q_pos, k_pos)
    return out.transpose(0, 2, 1, 3).reshape(b, sq, h, d)
