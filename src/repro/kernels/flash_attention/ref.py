"""Pure-jnp oracle for the flash attention kernel: full-matrix softmax
attention with the identical masking semantics (causal + validity +
sliding window on explicit positions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, q_pos, k_pos, *, window: int = 0,
                  soft_cap: float = 0.0):
    """q: (B,Sq,H,D) k/v: (B,Sk,KV,D) -> (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.reshape(b, sq, kv, g, d).astype(jnp.float32) / jnp.sqrt(d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if soft_cap:
        s = soft_cap * jnp.tanh(s / soft_cap)
    ok = (k_pos[:, None, :] <= q_pos[:, :, None]) & (k_pos[:, None, :] >= 0)
    if window and window > 0:
        ok &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(ok[:, None, None, :, :], p, 0.0)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p / l, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
