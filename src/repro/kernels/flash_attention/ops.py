"""jit'd wrapper: pads sequences to block multiples, invokes the Pallas
flash-attention kernel, unpads. This is what models/attention.py routes to
with impl="pallas"."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


@functools.partial(jax.jit,
                   static_argnames=("window", "soft_cap", "block_q",
                                    "block_k", "interpret"))
def flash_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                    soft_cap: float = 0.0, block_q: int = 128,
                    block_k: int = 256, interpret: bool = True):
    """Same contract as models.attention.sdpa: q (B,Sq,H,D), k/v (B,Sk,KV,D),
    positions (B,Sq)/(B,Sk) int32 (-1 = empty cache slot)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    pq = (-sq) % bq
    pk = (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        # padded queries attend to nothing real; give them pos max so the
        # causal mask passes and l stays > 0 via validity mask handling
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pk)), constant_values=-1)
    out = K.flash_attention_gqa(q, k, v, q_pos, k_pos, window=window,
                                soft_cap=soft_cap, block_q=bq, block_k=bk,
                                interpret=interpret)
    return out[:, :sq]
