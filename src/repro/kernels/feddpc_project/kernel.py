"""Pallas TPU kernels for the FedDPC server epilogue (DESIGN.md §2).

The server step per client update is two passes over R^d:

  1. reduction pass:  <d, prev>, ||d||^2, ||prev||^2   (three dots, fused)
  2. epilogue pass:   out = scale * (d - coef * prev)  (fused residual+scale)

Naively (paper Table 1: O(4k'd) server work in 4+ separate passes) each
scalar costs its own HBM sweep. Fusing pass 1 reads d and prev ONCE for
all three dots (6d bytes -> 4d bytes), and pass 2 fuses the projection
subtraction with the adaptive scaling (4d -> 3d bytes). TPU adaptation:
updates are processed as (rows, 128)-tiled blocks resident in VMEM —
lane-aligned, VPU elementwise, no MXU involvement.

Validated in interpret mode on CPU against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_ROWS = 512          # (512, 128) f32 block = 256 KiB VMEM per operand


def _reduce_kernel(d_ref, p_ref, out_ref):
    d = d_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(d * p)
    out_ref[0, 1] = jnp.sum(d * d)
    out_ref[0, 2] = jnp.sum(p * p)


def fused_dots(d2: jnp.ndarray, p2: jnp.ndarray, *, rows: int = DEFAULT_ROWS,
               interpret: bool = True) -> jnp.ndarray:
    """d2/p2: (M, 128). Returns (G, 3) per-block partials of
    [<d,p>, <d,d>, <p,p>] — sum over G outside (one tiny reduction)."""
    m = d2.shape[0]
    rows = min(rows, m)
    grid = (pl.cdiv(m, rows),)
    return pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 3), jnp.float32),
        interpret=interpret,
    )(d2, p2)


def _epilogue_kernel(coef_ref, scale_ref, d_ref, p_ref, out_ref):
    coef = coef_ref[0]
    scale = scale_ref[0]
    d = d_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    out_ref[...] = (scale * (d - coef * p)).astype(out_ref.dtype)


def fused_epilogue(d2: jnp.ndarray, p2: jnp.ndarray, coef, scale, *,
                   rows: int = DEFAULT_ROWS,
                   interpret: bool = True) -> jnp.ndarray:
    """out = scale * (d2 - coef * p2), one HBM pass. d2/p2: (M, 128)."""
    m = d2.shape[0]
    rows = min(rows, m)
    grid = (pl.cdiv(m, rows),)
    coef = jnp.asarray(coef, jnp.float32).reshape(1)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    return pl.pallas_call(
        _epilogue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # coef (broadcast to blocks)
            pl.BlockSpec((1,), lambda i: (0,)),  # scale
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(d2.shape, d2.dtype),
        interpret=interpret,
    )(coef, scale, d2, p2)
