"""Pallas TPU kernels for the FedDPC server epilogue (DESIGN.md §2).

The server step per client update is two passes over R^d:

  1. reduction pass:  <d, prev>, ||d||^2, ||prev||^2   (three dots, fused)
  2. epilogue pass:   out = scale * (d - coef * prev)  (fused residual+scale)

Naively (paper Table 1: O(4k'd) server work in 4+ separate passes) each
scalar costs its own HBM sweep. Fusing pass 1 reads d and prev ONCE for
all three dots (6d bytes -> 4d bytes), and pass 2 fuses the projection
subtraction with the adaptive scaling (4d -> 3d bytes). TPU adaptation:
updates are processed as (rows, 128)-tiled blocks resident in VMEM —
lane-aligned, VPU elementwise, no MXU involvement.

``batched_epilogue`` extends pass 2 to the WHOLE cohort (DESIGN.md §2):
one grid over the stacked (K, M, 128) deltas fuses the per-client
residual+scale with the client-mean and the global param update, so the
entire server epilogue is a single HBM pass over (K+2)·d floats instead
of K separate per-client kernel launches under vmap plus two more full
passes for the mean and the parameter update.

Validated in interpret mode on CPU against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_ROWS = 512          # (512, 128) f32 block = 256 KiB VMEM per operand


def _reduce_kernel(d_ref, p_ref, out_ref):
    d = d_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(d * p)
    out_ref[0, 1] = jnp.sum(d * d)
    out_ref[0, 2] = jnp.sum(p * p)


def fused_dots(d2: jnp.ndarray, p2: jnp.ndarray, *, rows: int = DEFAULT_ROWS,
               interpret: bool = True) -> jnp.ndarray:
    """d2/p2: (M, 128). Returns (G, 3) per-block partials of
    [<d,p>, <d,d>, <p,p>] — sum over G outside (one tiny reduction)."""
    m = d2.shape[0]
    rows = min(rows, m)
    grid = (pl.cdiv(m, rows),)
    return pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 3), jnp.float32),
        interpret=interpret,
    )(d2, p2)


def _guard_reduce_kernel(d_ref, p_ref, out_ref):
    """Reduction pass + guard epilogue in ONE sweep (DESIGN.md §12): the
    three FedDPC dots plus the update-guard's non-finite count, so
    validating a delta costs zero extra HBM traffic over computing its
    reduction scalars. Non-finite entries are zeroed before the dots —
    exact for clean deltas, and the dots stay finite (usable) even when
    the guard column flags the client for quarantine."""
    d = d_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    finite = jnp.isfinite(d)
    df = jnp.where(finite, d, 0.0)
    out_ref[0, 0] = jnp.sum(df * p)
    out_ref[0, 1] = jnp.sum(df * df)
    out_ref[0, 2] = jnp.sum(p * p)
    out_ref[0, 3] = jnp.sum((~finite).astype(jnp.float32))


def guard_dots(d2: jnp.ndarray, p2: jnp.ndarray, *, rows: int = DEFAULT_ROWS,
               interpret: bool = True) -> jnp.ndarray:
    """d2/p2: (M, 128). Returns (G, 4) per-block partials of
    [<d,p>, <d,d>, <p,p>, nonfinite(d)] — ``fused_dots`` with the guard
    column riding the same pass; sum over G outside."""
    m = d2.shape[0]
    rows = min(rows, m)
    grid = (pl.cdiv(m, rows),)
    return pl.pallas_call(
        _guard_reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 4), jnp.float32),
        interpret=interpret,
    )(d2, p2)


def _batched_epilogue_kernel(coef_ref, scale_ref, eta_ref, d_ref, p_ref,
                             w_ref, w_out_ref, dt_out_ref):
    """Whole-cohort server epilogue on one (rows, 128) tile:

        dt  = mean_j scale_j * (d_j - coef_j * prev)      (residual+scale+mean)
        w'  = w - eta_g * dt                              (global param update)

    d_ref block is (K, rows, 128) — all K clients' tile resident at once,
    so the stacked deltas are read exactly ONCE per round.
    """
    d = d_ref[...].astype(jnp.float32)                    # (K, r, 128)
    p = p_ref[...].astype(jnp.float32)                    # (r, 128)
    coef = coef_ref[...].astype(jnp.float32)[:, None, None]
    scale = scale_ref[...].astype(jnp.float32)[:, None, None]
    dt = jnp.mean(scale * (d - coef * p[None]), axis=0)
    dt_out_ref[...] = dt.astype(dt_out_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    w_out_ref[...] = (w - eta_ref[0] * dt).astype(w_out_ref.dtype)


def batched_epilogue(d3: jnp.ndarray, p2: jnp.ndarray, w2: jnp.ndarray,
                     coefs, scales, eta_g, *, rows: int = None,
                     interpret: bool = True):
    """Fused FedDPC server epilogue over the STACKED cohort in one grid.

    d3: (K, M, 128) client-stacked deltas; p2/w2: (M, 128) delta_prev /
    params; coefs/scales: (K,) per-client scalars from the reduction pass.
    Returns (new_w2, delta_t2), both (M, 128): one HBM pass over the
    (K+2)·M·128 input floats instead of K separate epilogue calls (which
    re-read prev K times and leave the mean + param update as extra
    passes). K·rows·128 f32 must fit VMEM, so the row block shrinks as K
    grows (default 512/K, floor 8). M must be a multiple of the row block
    (ops.py pads; full blocks avoid partial-block padding semantics).
    """
    k, m, lane = d3.shape
    assert lane == LANE, d3.shape
    rows = min(rows or max(8, DEFAULT_ROWS // max(1, k)), m)
    while m % rows:                 # largest divisor <= target (trace-time)
        rows -= 1
    grid = (pl.cdiv(m, rows),)
    coefs = jnp.asarray(coefs, jnp.float32).reshape(k)
    scales = jnp.asarray(scales, jnp.float32).reshape(k)
    eta = jnp.asarray(eta_g, jnp.float32).reshape(1)
    return pl.pallas_call(
        _batched_epilogue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),       # coefs (broadcast)
            pl.BlockSpec((k,), lambda i: (0,)),       # scales
            pl.BlockSpec((1,), lambda i: (0,)),       # eta_g
            pl.BlockSpec((k, rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        # delta_t is server STATE: emitted f32 to match the jnp path's
        # f32 accumulation whatever the input dtypes
        out_shape=[jax.ShapeDtypeStruct((m, lane), w2.dtype),
                   jax.ShapeDtypeStruct((m, lane), jnp.float32)],
        interpret=interpret,
    )(coefs, scales, eta, d3, p2, w2)


def _buffer_fold_kernel(inv_b, coef_ref, scale_ref, wgt_ref, eta_ref,
                        d_ref, p_ref, w_ref, w_out_ref, dt_out_ref):
    """Buffered-async server fold on one (rows, 128) tile (DESIGN.md §11):

        dt  = (1/B) sum_j wgt_j * scale_j * (d_j - coef_j * prev)
        w'  = w - eta_g * dt

    The grid's innermost axis j walks the B buffered deltas ONE AT A
    TIME and scatter-accumulates into the resident dt output block: the
    output BlockSpec ignores j, so Pallas keeps the (rows, 128) dt tile
    in VMEM across the whole j loop while each d_j block streams
    through. VMEM footprint is O(rows·128) independent of B — a buffer
    of 64 stale updates folds with the same full-size row block the
    K-resident batched epilogue would have to shrink 64x for.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dt_out_ref[...] = jnp.zeros_like(dt_out_ref)

    d = d_ref[0].astype(jnp.float32)                      # (r, 128)
    p = p_ref[...].astype(jnp.float32)                    # (r, 128)
    # staleness-discounted projection coefficient: the discount wgt_j
    # multiplies the adaptive scale, the geometry (coef_j) stays raw
    dt_out_ref[...] += ((wgt_ref[0] * scale_ref[0])
                        * (d - coef_ref[0] * p)) * inv_b

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        w = w_ref[...].astype(jnp.float32)
        w_out_ref[...] = (w - eta_ref[0] * dt_out_ref[...]
                          ).astype(w_out_ref.dtype)


def buffer_fold(d3: jnp.ndarray, p2: jnp.ndarray, w2: jnp.ndarray,
                coefs, scales, wgts, eta_g, *, rows: int = None,
                interpret: bool = True):
    """Staleness-weighted buffered fold over B stacked deltas.

    d3: (B, M, 128) buffered deltas; p2/w2: (M, 128) delta_prev/params;
    coefs/scales/wgts: (B,) reduction-pass scalars + staleness discounts.
    Returns (new_w2, delta_t2) like ``batched_epilogue``, but streams
    the deltas through a (blocks, B) grid with B innermost instead of
    holding all B resident — so ``rows`` stays at DEFAULT_ROWS no
    matter how large the arrival buffer grows. At wgts == 1 the math is
    ``batched_epilogue`` with mean replaced by an ordered partial-sum
    accumulation (same values up to f32 summation order).
    """
    b, m, lane = d3.shape
    assert lane == LANE, d3.shape
    rows = min(rows or DEFAULT_ROWS, m)
    while m % rows:                 # largest divisor <= target (trace-time)
        rows -= 1
    grid = (pl.cdiv(m, rows), b)    # j (deltas) innermost: dt block resident
    coefs = jnp.asarray(coefs, jnp.float32).reshape(b)
    scales = jnp.asarray(scales, jnp.float32).reshape(b)
    wgts = jnp.asarray(wgts, jnp.float32).reshape(b)
    eta = jnp.asarray(eta_g, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_buffer_fold_kernel, 1.0 / b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (j,)),    # coef_j
            pl.BlockSpec((1,), lambda i, j: (j,)),    # scale_j
            pl.BlockSpec((1,), lambda i, j: (j,)),    # staleness wgt_j
            pl.BlockSpec((1,), lambda i, j: (0,)),    # eta_g (broadcast)
            pl.BlockSpec((1, rows, LANE), lambda i, j: (j, i, 0)),
            pl.BlockSpec((rows, LANE), lambda i, j: (i, 0)),
            pl.BlockSpec((rows, LANE), lambda i, j: (i, 0)),
        ],
        # output index_maps ignore j -> blocks stay resident across the
        # inner delta loop (the scatter-accumulate idiom)
        out_specs=[pl.BlockSpec((rows, LANE), lambda i, j: (i, 0)),
                   pl.BlockSpec((rows, LANE), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, lane), w2.dtype),
                   jax.ShapeDtypeStruct((m, lane), jnp.float32)],
        interpret=interpret,
    )(coefs, scales, wgts, eta, d3, p2, w2)


def _dequant_batched_epilogue_kernel(coef_ref, scale_ref, qs_ref, qz_ref,
                                     eta_ref, q_ref, p_ref, w_ref,
                                     w_out_ref, dt_out_ref):
    """``_batched_epilogue_kernel`` with the codec dequant fused in front
    (DESIGN.md §13): the resident (K, rows, 128) block holds the
    QUANTIZED cohort (int8 or bf16 — 4x / 2x less HBM traffic and VMEM
    residency than the f32 stack), and the per-client dequant
    ``d_j = q_j * qs_j + qz_j`` runs on the VPU right before the
    residual+scale+mean, so the f32 deltas never materialize:

        dt  = mean_j scale_j * ((q_j * qs_j + qz_j) - coef_j * prev)
        w'  = w - eta_g * dt
    """
    q = q_ref[...].astype(jnp.float32)                    # (K, r, 128)
    qs = qs_ref[...].astype(jnp.float32)[:, None, None]
    qz = qz_ref[...].astype(jnp.float32)[:, None, None]
    d = q * qs + qz
    p = p_ref[...].astype(jnp.float32)                    # (r, 128)
    coef = coef_ref[...].astype(jnp.float32)[:, None, None]
    scale = scale_ref[...].astype(jnp.float32)[:, None, None]
    dt = jnp.mean(scale * (d - coef * p[None]), axis=0)
    dt_out_ref[...] = dt.astype(dt_out_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    w_out_ref[...] = (w - eta_ref[0] * dt).astype(w_out_ref.dtype)


def dequant_batched_epilogue(q3: jnp.ndarray, p2: jnp.ndarray,
                             w2: jnp.ndarray, coefs, scales, eta_g,
                             qscales, qzeros, *, rows: int = None,
                             interpret: bool = True):
    """``batched_epilogue`` over a QUANTIZED cohort stack.

    q3: (K, M, 128) int8/bf16 quantized deltas; qscales/qzeros: (K,)
    per-client dequant scalars for this leaf (repro/codec wire format:
    ``dequant(q) = q * qscale + qzero``); everything else as
    ``batched_epilogue``. The grid/blocking is identical — only the
    resident cohort block shrinks by the quantized dtype's itemsize.
    NOTE: int8's real-TPU min sublane tile is (32, 128), so the rows
    floor of 8 is an interpret-mode/bench layout; real-TPU autotuning
    stays with the carried-over ROADMAP item. Zero-padded rows dequant
    to qzero (not 0) — callers must trim outputs to the true length
    (ops.py does), exactly as they already trim the f32 path.
    """
    k, m, lane = q3.shape
    assert lane == LANE, q3.shape
    rows = min(rows or max(8, DEFAULT_ROWS // max(1, k)), m)
    while m % rows:                 # largest divisor <= target (trace-time)
        rows -= 1
    grid = (pl.cdiv(m, rows),)
    coefs = jnp.asarray(coefs, jnp.float32).reshape(k)
    scales = jnp.asarray(scales, jnp.float32).reshape(k)
    qscales = jnp.asarray(qscales, jnp.float32).reshape(k)
    qzeros = jnp.asarray(qzeros, jnp.float32).reshape(k)
    eta = jnp.asarray(eta_g, jnp.float32).reshape(1)
    return pl.pallas_call(
        _dequant_batched_epilogue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),       # coefs (broadcast)
            pl.BlockSpec((k,), lambda i: (0,)),       # scales
            pl.BlockSpec((k,), lambda i: (0,)),       # dequant scales
            pl.BlockSpec((k,), lambda i: (0,)),       # dequant zero-points
            pl.BlockSpec((1,), lambda i: (0,)),       # eta_g
            pl.BlockSpec((k, rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, lane), w2.dtype),
                   jax.ShapeDtypeStruct((m, lane), jnp.float32)],
        interpret=interpret,
    )(coefs, scales, qscales, qzeros, eta, q3, p2, w2)


def _dequant_buffer_fold_kernel(inv_b, coef_ref, scale_ref, wgt_ref,
                                qs_ref, qz_ref, eta_ref, q_ref, p_ref,
                                w_ref, w_out_ref, dt_out_ref):
    """``_buffer_fold_kernel`` with the per-arrival dequant fused in: the
    staleness discount wgt_j and the dequant scale qs_j COMPOSE as plain
    per-j scalar multipliers on the streamed block (DESIGN.md §11/§13):

        dt = (1/B) sum_j wgt_j * scale_j * ((q_j * qs_j + qz_j) - coef_j * prev)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dt_out_ref[...] = jnp.zeros_like(dt_out_ref)

    d = q_ref[0].astype(jnp.float32) * qs_ref[0] + qz_ref[0]   # (r, 128)
    p = p_ref[...].astype(jnp.float32)                          # (r, 128)
    dt_out_ref[...] += ((wgt_ref[0] * scale_ref[0])
                        * (d - coef_ref[0] * p)) * inv_b

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        w = w_ref[...].astype(jnp.float32)
        w_out_ref[...] = (w - eta_ref[0] * dt_out_ref[...]
                          ).astype(w_out_ref.dtype)


def dequant_buffer_fold(q3: jnp.ndarray, p2: jnp.ndarray, w2: jnp.ndarray,
                        coefs, scales, wgts, eta_g, qscales, qzeros, *,
                        rows: int = None, interpret: bool = True):
    """``buffer_fold`` over a QUANTIZED arrival buffer: q3 (B, M, 128)
    int8/bf16, qscales/qzeros (B,) per-arrival dequant scalars; the
    scatter-accumulate grid is unchanged (B innermost, dt resident) and
    each streamed block is dequantized on the fly."""
    b, m, lane = q3.shape
    assert lane == LANE, q3.shape
    rows = min(rows or DEFAULT_ROWS, m)
    while m % rows:                 # largest divisor <= target (trace-time)
        rows -= 1
    grid = (pl.cdiv(m, rows), b)    # j (arrivals) innermost: dt resident
    coefs = jnp.asarray(coefs, jnp.float32).reshape(b)
    scales = jnp.asarray(scales, jnp.float32).reshape(b)
    wgts = jnp.asarray(wgts, jnp.float32).reshape(b)
    qscales = jnp.asarray(qscales, jnp.float32).reshape(b)
    qzeros = jnp.asarray(qzeros, jnp.float32).reshape(b)
    eta = jnp.asarray(eta_g, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_dequant_buffer_fold_kernel, 1.0 / b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (j,)),    # coef_j
            pl.BlockSpec((1,), lambda i, j: (j,)),    # scale_j
            pl.BlockSpec((1,), lambda i, j: (j,)),    # staleness wgt_j
            pl.BlockSpec((1,), lambda i, j: (j,)),    # dequant scale_j
            pl.BlockSpec((1,), lambda i, j: (j,)),    # dequant zero_j
            pl.BlockSpec((1,), lambda i, j: (0,)),    # eta_g (broadcast)
            pl.BlockSpec((1, rows, LANE), lambda i, j: (j, i, 0)),
            pl.BlockSpec((rows, LANE), lambda i, j: (i, 0)),
            pl.BlockSpec((rows, LANE), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((rows, LANE), lambda i, j: (i, 0)),
                   pl.BlockSpec((rows, LANE), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, lane), w2.dtype),
                   jax.ShapeDtypeStruct((m, lane), jnp.float32)],
        interpret=interpret,
    )(coefs, scales, wgts, qscales, qzeros, eta, q3, p2, w2)


def _epilogue_kernel(coef_ref, scale_ref, d_ref, p_ref, out_ref):
    coef = coef_ref[0]
    scale = scale_ref[0]
    d = d_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    out_ref[...] = (scale * (d - coef * p)).astype(out_ref.dtype)


def fused_epilogue(d2: jnp.ndarray, p2: jnp.ndarray, coef, scale, *,
                   rows: int = DEFAULT_ROWS,
                   interpret: bool = True) -> jnp.ndarray:
    """out = scale * (d2 - coef * p2), one HBM pass. d2/p2: (M, 128)."""
    m = d2.shape[0]
    rows = min(rows, m)
    grid = (pl.cdiv(m, rows),)
    coef = jnp.asarray(coef, jnp.float32).reshape(1)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    return pl.pallas_call(
        _epilogue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # coef (broadcast to blocks)
            pl.BlockSpec((1,), lambda i: (0,)),  # scale
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(d2.shape, d2.dtype),
        interpret=interpret,
    )(coef, scale, d2, p2)
