"""jit'd wrappers: flat-vector and pytree entry points for the fused
FedDPC server epilogue.

``residual_scale_tree`` is what core/projection.py routes to with
use_kernel=True: per-leaf (pad to (M,128)) fused epilogue, given the
already-computed coef/scale scalars.  ``project_and_scale_flat`` is the
complete two-pass kernel path on one flat vector (used by benchmarks to
measure the fused HBM-pass structure end-to-end).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.feddpc_project import kernel as K

EPS = 1e-12


def _to_2d(x: jnp.ndarray):
    """Flatten + zero-pad to (M, 128) with M a multiple of the block rows
    (zero rows are exact no-ops for both the dots and the epilogue, and a
    full final block avoids Pallas partial-block padding semantics)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    chunk = K.LANE * min(K.DEFAULT_ROWS, max(1, (n + K.LANE - 1) // K.LANE))
    pad = (-n) % chunk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, K.LANE), n


def _from_2d(y2: jnp.ndarray, n: int, shape, dtype):
    return y2.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_dots_flat(d: jnp.ndarray, p: jnp.ndarray, interpret: bool = True):
    """-> (3,) = [<d,p>, <d,d>, <p,p>] via one fused HBM pass."""
    d2, _ = _to_2d(d)
    p2, _ = _to_2d(p)
    partials = K.fused_dots(d2, p2, interpret=interpret)
    return partials.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def guard_dots_flat(d: jnp.ndarray, p: jnp.ndarray, interpret: bool = True):
    """-> (4,) = [<d,p>, <d,d>, <p,p>, nonfinite(d)]: the reduction pass
    with the update-guard's validity column fused into the same HBM
    sweep (DESIGN.md §12). The zero padding added by ``_to_2d`` is
    finite, so it never inflates the non-finite count."""
    d2, _ = _to_2d(d)
    p2, _ = _to_2d(p)
    return K.guard_dots(d2, p2, interpret=interpret).sum(axis=0)


@functools.partial(jax.jit, static_argnames=("lam", "interpret"))
def project_and_scale_flat(d: jnp.ndarray, p: jnp.ndarray, lam: float = 1.0,
                           interpret: bool = True) -> jnp.ndarray:
    """Complete FedDPC per-client modification on a flat vector:
    pass 1 fused dots -> scalars; pass 2 fused residual+scale."""
    dots = fused_dots_flat(d, p, interpret=interpret)
    dp, dd, pp = dots[0], dots[1], dots[2]
    coef = jnp.where(pp > EPS, dp / jnp.maximum(pp, EPS), 0.0)
    # ||resid||^2 = ||d||^2 - coef^2 ||p||^2 (Pythagoras; saves a 3rd pass)
    sq_resid = jnp.maximum(dd - coef * coef * pp, 0.0)
    scale = lam + jnp.sqrt(dd) / jnp.maximum(jnp.sqrt(sq_resid), EPS)
    d2, n = _to_2d(d)
    p2, _ = _to_2d(p)
    out2 = K.fused_epilogue(d2, p2, coef, scale, interpret=interpret)
    return _from_2d(out2, n, d.shape, d.dtype)


def _to_2d_batched(x: jnp.ndarray, rows: int):
    """(K, ...) -> (K, M, 128) with M a multiple of `rows` (full blocks;
    zero padding is an exact no-op for the epilogue: dt == 0 there)."""
    k = x.shape[0]
    flat = x.reshape(k, -1)
    n = flat.shape[1]
    chunk = K.LANE * rows
    pad = (-n) % chunk
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(k, -1, K.LANE), n


def batched_server_epilogue(deltas, delta_prev, params, coefs, scales,
                            eta_g, interpret: bool = None):
    """Whole-cohort FedDPC server epilogue (kernel.batched_epilogue per
    leaf): deltas client-stacked (K, ...), delta_prev/params plain trees,
    coefs/scales (K,) from the reduction pass. Returns
    (new_params, delta_t) in one fused HBM pass over the stacked deltas —
    the use_kernel=True route of core/feddpc.server_step. delta_t comes
    back f32 regardless of input dtypes (it is server STATE — matching
    the jnp path's f32 accumulation). interpret=None auto-selects: real
    kernel on TPU, interpret mode elsewhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    flat_d, treedef = jax.tree_util.tree_flatten(deltas)
    flat_p = jax.tree.leaves(delta_prev)
    flat_w = jax.tree.leaves(params)
    new_w, new_dt = [], []
    for d, p, w in zip(flat_d, flat_p, flat_w):
        k = d.shape[0]
        rows = max(8, K.DEFAULT_ROWS // max(1, k))
        d3, n = _to_2d_batched(d, rows)
        rows = min(rows, d3.shape[1])
        p2 = jnp.pad(p.reshape(-1), (0, d3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w2 = jnp.pad(w.reshape(-1), (0, d3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w_out2, dt2 = K.batched_epilogue(d3, p2, w2, coefs, scales, eta_g,
                                         rows=rows, interpret=interpret)
        new_w.append(_from_2d(w_out2, n, w.shape, w.dtype))
        new_dt.append(_from_2d(dt2, n, p.shape, jnp.float32))
    return (jax.tree_util.tree_unflatten(treedef, new_w),
            jax.tree_util.tree_unflatten(treedef, new_dt))


def buffered_server_fold(deltas, delta_prev, params, coefs, scales,
                         weights, eta_g, interpret: bool = None):
    """Staleness-weighted buffered-async server fold (kernel.buffer_fold
    per leaf, DESIGN.md §11): deltas is the ARRIVAL BUFFER stacked
    (B, ...), coefs/scales (B,) from the reduction pass, weights (B,)
    the (1+s)^(-alpha) staleness discounts. Returns (new_params,
    delta_t) like ``batched_server_epilogue``, but the scatter-
    accumulate grid streams the buffered deltas one at a time, so the
    row block stays at DEFAULT_ROWS regardless of the buffer size
    (batched_epilogue's K-resident block would shrink as B grows).
    interpret=None auto-selects: real kernel on TPU, interpret mode
    elsewhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    flat_d, treedef = jax.tree_util.tree_flatten(deltas)
    flat_p = jax.tree.leaves(delta_prev)
    flat_w = jax.tree.leaves(params)
    new_w, new_dt = [], []
    for d, p, w in zip(flat_d, flat_p, flat_w):
        d3, n = _to_2d_batched(d, K.DEFAULT_ROWS)
        rows = min(K.DEFAULT_ROWS, d3.shape[1])
        p2 = jnp.pad(p.reshape(-1), (0, d3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w2 = jnp.pad(w.reshape(-1), (0, d3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w_out2, dt2 = K.buffer_fold(d3, p2, w2, coefs, scales, weights,
                                    eta_g, rows=rows, interpret=interpret)
        new_w.append(_from_2d(w_out2, n, w.shape, w.dtype))
        new_dt.append(_from_2d(dt2, n, p.shape, jnp.float32))
    return (jax.tree_util.tree_unflatten(treedef, new_w),
            jax.tree_util.tree_unflatten(treedef, new_dt))


def dequant_batched_server_epilogue(payload, delta_prev, params, coefs,
                                    scales, eta_g, interpret: bool = None):
    """``batched_server_epilogue`` fed the codec's QUANTIZED cohort
    payload (``{"q", "scale", "zero"}`` per-leaf trees, repro/codec wire
    format) instead of the f32 delta stack: the per-leaf dequant fuses
    into the epilogue grid (kernel.dequant_batched_epilogue), so the
    cohort's HBM sweep reads int8/bf16 blocks. Padded tails dequant to
    the zero-point, which is trimmed away exactly like the f32 path's
    zero padding."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    flat_q, treedef = jax.tree_util.tree_flatten(payload["q"])
    flat_qs = jax.tree.leaves(payload["scale"])
    flat_qz = jax.tree.leaves(payload["zero"])
    flat_p = jax.tree.leaves(delta_prev)
    flat_w = jax.tree.leaves(params)
    new_w, new_dt = [], []
    for q, qs, qz, p, w in zip(flat_q, flat_qs, flat_qz, flat_p, flat_w):
        k = q.shape[0]
        rows = max(8, K.DEFAULT_ROWS // max(1, k))
        q3, n = _to_2d_batched(q, rows)
        rows = min(rows, q3.shape[1])
        p2 = jnp.pad(p.reshape(-1), (0, q3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w2 = jnp.pad(w.reshape(-1), (0, q3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w_out2, dt2 = K.dequant_batched_epilogue(
            q3, p2, w2, coefs, scales, eta_g, qs, qz,
            rows=rows, interpret=interpret)
        new_w.append(_from_2d(w_out2, n, w.shape, w.dtype))
        new_dt.append(_from_2d(dt2, n, p.shape, jnp.float32))
    return (jax.tree_util.tree_unflatten(treedef, new_w),
            jax.tree_util.tree_unflatten(treedef, new_dt))


def dequant_buffered_server_fold(payload, delta_prev, params, coefs,
                                 scales, weights, eta_g,
                                 interpret: bool = None):
    """``buffered_server_fold`` fed the codec's quantized arrival-buffer
    payload: the per-arrival dequant fuses into the scatter-accumulate
    stream (kernel.dequant_buffer_fold), staleness discounts composing
    with the dequant scales as per-arrival scalars."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    flat_q, treedef = jax.tree_util.tree_flatten(payload["q"])
    flat_qs = jax.tree.leaves(payload["scale"])
    flat_qz = jax.tree.leaves(payload["zero"])
    flat_p = jax.tree.leaves(delta_prev)
    flat_w = jax.tree.leaves(params)
    new_w, new_dt = [], []
    for q, qs, qz, p, w in zip(flat_q, flat_qs, flat_qz, flat_p, flat_w):
        q3, n = _to_2d_batched(q, K.DEFAULT_ROWS)
        rows = min(K.DEFAULT_ROWS, q3.shape[1])
        p2 = jnp.pad(p.reshape(-1), (0, q3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w2 = jnp.pad(w.reshape(-1), (0, q3.shape[1] * K.LANE - n)
                     ).reshape(-1, K.LANE)
        w_out2, dt2 = K.dequant_buffer_fold(
            q3, p2, w2, coefs, scales, weights, eta_g, qs, qz,
            rows=rows, interpret=interpret)
        new_w.append(_from_2d(w_out2, n, w.shape, w.dtype))
        new_dt.append(_from_2d(dt2, n, p.shape, jnp.float32))
    return (jax.tree_util.tree_unflatten(treedef, new_w),
            jax.tree_util.tree_unflatten(treedef, new_dt))


def residual_scale_tree(delta, delta_prev, coef, scale, interpret: bool = True):
    """Per-leaf fused epilogue with precomputed scalars (pytree entry used
    by core/projection.project_and_scale(use_kernel=True))."""
    def one(d, p):
        d2, n = _to_2d(d)
        p2, _ = _to_2d(p)
        out2 = K.fused_epilogue(d2, p2, coef, scale, interpret=interpret)
        return _from_2d(out2, n, d.shape, d.dtype)

    return jax.tree.map(one, delta, delta_prev)
