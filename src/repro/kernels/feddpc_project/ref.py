"""Pure-jnp oracle for the FedDPC projection/scaling epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dots_ref(d2: jnp.ndarray, p2: jnp.ndarray) -> jnp.ndarray:
    """-> (3,) = [<d,p>, <d,d>, <p,p>] in f32."""
    df = d2.astype(jnp.float32)
    pf = p2.astype(jnp.float32)
    return jnp.stack([jnp.sum(df * pf), jnp.sum(df * df), jnp.sum(pf * pf)])


def epilogue_ref(d2, p2, coef, scale):
    return (scale * (d2.astype(jnp.float32)
                     - coef * p2.astype(jnp.float32))).astype(d2.dtype)


def project_and_scale_flat_ref(d: jnp.ndarray, p: jnp.ndarray, lam: float,
                               eps: float = 1e-12):
    """Whole FedDPC per-client modification on a FLAT vector (oracle for
    ops.project_and_scale_flat)."""
    df, pf = d.astype(jnp.float32), p.astype(jnp.float32)
    dp = jnp.vdot(df, pf)
    pp = jnp.vdot(pf, pf)
    coef = jnp.where(pp > eps, dp / jnp.maximum(pp, eps), 0.0)
    resid = df - coef * pf
    norm_d = jnp.linalg.norm(df)
    norm_r = jnp.linalg.norm(resid)
    scale = lam + norm_d / jnp.maximum(norm_r, eps)
    return (scale * resid).astype(d.dtype)
