"""Pure-jnp oracle for the FedDPC projection/scaling epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dots_ref(d2: jnp.ndarray, p2: jnp.ndarray) -> jnp.ndarray:
    """-> (3,) = [<d,p>, <d,d>, <p,p>] in f32."""
    df = d2.astype(jnp.float32)
    pf = p2.astype(jnp.float32)
    return jnp.stack([jnp.sum(df * pf), jnp.sum(df * df), jnp.sum(pf * pf)])


def guard_dots_ref(d2: jnp.ndarray, p2: jnp.ndarray) -> jnp.ndarray:
    """-> (4,) = [<d,p>, <d,d>, <p,p>, nonfinite(d)] with non-finite
    entries of d zeroed before the dots (oracle for kernel.guard_dots)."""
    df = d2.astype(jnp.float32)
    pf = p2.astype(jnp.float32)
    finite = jnp.isfinite(df)
    dz = jnp.where(finite, df, 0.0)
    return jnp.stack([jnp.sum(dz * pf), jnp.sum(dz * dz), jnp.sum(pf * pf),
                      jnp.sum((~finite).astype(jnp.float32))])


def epilogue_ref(d2, p2, coef, scale):
    return (scale * (d2.astype(jnp.float32)
                     - coef * p2.astype(jnp.float32))).astype(d2.dtype)


def batched_epilogue_ref(d3, p2, w2, coefs, scales, eta_g):
    """Oracle for kernel.batched_epilogue: d3 (K, M, 128) stacked deltas,
    p2/w2 (M, 128) -> (new_w2, delta_t2)."""
    df = d3.astype(jnp.float32)
    pf = p2.astype(jnp.float32)
    c = jnp.asarray(coefs, jnp.float32)[:, None, None]
    s = jnp.asarray(scales, jnp.float32)[:, None, None]
    dt = jnp.mean(s * (df - c * pf[None]), axis=0)
    new_w = (w2.astype(jnp.float32)
             - jnp.asarray(eta_g, jnp.float32) * dt).astype(w2.dtype)
    return new_w, dt                    # delta_t stays f32 (server state)


def buffer_fold_ref(d3, p2, w2, coefs, scales, wgts, eta_g):
    """Oracle for kernel.buffer_fold: the staleness discount multiplies
    the adaptive scale (geometry stays raw), then the math IS the
    batched epilogue with effective scales wgts * scales."""
    s = jnp.asarray(scales, jnp.float32) * jnp.asarray(wgts, jnp.float32)
    return batched_epilogue_ref(d3, p2, w2, coefs, s, eta_g)


def dequant_ref(q3, qscales, qzeros):
    """repro/codec wire-format dequant on a (K, M, 128) quantized stack:
    ``q * qscale + qzero`` with per-client (K,) scalars, in f32."""
    qs = jnp.asarray(qscales, jnp.float32)[:, None, None]
    qz = jnp.asarray(qzeros, jnp.float32)[:, None, None]
    return q3.astype(jnp.float32) * qs + qz


def dequant_batched_epilogue_ref(q3, p2, w2, coefs, scales, eta_g,
                                 qscales, qzeros):
    """Oracle for kernel.dequant_batched_epilogue: dequantize the stack,
    then the math IS the batched epilogue."""
    return batched_epilogue_ref(dequant_ref(q3, qscales, qzeros),
                                p2, w2, coefs, scales, eta_g)


def dequant_buffer_fold_ref(q3, p2, w2, coefs, scales, wgts, eta_g,
                            qscales, qzeros):
    """Oracle for kernel.dequant_buffer_fold: dequant then the
    staleness-weighted fold (discount composes with the dequant scale as
    plain per-arrival multipliers)."""
    return buffer_fold_ref(dequant_ref(q3, qscales, qzeros),
                           p2, w2, coefs, scales, wgts, eta_g)


def project_and_scale_flat_ref(d: jnp.ndarray, p: jnp.ndarray, lam: float,
                               eps: float = 1e-12):
    """Whole FedDPC per-client modification on a FLAT vector (oracle for
    ops.project_and_scale_flat)."""
    df, pf = d.astype(jnp.float32), p.astype(jnp.float32)
    dp = jnp.vdot(df, pf)
    pp = jnp.vdot(pf, pf)
    coef = jnp.where(pp > eps, dp / jnp.maximum(pp, eps), 0.0)
    resid = df - coef * pf
    norm_d = jnp.linalg.norm(df)
    norm_r = jnp.linalg.norm(resid)
    scale = lam + norm_d / jnp.maximum(norm_r, eps)
    return (scale * resid).astype(d.dtype)
