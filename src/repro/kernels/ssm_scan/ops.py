"""jit'd wrapper for the Mamba selective-scan kernel: pads S to the time
chunk and D_in to the channel block, runs the kernel, unpads."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import kernel as K


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan(u, dt, b, c, a, d_skip, interpret: bool = True):
    bsz, s, d_in = u.shape
    ts = min(K.TS, max(8, s))
    blk = min(K.BLK_D, d_in)
    ps = (-s) % ts
    pd = (-d_in) % blk
    if ps or pd:
        u = jnp.pad(u, ((0, 0), (0, ps), (0, pd)))
        dt = jnp.pad(dt, ((0, 0), (0, ps), (0, pd)))
        b = jnp.pad(b, ((0, 0), (0, ps), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, ps), (0, 0)))
    if pd:
        a = jnp.pad(a, ((0, pd), (0, 0)))
        d_skip = jnp.pad(d_skip, (0, pd))
    y, h = K.ssm_scan(u, dt, b, c, a, d_skip, ts=ts, blk_d=blk,
                      interpret=interpret)
    return y[:, :s, :d_in], h[:, :d_in, :]
