"""Pure-jnp oracle for the selective scan (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, b, c, a, d_skip):
    """Same contract as kernel.ssm_scan. Straight lax.scan over time."""
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        decay = jnp.exp(dt_t[..., None] * a)             # (B, D, N)
        h = h * decay + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = (h * c_t[:, None, :]).sum(-1) + d_skip * u_t
        return h, y

    bsz, s, d_in = u.shape
    n = b.shape[-1]
    h0 = jnp.zeros((bsz, d_in, n), jnp.float32)
    xs = (uf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          b.swapaxes(0, 1), c.swapaxes(0, 1))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype), h_fin
