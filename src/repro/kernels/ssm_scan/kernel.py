"""Mamba-1 selective-scan Pallas kernel (TPU target).

Recurrence per channel c, state n:
    h_t[c,n] = exp(dt_t[c] * A[c,n]) * h_{t-1}[c,n] + dt_t[c] * B_t[n] * u_t[c]
    y_t[c]   = sum_n C_t[n] * h_t[c,n] + D[c] * u_t[c]

TPU adaptation (DESIGN.md §2): the GPU Mamba kernel is a warp-level scan
over time held in registers/shared memory; the TPU analogue keeps the
running state h (BLK_D x N) resident in VMEM SCRATCH across sequential
time-chunk grid steps, processing TS timesteps per grid step with a
fori_loop. Grid = (batch, d_blocks, time_chunks) with time INNERMOST
(sequential, "arbitrary" semantics); channel blocks BLK_D are
lane-aligned (128). State dim N (=16) stays in the sublane dimension.

This trades the associative-scan's O(S log S) elementwise work (the pure
jnp lowering in models/ssm.py) for a single O(S) pass with zero HBM
traffic for h — the structural win on TPU where the scan state would
otherwise round-trip to HBM between layers of the log-tree.

Validated against ref.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK_D = 128          # channel block (lanes)
TS = 64              # timesteps per grid step


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref,
                y_ref, hout_ref, h_scr, *, nt: int, ts: int):
    t_i = pl.program_id(2)

    @pl.when(t_i == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)            # (BLK_D, N)
    dskip = dskip_ref[...].astype(jnp.float32)    # (BLK_D,)

    def step(i, h):
        u_t = u_ref[0, i, :].astype(jnp.float32)          # (BLK_D,)
        dt_t = dt_ref[0, i, :].astype(jnp.float32)        # (BLK_D,)
        b_t = b_ref[0, i, :].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, i, :].astype(jnp.float32)          # (N,)
        decay = jnp.exp(dt_t[:, None] * a)                # (BLK_D, N)
        h = h * decay + (dt_t * u_t)[:, None] * b_t[None, :]
        y = (h * c_t[None, :]).sum(axis=1) + dskip * u_t  # (BLK_D,)
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, ts, step, h_scr[...])
    h_scr[...] = h

    @pl.when(t_i == nt - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


def ssm_scan(u, dt, b, c, a, d_skip, *, ts: int = TS, blk_d: int = BLK_D,
             interpret: bool = True):
    """u/dt: (B, S, D_in) — u post-conv/silu, dt post-softplus (f32).
    b/c: (B, S, N) f32. a: (D_in, N) f32 (A = a, already negative).
    d_skip: (D_in,) f32. Returns (y (B, S, D_in), h_final (B, D_in, N) f32).

    S % ts == 0 and D_in % blk_d == 0 required (ops.py pads).
    """
    bsz, s, d_in = u.shape
    n = b.shape[-1]
    ts = min(ts, s)
    blk_d = min(blk_d, d_in)
    nt = s // ts
    nd = d_in // blk_d
    grid = (bsz, nd, nt)

    kernel = functools.partial(_ssm_kernel, nt=nt, ts=ts)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ts, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, ts, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, ts, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, ts, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((blk_d, n), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((blk_d,), lambda bi, di, ti: (di,)),
        ],
        out_specs=[
            pl.BlockSpec((1, ts, blk_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, blk_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(u.shape, u.dtype),
            jax.ShapeDtypeStruct((bsz, d_in, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((blk_d, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, b, c, a, d_skip)
    return y, h_fin
