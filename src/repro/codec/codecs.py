"""The shipped codecs: identity (pass-through), bf16, int8.

All lossy codecs emit the uniform ``{"q", "scale", "zero"}`` payload of
repro/codec/base.py with per-leaf, per-client scalars, so the decode —
``q.astype(f32) * scale + zero`` — is one expression shared with the
fused Pallas dequant epilogue (kernels/feddpc_project).

int8 wire format (per leaf, per client): affine by default —
``scale = (max - min) / 254``, ``zero = min + 127 * scale``, codes in
[-127, 127]; ``symmetric=True`` drops the zero-point
(``scale = max|x| / 127``, ``zero = 0``); ``stochastic=True`` (symmetric
only) rounds with ``floor(y + u)``, u ~ U[0,1) — unbiased, key-driven.
Zero-range leaves flatten scale to 1 (codes are all zero, decode is
exact); nonfinite leaves keep nonfinite scales so the guard still sees
them after decode (base.py contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import base
from repro.codec.base import DeltaCodec, register_codec, tree_nbytes

PyTree = object


@register_codec("identity")
class IdentityCodec(DeltaCodec):
    """Pass-through: encode/decode return the SAME pytree (no casts, no
    wrapper), so codec=identity rounds are bitwise the no-codec rounds."""

    name = "identity"
    lossy = False

    def encode_cohort(self, stacked, *, key=None):
        return stacked

    def decode_cohort(self, payload):
        return payload

    def encode(self, tree, *, key=None):
        return tree

    def decode(self, payload):
        return payload

    def client_bytes(self, template):
        return tree_nbytes(template)

    def encoded_template(self, template, clients):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((clients,) + tuple(x.shape),
                                           jnp.float32), template)


def _broadcast(v, ndim):
    """(K,) per-client scalars -> (K, 1, ..., 1) against a (K, ...) leaf."""
    return v.reshape(v.shape + (1,) * (ndim - 1))


def _decode_leaf(q, scale, zero):
    return (q.astype(jnp.float32) * _broadcast(scale, q.ndim)
            + _broadcast(zero, q.ndim))


class _QuantCodec(DeltaCodec):
    """Shared cohort plumbing: per-leaf encode over the payload dict."""

    def _encode_leaf(self, x, key):
        raise NotImplementedError

    def encode_cohort(self, stacked, *, key=None):
        if self.stochastic and key is None:
            raise ValueError(
                f"codec {self.name!r} rounds stochastically and needs an "
                "explicit PRNG key per encode (pass key=)")
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        qs, ss, zs = [], [], []
        for i, leaf in enumerate(leaves):
            lk = None if key is None else jax.random.fold_in(key, i)
            q, s, z = self._encode_leaf(leaf, lk)
            qs.append(q), ss.append(s), zs.append(z)
        unflat = jax.tree_util.tree_unflatten
        return {"q": unflat(treedef, qs), "scale": unflat(treedef, ss),
                "zero": unflat(treedef, zs)}

    def decode_cohort(self, payload):
        return jax.tree.map(_decode_leaf, payload["q"], payload["scale"],
                            payload["zero"])


@register_codec("bf16")
class BF16Codec(_QuantCodec):
    """bfloat16 round-to-nearest-even, unit scales: halves uplink bytes
    with ~2^-8 relative error; bf16->f32 decode is exact."""

    name = "bf16"
    lossy = True
    _itemsize = 2

    def _encode_leaf(self, x, key):
        k = x.shape[0]
        q = x.astype(jnp.float32).astype(jnp.bfloat16)
        return q, jnp.ones((k,), jnp.float32), jnp.zeros((k,), jnp.float32)

    def client_bytes(self, template):
        return _payload_bytes(template, self._itemsize)


def _payload_bytes(template, itemsize: int) -> int:
    """q bytes + (scale, zero) f32 scalars per leaf."""
    leaves = jax.tree.leaves(template)
    elems = sum(int(np.prod(tuple(x.shape), dtype=np.int64))
                for x in leaves)
    return elems * itemsize + 8 * len(leaves)


@register_codec("int8")
class Int8Codec(_QuantCodec):
    """int8 with per-leaf/per-client scales and zero-points (affine by
    default); see module docstring for the wire format."""

    name = "int8"
    lossy = True
    _itemsize = 1

    def __init__(self, symmetric: bool = False, stochastic: bool = False):
        if stochastic and not symmetric:
            raise ValueError("stochastic rounding is the symmetric "
                             "option (int8_sr); affine int8 rounds to "
                             "nearest")
        self.symmetric = symmetric
        self.stochastic = stochastic
        self.name = ("int8_sr" if stochastic
                     else "int8_sym" if symmetric else "int8")

    def _encode_leaf(self, x, key):
        x = x.astype(jnp.float32)
        red = tuple(range(1, x.ndim))
        if self.symmetric:
            amax = jnp.max(jnp.abs(x), axis=red) if red else jnp.abs(x)
            scale = amax / 127.0
            zero = jnp.zeros_like(scale)
        else:
            mn = jnp.min(x, axis=red) if red else x
            mx = jnp.max(x, axis=red) if red else x
            scale = (mx - mn) / 254.0
            zero = mn + 127.0 * scale
        # zero ranges -> unit scale (codes all 0, decode exact); the
        # where() keeps NaN/Inf scales so nonfinite rows survive decode
        scale = jnp.where(scale <= 0.0, jnp.ones_like(scale), scale)
        y = (x - _broadcast(zero, x.ndim)) / _broadcast(scale, x.ndim)
        if self.stochastic:
            y = jnp.floor(y + jax.random.uniform(key, y.shape))
        else:
            y = jnp.round(y)
        q = jnp.clip(y, -127.0, 127.0).astype(jnp.int8)
        return q, scale, zero

    def client_bytes(self, template):
        return _payload_bytes(template, self._itemsize)

    def config_dict(self):
        return {"name": self.name, "symmetric": self.symmetric,
                "stochastic": self.stochastic}


register_codec("int8_sym")(lambda: Int8Codec(symmetric=True))
register_codec("int8_sr")(lambda: Int8Codec(symmetric=True,
                                            stochastic=True))

base._refresh_names()
