"""repro.codec — communication-compressed client deltas (DESIGN.md §13).

A ``DeltaCodec`` sits between local training and aggregation: clients
quantize their update pytrees (the uplink payload), the server decodes —
or feeds the quantized stack straight into the fused dequant→project
Pallas epilogue — and an optional server-side error-feedback accumulator
re-injects the quantization error so lossy codecs do not bias the run.

Codecs register by name (``identity`` / ``bf16`` / ``int8`` /
``int8_sym`` / ``int8_sr``); ``make_codec(name)`` builds one,
``codec_names()`` enumerates the registry (the cross-regime matrix and
the CLI resolve names through it).
"""
from repro.codec.base import (DeltaCodec, EncodedCohort, codec_names,
                              make_codec, register_codec, tree_nbytes)
from repro.codec.codecs import BF16Codec, IdentityCodec, Int8Codec

# snapshot AFTER the built-in codecs above have registered (base's own
# module attribute predates them); codec_names() is always live
CODEC_NAMES = codec_names()

__all__ = [
    "BF16Codec", "CODEC_NAMES", "DeltaCodec", "EncodedCohort",
    "IdentityCodec", "Int8Codec", "codec_names", "make_codec",
    "register_codec", "tree_nbytes",
]
