"""DeltaCodec protocol, registry, and the EncodedCohort wire container.

A codec maps a client's update pytree (f32 leaves) to a compact payload
pytree and back.  The canonical lossy payload is a dict

    {"q": <quantized leaves>, "scale": <per-leaf scales>,
     "zero": <per-leaf zero-points>}

where cohort-stacked encodes carry a leading client axis on ``q`` and a
``(K,)`` vector per leaf for ``scale``/``zero`` — ONE uniform wire
format, so decode, sharding specs, buffered-async stacking, checkpoint
templates, and the fused dequant→project kernel all share a single code
path (``dequant(x) = q.astype(f32) * scale + zero``; bf16 rides the same
format with unit scales).  The identity codec passes trees through
untouched — its round output is bitwise the no-codec round.

Nonfinite propagation contract: a NaN/Inf client delta must still look
nonfinite AFTER decode, so the chaos ``UpdateGuard`` (quarantine
decisions read quantized-domain norms, DESIGN.md §12/§13) can still see
it.  Quantizers therefore keep nonfinite scales instead of flushing
them to 1 — only exact-zero ranges flatten.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_nbytes(tree: PyTree) -> int:
    """Wire bytes of a pytree: sum of ``size * itemsize`` over leaves
    (works on jnp/np arrays and ShapeDtypeStructs alike)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = tuple(getattr(leaf, "shape", ()))
        dtype = np.dtype(getattr(leaf, "dtype", np.float32))
        total += int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return total


def sanitized_residual(raw: PyTree, dec: PyTree) -> PyTree:
    """Per-element quantization residual ``raw - dec`` with nonfinite
    entries zeroed: fault-injected NaN/Inf deltas must not poison the
    error-feedback accumulator (the guard quarantines those rows; EF
    only ever tracks well-formed quantization error)."""
    def _r(x, d):
        r = x.astype(jnp.float32) - d.astype(jnp.float32)
        return jnp.where(jnp.isfinite(r), r, jnp.zeros_like(r))
    return jax.tree.map(_r, raw, dec)


@dataclasses.dataclass
class EncodedCohort:
    """A cohort's encoded uplink: the quantized payload pytree plus the
    codec name and client count it was encoded under.  The API-boundary
    container (ingest staging, benchmarks); jit-internal paths move the
    raw ``payload`` pytree."""
    codec: str
    payload: PyTree
    clients: int

    @property
    def nbytes(self) -> int:
        return tree_nbytes(self.payload)


class DeltaCodec:
    """Base codec interface.  Subclasses set ``name``/``lossy`` and
    implement the cohort-stacked encode/decode (leading client axis K);
    single-delta encode/decode default to the K=1 cohort path."""

    name: str = "abstract"
    lossy: bool = False
    stochastic: bool = False

    # -------- cohort (leading client axis) --------
    def encode_cohort(self, stacked: PyTree, *,
                      key: Optional[jax.Array] = None) -> PyTree:
        raise NotImplementedError

    def decode_cohort(self, payload: PyTree) -> PyTree:
        raise NotImplementedError

    # -------- single delta --------
    def encode(self, tree: PyTree, *,
               key: Optional[jax.Array] = None) -> PyTree:
        stacked = jax.tree.map(lambda x: x[None], tree)
        payload = self.encode_cohort(stacked, key=key)
        return jax.tree.map(lambda x: x[0], payload)

    def decode(self, payload: PyTree) -> PyTree:
        stacked = jax.tree.map(lambda x: x[None], payload)
        dec = self.decode_cohort(stacked)
        return jax.tree.map(lambda x: x[0], dec)

    # -------- accounting / templates --------
    def client_bytes(self, template: PyTree) -> int:
        """Uplink wire bytes ONE client pays per round for a delta
        shaped like ``template`` (payload arrays as actually shipped)."""
        raise NotImplementedError

    def encoded_template(self, template: PyTree, clients: int) -> PyTree:
        """ShapeDtypeStruct pytree of ``encode_cohort`` output for a
        K=``clients`` stack of ``template``-shaped deltas (checkpoint
        restore + sharding-spec construction)."""
        stacked = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((clients,) + tuple(x.shape),
                                           jnp.float32), template)
        key = jax.random.PRNGKey(0) if self.stochastic else None
        return jax.eval_shape(
            lambda s: self.encode_cohort(s, key=key), stacked)

    def config_dict(self) -> Dict[str, Any]:
        return {"name": self.name}


# ---------------- registry ----------------

_REGISTRY: Dict[str, Callable[[], DeltaCodec]] = {}


def register_codec(name: str):
    """Decorator: ``@register_codec("mycodec")`` over a zero-arg factory
    (or codec class) adds it to the name registry."""
    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"codec {name!r} already registered")
        _REGISTRY[name] = factory
        return factory
    return deco


def make_codec(name: Optional[str]) -> Optional[DeltaCodec]:
    """Build a codec by registry name; None/"" -> None (codec off)."""
    if not name:
        return None
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown codec {name!r}; registered: "
                         f"{', '.join(sorted(_REGISTRY))}") from None
    return factory()


def codec_names():
    return tuple(sorted(_REGISTRY))


# populated by repro.codec.codecs at import; kept as a module attribute
# so launch/bench argparse choices can reference a stable tuple
CODEC_NAMES = ()


def _refresh_names():
    global CODEC_NAMES
    CODEC_NAMES = codec_names()
