"""Pluggable client data sources — the READ stage of the ingest pipeline
(DESIGN.md §10; moved here from the retired core/datasources.py).

``DataSource`` replaces the bare ``batch_fn(client, round) -> list``
callable the trainer historically took: a source yields one client's
minibatches for one round, and the pipeline materializes them ON THE
INGEST PATH — with prefetching on, that is the staging ring's producer
thread, so a source backed by disk/host IO overlaps device compute for
free instead of forcing callers to pre-materialize lists.

Protocol:

    source.client_batches(client, round) -> iterable of batch pytrees
        (numpy leaves; every batch of a client/round has the same
        shapes, and shapes are shared across clients so cohorts stack)
    source.close()    release any underlying readers (optional)

Sources are CALLER-owned: sweeps share one source across many trainers
(benchmarks/common.py), so ``FederatedTrainer.close()`` never calls
``source.close()`` — close it yourself when the last trainer is done.

``ListDataSource`` adapts the legacy callable signature verbatim.
``IteratorDataSource`` wraps any ``iter_fn(client, round)`` generator
factory; sources with their own state (ingest.images.
StreamingImageSource, the disk-backed readers in ingest.datasets)
subclass ``DataSource`` directly instead.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, List

Batch = Any


class DataSource:
    """Protocol + base class: subclass and implement ``client_batches``."""

    def client_batches(self, client: int, round: int) -> Iterable[Batch]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class ListDataSource(DataSource):
    """Adapter for the legacy ``batch_fn(client, round) -> list`` shape —
    the old trainer signature spelled as a source."""

    def __init__(self, batch_fn: Callable[[int, int], List[Batch]]):
        self.batch_fn = batch_fn

    def client_batches(self, client, round):
        return self.batch_fn(client, round)


class IteratorDataSource(DataSource):
    """Streaming source: ``iter_fn(client, round)`` returns a fresh
    iterator/generator whose items materialize lazily as the ingest path
    consumes them (inside the staging thread when prefetching is on)."""

    def __init__(self, iter_fn: Callable[[int, int], Iterable[Batch]]):
        self.iter_fn = iter_fn

    def client_batches(self, client, round):
        return self.iter_fn(client, round)


def as_data_source(obj) -> DataSource:
    """Coerce the trainer's ``data`` argument: a ``DataSource`` passes
    through; a bare callable (the legacy ``batch_fn``) is wrapped."""
    if isinstance(obj, DataSource):
        return obj
    if callable(obj):
        return ListDataSource(obj)
    raise TypeError(f"expected a DataSource or a batch_fn callable, "
                    f"got {type(obj).__name__}")
