"""Depth-N staging ring for cohort ingest (DESIGN.md §10; the depth-2
special case is the double-buffered prefetcher of DESIGN.md §2, moved
here from core/client.py).

A daemon thread runs ``produce_fn(t, slot)`` for t = start..end-1 IN
ROUND ORDER (so RNG-driven client sampling inside it draws the exact
same sequence as the blocking path), staging upcoming rounds into free
ring slots while the current round's program runs on device. With
``slots=N`` the producer runs up to N rounds ahead of the oldest
unreleased slot and never overwrites a buffer a round may still be
reading: the consumer releases a slot only after it has synchronized on
that round's results (on CPU backends a device-placed value may alias
the slot's host buffer — see ingest/placement.py — so placement alone
never frees a slot).

    item, slot = ring.get(t)   # blocks only until round t is staged
    ... dispatch + sync ...
    ring.release(slot)

``end=None`` runs the producer unbounded — the buffered-async engine
(core/async_engine.py) dispatches a dynamic number of waves, so the
horizon is open until ``stop()``; backpressure still comes from the
``slots`` free-list, so "unbounded" never stages more than ``slots``
rounds ahead.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
import traceback
from typing import Optional


class CohortPrefetcher:
    """Ring of ``slots`` staging buffers filled in round order by a
    producer thread; ``slots=2`` double-buffers (the historical
    default), ``slots=1`` single-buffers (the producer still runs off
    the consumer thread, but can only work ahead while the consumer
    holds nothing — useful as the degenerate point of depth sweeps).

    ``stall_timeout`` (seconds, None = wait forever) bounds how long a
    ``get`` waits for a LIVE producer: a producer thread hung inside
    ``produce_fn`` (slow disk read, deadlocked source) previously spun
    the consumer forever — the 1s poll only escaped on a *dead* thread.
    With a deadline the consumer raises, naming the stuck round, so the
    caller can surface the hang instead of inheriting it.

    ``max_restarts`` supervises the producer (DESIGN.md §12): a
    ``produce_fn`` raise is retried for the SAME round up to
    ``max_restarts`` times total across the ring's lifetime, with
    bounded exponential backoff (``restart_backoff * 2**attempt``)
    between tries; ``restart_count`` tallies the recoveries. Past the
    budget the failure propagates exactly as before: the consumer's
    next ``get`` raises, carrying the producer's traceback text."""

    def __init__(self, produce_fn, start: int, end: Optional[int],
                 slots: int = 2, stall_timeout: Optional[float] = None,
                 max_restarts: int = 0, restart_backoff: float = 0.0):
        self._end = end
        self._stall_timeout = stall_timeout
        self._max_restarts = max(0, int(max_restarts))
        self._restart_backoff = float(restart_backoff)
        self.restart_count = 0
        # restarts keyed by the STAGED round whose produce_fn crashed —
        # the producer runs ahead of consumption, so a cumulative count
        # alone would let the consumer charge a crash during round t+1's
        # staging to whatever round happened to be observing (the round-
        # attribution bug of DESIGN.md §12's accounting)
        self.restart_rounds: dict = {}
        self._ready = queue.Queue()
        self._free = queue.Queue()
        self.slots = max(1, slots)
        for _ in range(self.slots):
            self._free.put({})
        self._exc = None
        self._exc_tb = None             # producer traceback text, for get()
        self._stopped = False
        self._thread = threading.Thread(
            target=self._loop, args=(produce_fn, start, end), daemon=True,
            name="cohort-prefetch")
        self._thread.start()

    def _loop(self, produce_fn, start, end):
        rounds = (range(start, end) if end is not None
                  else itertools.count(start))
        try:
            for t in rounds:
                slot = self._free.get()
                if slot is None:        # stop() sentinel
                    return
                item = self._produce_supervised(produce_fn, t, slot)
                self._ready.put((t, item, slot))
        except BaseException as e:      # surfaced on the next get()
            self._exc = e
            self._exc_tb = traceback.format_exc()
            self._ready.put((None, None, None))

    def _produce_supervised(self, produce_fn, t, slot):
        """Retry produce_fn(t, slot) against a lifetime restart budget.
        Backoff doubles per retry of the same round so a persistently
        failing source drains the budget slowly instead of hot-looping."""
        attempt = 0
        while True:
            try:
                return produce_fn(t, slot)
            except BaseException:
                if self.restart_count >= self._max_restarts:
                    raise
                self.restart_count += 1
                self.restart_rounds[t] = self.restart_rounds.get(t, 0) + 1
                if self._restart_backoff > 0:
                    time.sleep(self._restart_backoff * (2 ** attempt))
                attempt += 1

    def get(self, t: int):
        if self._end is not None and t >= self._end:
            raise RuntimeError(
                f"round {t} is past the configured horizon ({self._end} "
                "rounds were prefetched); raise ExecConfig.rounds or set "
                "ExecConfig.prefetch=False to run extra rounds")
        waited = 0.0
        poll = 1.0
        if self._stall_timeout is not None:
            poll = min(poll, max(self._stall_timeout / 4, 0.01))
        while True:
            try:
                got, item, slot = self._ready.get(timeout=poll)
                break
            except queue.Empty:
                # a dead producer with an empty queue would otherwise
                # hang forever (e.g. rounds re-run after a completed run)
                if not self._thread.is_alive():
                    try:
                        # drain once more: the producer's final put may
                        # have landed between the timeout and this check
                        got, item, slot = self._ready.get_nowait()
                        break
                    except queue.Empty:
                        raise RuntimeError(
                            f"prefetch producer exited (rounds consumed "
                            f"or stopped) — round {t} was never staged; "
                            "set ExecConfig.prefetch=False to re-run rounds"
                            + self._cause_suffix()) from self._exc
                waited += poll
                if (self._stall_timeout is not None
                        and waited >= self._stall_timeout):
                    # producer ALIVE but stuck inside produce_fn: raise
                    # with the stuck round instead of spinning forever
                    raise RuntimeError(
                        f"staging producer stalled: round {t} not staged "
                        f"after {waited:.1f}s (stall deadline "
                        f"{self._stall_timeout}s) — the producer thread is "
                        "alive but blocked inside produce_fn (slow or "
                        "deadlocked source read?)")
        if got is None:                 # producer-failure sentinel; a round
            # staged BEFORE the failure is still valid and returned above.
            # Re-poison so every later get() fails too instead of hanging.
            self._ready.put((None, None, None))
            # the producer's own traceback is re-raised inline: `from`
            # chaining alone loses the frames inside produce_fn when the
            # consumer's RuntimeError is caught and reported elsewhere
            raise RuntimeError("cohort prefetch thread failed"
                               + self._cause_suffix()) from self._exc
        if got != t:
            raise RuntimeError(
                f"prefetched round {got} but round {t} was requested — "
                "prefetching requires run_round(t) in sequential order "
                "(set ExecConfig.prefetch=False for out-of-order rounds)")
        return item, slot

    def _cause_suffix(self) -> str:
        """The producer's formatted traceback, for re-raise messages —
        the frames inside produce_fn are otherwise lost when the
        consumer-side RuntimeError is caught and reported elsewhere."""
        if self._exc_tb is None:
            return ""
        return "\nproducer traceback:\n" + self._exc_tb

    def release(self, slot: dict):
        self._free.put(slot)

    def stop(self, join_timeout: float = 5.0):
        """Stop the producer and reclaim its staging state: put the
        sentinel, JOIN the thread (bounded — a producer hung inside
        ``produce_fn`` is a daemon and cannot block interpreter exit),
        then drain every staged-but-unconsumed slot out of ``_ready`` so
        their buffers are dropped with the ring instead of pinning
        whatever host/device memory the producer staged ahead."""
        if self._stopped:
            return
        self._stopped = True
        self._free.put(None)            # unblock the producer if waiting
        self._thread.join(timeout=join_timeout)
        while True:                     # drain staged slots (and any
            try:                        # poison sentinel) — nothing will
                self._ready.get_nowait()  # consume them after stop()
            except queue.Empty:
                break
