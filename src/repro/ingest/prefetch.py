"""Depth-N staging ring for cohort ingest (DESIGN.md §10; the depth-2
special case is the double-buffered prefetcher of DESIGN.md §2, moved
here from core/client.py).

A daemon thread runs ``produce_fn(t, slot)`` for t = start..end-1 IN
ROUND ORDER (so RNG-driven client sampling inside it draws the exact
same sequence as the blocking path), staging upcoming rounds into free
ring slots while the current round's program runs on device. With
``slots=N`` the producer runs up to N rounds ahead of the oldest
unreleased slot and never overwrites a buffer a round may still be
reading: the consumer releases a slot only after it has synchronized on
that round's results (on CPU backends a device-placed value may alias
the slot's host buffer — see ingest/placement.py — so placement alone
never frees a slot).

    item, slot = ring.get(t)   # blocks only until round t is staged
    ... dispatch + sync ...
    ring.release(slot)
"""
from __future__ import annotations

import queue
import threading


class CohortPrefetcher:
    """Ring of ``slots`` staging buffers filled in round order by a
    producer thread; ``slots=2`` double-buffers (the historical
    default), ``slots=1`` single-buffers (the producer still runs off
    the consumer thread, but can only work ahead while the consumer
    holds nothing — useful as the degenerate point of depth sweeps)."""

    def __init__(self, produce_fn, start: int, end: int, slots: int = 2):
        self._end = end
        self._ready = queue.Queue()
        self._free = queue.Queue()
        self.slots = max(1, slots)
        for _ in range(self.slots):
            self._free.put({})
        self._exc = None
        self._stopped = False
        self._thread = threading.Thread(
            target=self._loop, args=(produce_fn, start, end), daemon=True,
            name="cohort-prefetch")
        self._thread.start()

    def _loop(self, produce_fn, start, end):
        try:
            for t in range(start, end):
                slot = self._free.get()
                if slot is None:        # stop() sentinel
                    return
                item = produce_fn(t, slot)
                self._ready.put((t, item, slot))
        except BaseException as e:      # surfaced on the next get()
            self._exc = e
            self._ready.put((None, None, None))

    def get(self, t: int):
        if t >= self._end:
            raise RuntimeError(
                f"round {t} is past the configured horizon ({self._end} "
                "rounds were prefetched); raise ExecConfig.rounds or set "
                "ExecConfig.prefetch=False to run extra rounds")
        while True:
            try:
                got, item, slot = self._ready.get(timeout=1.0)
                break
            except queue.Empty:
                # a dead producer with an empty queue would otherwise
                # hang forever (e.g. rounds re-run after a completed run)
                if not self._thread.is_alive():
                    try:
                        # drain once more: the producer's final put may
                        # have landed between the timeout and this check
                        got, item, slot = self._ready.get_nowait()
                        break
                    except queue.Empty:
                        raise RuntimeError(
                            f"prefetch producer exited (rounds consumed "
                            f"or stopped) — round {t} was never staged; "
                            "set ExecConfig.prefetch=False to re-run rounds"
                        ) from self._exc
        if got is None:                 # producer-failure sentinel; a round
            # staged BEFORE the failure is still valid and returned above.
            # Re-poison so every later get() fails too instead of hanging.
            self._ready.put((None, None, None))
            raise RuntimeError("cohort prefetch thread failed") from self._exc
        if got != t:
            raise RuntimeError(
                f"prefetched round {got} but round {t} was requested — "
                "prefetching requires run_round(t) in sequential order "
                "(set ExecConfig.prefetch=False for out-of-order rounds)")
        return item, slot

    def release(self, slot: dict):
        self._free.put(slot)

    def stop(self):
        if not self._stopped:
            self._stopped = True
            self._free.put(None)        # unblock the producer if waiting
