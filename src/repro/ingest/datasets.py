"""Disk-backed federated DataSource impls + the decode/augment stage
(DESIGN.md §10): the paper's real datasets behind the §3 protocol.

``CIFAR10Source`` / ``CIFAR100Source`` / ``TinyImageNetSource`` wrap the
format readers (ingest/readers.py), Dirichlet-partition the training
labels across clients (data/dirichlet.py — the paper's heterogeneity
model), and stream round-seeded per-client batches through
``client_batches``. Records stay uint8 until a batch is drawn: the
DECODE stage (uint8 -> [-1, 1] float32) and the optional AUGMENT stage
(reflect-pad random crop + horizontal flip) run lazily on the ingest
path — the staging ring's producer thread when prefetching is on — so a
4x smaller uint8 working set lives in host memory and per-batch decode
work overlaps device compute.

Determinism contract (same as ingest/images.client_batches): everything
a round yields for a client is a pure function of (client, round, the
source's construction arguments) — batch order, wrap-padding, and the
augmentation draws all come from ``RandomState(hash((client, round)))``
— so prefetched, blocking, serial, and resumed runs see identical bytes.
"""
from __future__ import annotations

from typing import Callable, Iterator, List, Optional

import numpy as np

from repro.data.dirichlet import dirichlet_partition
from repro.ingest import readers
from repro.ingest.images import iter_batch_selections
from repro.ingest.sources import DataSource


def decode_images(raw: np.ndarray) -> np.ndarray:
    """DECODE stage: uint8 pixels -> float32 in [-1, 1] (the range the
    synthetic pipeline and the vision models already use)."""
    return (np.asarray(raw, np.float32) / 127.5) - 1.0


def augment_images(imgs: np.ndarray, rng: np.random.RandomState,
                   pad: int = 4, flip: bool = True) -> np.ndarray:
    """AUGMENT stage: per-image reflect-pad random crop + horizontal
    flip (the standard CIFAR recipe). Deterministic given ``rng``."""
    n, h, w = imgs.shape[:3]
    padded = np.pad(imgs, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                    mode="reflect")
    ys = rng.randint(0, 2 * pad + 1, size=n)
    xs = rng.randint(0, 2 * pad + 1, size=n)
    flips = rng.rand(n) < 0.5 if flip else np.zeros(n, bool)
    out = np.empty_like(imgs)
    for i in range(n):
        crop = padded[i, ys[i]:ys[i] + h, xs[i]:xs[i] + w]
        out[i] = crop[:, ::-1] if flips[i] else crop
    return out


class DiskImageSource(DataSource):
    """Dirichlet-partitioned disk-backed image source.

    ``labels`` (N,) drive the partition; ``fetch(indices) -> (B, H, W,
    3) uint8`` is the read stage (an in-memory slice for CIFAR, lazy
    file decode for TinyImageNet). Subclasses are thin constructors.
    """

    def __init__(self, labels: np.ndarray,
                 fetch: Callable[[np.ndarray], np.ndarray], *,
                 num_clients: int, alpha: float, batch_size: int,
                 local_epochs: int = 1, augment: bool = False,
                 seed: int = 0, min_size: int = 2):
        self.labels = np.asarray(labels, np.int32)
        self.fetch = fetch
        self.num_clients = num_clients
        self.batch_size = batch_size
        self.local_epochs = local_epochs
        self.augment = augment
        self.client_indices: List[np.ndarray] = dirichlet_partition(
            self.labels, num_clients, alpha, seed=seed, min_size=min_size)

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def client_weights(self) -> np.ndarray:
        return np.asarray([len(ix) for ix in self.client_indices],
                          np.float64)

    def client_batches(self, client: int, round: int
                       ) -> Iterator[dict]:
        # index iteration shared with the array-backed pipeline
        # (ingest/images.iter_batch_selections) — one source of truth
        # for the (client, round) determinism contract; augmentation
        # draws from the same per-round rng between batches
        for sel, rng in iter_batch_selections(
                self.client_indices[client], self.batch_size, client,
                round, self.local_epochs):
            imgs = decode_images(self.fetch(sel))
            if self.augment:
                imgs = augment_images(imgs, rng)
            yield {"images": imgs,
                   "labels": self.labels[sel].astype(np.int32)}


class _ArraySource(DiskImageSource):
    """Shared CIFAR constructor body: eager uint8 arrays + test split."""

    def __init__(self, data: readers.ArrayImageData, *, num_clients: int,
                 alpha: float, batch_size: int, **kw):
        self._data = data
        super().__init__(data.train_labels,
                         lambda sel: data.train_images[sel],
                         num_clients=num_clients, alpha=alpha,
                         batch_size=batch_size, **kw)

    def test_arrays(self):
        """(images float32 [-1,1], labels int32) — the held-out split,
        decoded once, for eval_fn construction."""
        return (decode_images(self._data.test_images),
                self._data.test_labels.astype(np.int32))


class CIFAR10Source(_ArraySource):
    """FedDPC §5.1's CIFAR-10: Dirichlet(alpha)-partitioned, disk-backed
    (the standard cifar-10-batches-py download under ``root``)."""

    def __init__(self, root: str, *, num_clients: int = 100,
                 alpha: float = 0.2, batch_size: int = 64, **kw):
        super().__init__(readers.load_cifar10(root),
                         num_clients=num_clients, alpha=alpha,
                         batch_size=batch_size, **kw)


class CIFAR100Source(_ArraySource):
    """CIFAR-100 with fine labels (cifar-100-python under ``root``)."""

    def __init__(self, root: str, *, num_clients: int = 100,
                 alpha: float = 0.2, batch_size: int = 64, **kw):
        super().__init__(readers.load_cifar100(root),
                         num_clients=num_clients, alpha=alpha,
                         batch_size=batch_size, **kw)


class TinyImageNetSource(DiskImageSource):
    """TinyImageNet (tiny-imagenet-200 under ``root``): path-indexed,
    images read + decoded lazily per batch on the ingest path, so the
    1.2 GB training set never materializes in host memory at once."""

    def __init__(self, root: str, *, num_clients: int = 100,
                 alpha: float = 0.2, batch_size: int = 64,
                 image_size: Optional[int] = 64,
                 decode_workers: int = 0, **kw):
        self.index = readers.load_tiny_imagenet(root)
        self.image_size = image_size
        # bounded read/decode pool (ExecConfig.decode_workers); 0 =
        # serial. Output order is pinned to the selection order either
        # way, so the batch stacks are bit-identical across settings.
        self.decoder = readers.ImageDecodePool(decode_workers)
        paths = self.index.train_paths

        def fetch(sel):
            return np.stack(self.decoder.decode(
                [paths[i] for i in sel], image_size))

        super().__init__(self.index.train_labels, fetch,
                         num_clients=num_clients, alpha=alpha,
                         batch_size=batch_size, **kw)

    @property
    def num_classes(self) -> int:
        return self.index.num_classes

    def test_arrays(self):
        """Decoded val split (eagerly — it is 20x smaller than train)."""
        imgs = np.stack(self.decoder.decode(self.index.val_paths,
                                            self.image_size))
        return decode_images(imgs), self.index.val_labels.astype(np.int32)
