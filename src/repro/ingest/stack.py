"""Cohort-stack stage of the ingest pipeline (DESIGN.md §10; moved here
from core/client.py, which keeps deprecated shims for one release).

``stack_batches``/``stack_cohort`` build the padded (K, M, ...) cohort
stack the fused round consumes; ``stack_cohort_into`` does the same into
preallocated buffers owned by a staging-ring slot, so the per-round
np.stack allocations disappear from the ingest path.
"""
from __future__ import annotations

import jax
import numpy as np


def stack_batches(batch_list, max_batches: int, template=None):
    """Pad a list of same-shape batch pytrees to (max_batches, ...) + mask.

    ``template`` (one batch pytree, shapes/dtypes only) makes an EMPTY
    list stackable: a zero-data client contributes an all-zero stack
    with an all-False mask (the local scan no-ops, delta == 0) while
    still counting as a sampled client for the server rules."""
    n = len(batch_list)
    assert n <= max_batches, (n, max_batches)
    if n == 0:
        if template is None:
            raise ValueError("stack_batches of an empty batch list needs a "
                             "template batch for the leaf shapes")
        stacked = jax.tree.map(
            lambda x: np.zeros((max_batches,) + np.shape(x),
                               np.asarray(x).dtype), template)
        return stacked, np.zeros(max_batches, bool)
    stacked = jax.tree.map(lambda *xs: np.stack(xs), *batch_list)
    if n < max_batches:
        pad = max_batches - n
        stacked = jax.tree.map(
            lambda x: np.concatenate(
                [x, np.repeat(x[-1:], pad, axis=0)], axis=0), stacked)
    mask = np.arange(max_batches) < n
    return stacked, mask


def stack_cohort(per_client_batches, max_batches: int, pad_to: int = None):
    """Stack K clients' batch lists into one (K, M, ...) pytree + (K, M)
    mask — the input of ``make_cohort_local_update``. M = max_batches is
    the shape bucket; ragged clients pad with masked repeats.

    ``pad_to`` > K appends DUMMY clients (copies of the last real row
    with an all-False mask row) so uneven cohorts shard over a client
    axis whose size does not divide K (DESIGN.md §2): a fully-masked
    client runs a no-op local scan (delta == 0) and the server rules
    exclude it from every mean via the derived client validity mask.
    """
    template = next((b[0] for b in per_client_batches if b), None)
    if template is None:
        raise ValueError("every client in the cohort has zero batches")
    pairs = [stack_batches(b, max_batches, template=template)
             for b in per_client_batches]
    batches = jax.tree.map(lambda *xs: np.stack(xs), *[p[0] for p in pairs])
    masks = np.stack([p[1] for p in pairs])
    k = len(per_client_batches)
    if pad_to is not None and pad_to > k:
        pad = pad_to - k
        batches = jax.tree.map(
            lambda x: np.concatenate(
                [x, np.repeat(x[-1:], pad, axis=0)], axis=0), batches)
        masks = np.concatenate(
            [masks, np.zeros((pad,) + masks.shape[1:], bool)], axis=0)
    return batches, masks


def stack_cohort_into(per_client_batches, max_batches: int, slot: dict,
                      pad_to: int = None):
    """``stack_cohort`` into PREALLOCATED host buffers (DESIGN.md §10).

    ``slot`` is a mutable dict owned by the caller (one per staging-ring
    buffer): its (K, M, ...) arrays + (K, M) mask are allocated on first
    use and reused every round — reallocation happens only when the
    cohort shape grows/changes (grow-once M bucketing keeps that rare),
    so the per-round np.stack allocations disappear from the ingest path.
    Returns (batches_pytree, mask) views backed by the slot's buffers;
    they stay valid until the slot is refilled.

    ``pad_to`` appends dummy clients exactly as ``stack_cohort`` does
    (copies of the last real row, all-False mask rows).
    """
    k, m = len(per_client_batches), max_batches
    kp = k if pad_to is None else max(pad_to, k)
    first = next((b for b in per_client_batches if b), None)
    if first is None:
        raise ValueError("every client in the cohort has zero batches")
    leaves0, treedef = jax.tree_util.tree_flatten(first[0])
    shapes = tuple((np.shape(x), np.asarray(x).dtype) for x in leaves0)
    key = (kp, m, treedef, shapes)
    if slot.get("key") != key:
        slot["key"] = key
        slot["bufs"] = [np.empty((kp, m) + s, dt) for s, dt in shapes]
        slot["mask"] = np.empty((kp, m), bool)
    bufs, mask = slot["bufs"], slot["mask"]
    for j, blist in enumerate(per_client_batches):
        n = len(blist)
        assert n <= m, (n, m)
        if n == 0:                      # zero-data client: all-zero rows,
            for buf in bufs:            # fully masked — still a SAMPLED
                buf[j] = 0              # client (see core/round.py)
            mask[j] = False
            continue
        for i, b in enumerate(blist):
            for buf, x in zip(bufs, jax.tree_util.tree_flatten(b)[0]):
                buf[j, i] = x
        if n < m:                       # ragged: pad with masked repeats
            for buf in bufs:
                buf[j, n:] = buf[j, n - 1]
        mask[j] = np.arange(m) < n
    for j in range(k, kp):              # dummy clients: masked copies
        for buf in bufs:
            buf[j] = buf[k - 1]
        mask[j] = False
    return jax.tree_util.tree_unflatten(treedef, bufs), mask
