"""Device-place stage of the ingest pipeline (DESIGN.md §10).

The fused cohort round consumes a (K, M, ...) batch stack, a (K, M)
mask and (K,) client ids. Historically those crossed host->device
implicitly at jit dispatch, serializing the H2D copy on the consumer
thread. ``CohortPlacer`` makes the placement an explicit pipeline stage:

  * it places against the round's ACTUAL layout — the same
    ``P("clients")`` NamedSharding objects the round's jit was built
    with on a mesh (sharding/rules.cohort_round_shardings), or the
    default device off-mesh — so dispatch finds the inputs already
    resident and copies nothing;
  * ``place`` BLOCKS until the transfer lands. Run on the staging
    ring's producer thread (ExecConfig.device_stage=True) that wait
    overlaps device compute and disappears from the round's critical
    path. Run on the consumer thread (device_stage=False) it is the
    measured "transfer wait at dispatch" that
    RoundRecord.ingest_device_seconds reports.

A word on buffer lifetime: on CPU backends ``jax.device_put`` of an
aligned numpy array MAY be zero-copy — the device value aliases the
host buffer instead of copying it. Placement therefore does NOT free
the staging slot for overwrite; the pipeline keeps every slot reserved
until its round's results have synchronized (the same contract the
host-staged path always had), which is safe under either semantics.

Placement never changes values — committed arrays feed the jit exactly
as host arrays would — so every execution regime stays round-for-round
equal to the serial reference (tests/test_regime_matrix.py).

MULTI-PROCESS (DESIGN.md §15): when the clients mesh spans processes the
round's input sharding is no longer fully addressable and a plain
``device_put`` of a host array is illegal. Each host's pipeline then
stages only its LOCAL client-row slice (sharding/rules.local_row_range)
and the placer assembles the global array from per-host shards via
``jax.make_array_from_callback`` — the callback answers each local
device's global row-slice out of the local buffer, so no client batch
ever crosses a host boundary on the host side. ``put_global`` is the
matching helper for REPLICATED values (params, server state at init /
restore), where every host holds the full array.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def put_global(x, sh):
    """device_put that also works when ``sh`` spans processes.

    Fully-addressable sharding (single-process, or an off-mesh default
    device): plain ``jax.device_put``. Process-spanning sharding: build
    the global array from this host's addressable shards with
    ``make_array_from_callback`` — correct for any sharding where this
    host can answer its own devices' index map from ``x`` (replicated
    values, or a host-complete array). ``x`` must be the FULL global
    value on every calling host."""
    if sh is None:
        return jax.device_put(x)
    if getattr(sh, "is_fully_addressable", True):
        return jax.device_put(x, sh)
    x = np.asarray(x)
    return jax.make_array_from_callback(x.shape, sh, lambda idx: x[idx])


class CohortPlacer:
    """Places (batches, masks, ids) with the cohort round's input layout.

    ``input_sharding`` is the client-axis NamedSharding shared by every
    cohort-stacked input of the round's jit (None = single-device: the
    default device, uncommitted — jit accepts it without a copy).

    ``local_rows=(lo, hi)`` + ``global_rows=K``: multi-process mode —
    ``place`` receives arrays covering ONLY global client rows
    [lo, hi) and assembles (K, ...) global arrays whose addressable
    shards come straight out of the local slice. Requires
    ``input_sharding`` to shard the leading axis so that this host's
    devices cover exactly [lo, hi) (sharding/rules.local_row_range
    computes that range from the same sharding)."""

    def __init__(self, input_sharding=None, *,
                 local_rows: Optional[Tuple[int, int]] = None,
                 global_rows: Optional[int] = None):
        self.input_sharding = input_sharding
        self.local_rows = local_rows
        self.global_rows = global_rows
        if (local_rows is None) != (global_rows is None):
            raise ValueError(
                "local_rows and global_rows must be set together")
        if local_rows is not None and input_sharding is None:
            raise ValueError("local_rows placement needs input_sharding")

    def _put_local(self, local):
        """Assemble one global (K, ...) array from this host's
        [lo, hi) slice via make_array_from_callback."""
        lo, hi = self.local_rows
        k = self.global_rows
        local = np.asarray(local)
        if local.shape[0] != hi - lo:
            raise ValueError(
                f"local slice has {local.shape[0]} rows, expected "
                f"{hi - lo} (= local_rows {self.local_rows})")
        gshape = (k,) + local.shape[1:]

        def cb(idx):
            sl = idx[0]
            start = 0 if sl.start is None else sl.start
            stop = k if sl.stop is None else sl.stop
            return local[start - lo:stop - lo]

        return jax.make_array_from_callback(
            gshape, self.input_sharding, cb)

    def place(self, batches: PyTree, masks, ids) -> Tuple[PyTree, Any, Any]:
        sh = self.input_sharding
        if self.local_rows is not None:
            put = self._put_local
        elif sh is None:
            put = jax.device_put
        else:
            put = lambda x: jax.device_put(x, sh)
        batches = jax.tree.map(put, batches)
        masks = None if masks is None else put(masks)
        ids = None if ids is None else put(ids)
        # block on the transfer so a producer-thread call fully absorbs
        # the H2D wait (and the timing around a consumer-thread call
        # measures it, not just the dispatch)
        jax.block_until_ready((batches, masks, ids))
        return batches, masks, ids

    def place_encoded(self, cohort):
        """Stage a codec-compressed cohort (codec.base.EncodedCohort)
        against the round's client-axis layout.

        The payload leaves already carry the leading client axis (q:
        (K, ...) quantized codes, scale/zero: (K,) per-leaf vectors), so
        the SAME sharding the raw batch stack uses covers the whole wire
        dict. This is where the codec's H2D win lands: the bytes crossing
        the bus are the quantized codes (int8/bf16), not the f32 deltas —
        ``EncodedCohort.nbytes`` before/after this call is the receipt
        bench_cohort.py's codec sweep reports as device-stage bytes.

        Returns the cohort with its payload device-resident (blocking,
        same contract as ``place``).
        """
        sh = self.input_sharding
        if self.local_rows is not None:
            # every payload leaf carries the leading client axis, so the
            # local-slice assembly covers the whole wire dict too
            put = self._put_local
        elif sh is None:
            put = jax.device_put
        else:
            put = lambda x: jax.device_put(x, sh)
        payload = jax.tree.map(put, cohort.payload)
        jax.block_until_ready(payload)
        return type(cohort)(codec=cohort.codec, payload=payload,
                            clients=cohort.clients)
