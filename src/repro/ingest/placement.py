"""Device-place stage of the ingest pipeline (DESIGN.md §10).

The fused cohort round consumes a (K, M, ...) batch stack, a (K, M)
mask and (K,) client ids. Historically those crossed host->device
implicitly at jit dispatch, serializing the H2D copy on the consumer
thread. ``CohortPlacer`` makes the placement an explicit pipeline stage:

  * it places against the round's ACTUAL layout — the same
    ``P("clients")`` NamedSharding objects the round's jit was built
    with on a mesh (sharding/rules.cohort_round_shardings), or the
    default device off-mesh — so dispatch finds the inputs already
    resident and copies nothing;
  * ``place`` BLOCKS until the transfer lands. Run on the staging
    ring's producer thread (ExecConfig.device_stage=True) that wait
    overlaps device compute and disappears from the round's critical
    path. Run on the consumer thread (device_stage=False) it is the
    measured "transfer wait at dispatch" that
    RoundRecord.ingest_device_seconds reports.

A word on buffer lifetime: on CPU backends ``jax.device_put`` of an
aligned numpy array MAY be zero-copy — the device value aliases the
host buffer instead of copying it. Placement therefore does NOT free
the staging slot for overwrite; the pipeline keeps every slot reserved
until its round's results have synchronized (the same contract the
host-staged path always had), which is safe under either semantics.

Placement never changes values — committed arrays feed the jit exactly
as host arrays would — so every execution regime stays round-for-round
equal to the serial reference (tests/test_regime_matrix.py).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

PyTree = Any


class CohortPlacer:
    """Places (batches, masks, ids) with the cohort round's input layout.

    ``input_sharding`` is the client-axis NamedSharding shared by every
    cohort-stacked input of the round's jit (None = single-device: the
    default device, uncommitted — jit accepts it without a copy)."""

    def __init__(self, input_sharding=None):
        self.input_sharding = input_sharding

    def place(self, batches: PyTree, masks, ids) -> Tuple[PyTree, Any, Any]:
        sh = self.input_sharding
        put = (jax.device_put if sh is None
               else (lambda x: jax.device_put(x, sh)))
        batches = jax.tree.map(put, batches)
        masks = None if masks is None else put(masks)
        ids = None if ids is None else put(ids)
        # block on the transfer so a producer-thread call fully absorbs
        # the H2D wait (and the timing around a consumer-thread call
        # measures it, not just the dispatch)
        jax.block_until_ready((batches, masks, ids))
        return batches, masks, ids

    def place_encoded(self, cohort):
        """Stage a codec-compressed cohort (codec.base.EncodedCohort)
        against the round's client-axis layout.

        The payload leaves already carry the leading client axis (q:
        (K, ...) quantized codes, scale/zero: (K,) per-leaf vectors), so
        the SAME sharding the raw batch stack uses covers the whole wire
        dict. This is where the codec's H2D win lands: the bytes crossing
        the bus are the quantized codes (int8/bf16), not the f32 deltas —
        ``EncodedCohort.nbytes`` before/after this call is the receipt
        bench_cohort.py's codec sweep reports as device-stage bytes.

        Returns the cohort with its payload device-resident (blocking,
        same contract as ``place``).
        """
        sh = self.input_sharding
        put = (jax.device_put if sh is None
               else (lambda x: jax.device_put(x, sh)))
        payload = jax.tree.map(put, cohort.payload)
        jax.block_until_ready(payload)
        return type(cohort)(codec=cohort.codec, payload=payload,
                            clients=cohort.clients)
