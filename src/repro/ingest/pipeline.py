"""Staged cohort ingest pipeline (DESIGN.md §10).

One object owns the whole host->device feed path of the fused cohort
round as four explicit stages:

    read           source.client_batches(client, round)  (ingest/sources)
    decode/augment inside the source's iterable — disk sources decode
                   raw uint8 records and apply augmentation lazily as
                   the stacker consumes them (ingest/datasets)
    cohort-stack   stack_cohort_into a preallocated ring-slot buffer
                   (ingest/stack)
    device-place   jax.device_put against the round's actual sharding
                   (ingest/placement)

With ``depth >= 1`` staging buffers and ``CohortPrefetcher`` the stages
for round t+1..t+depth run on a producer thread while round t's program
runs on device. ``device_stage=True`` moves the device-place stage onto
the producer thread too: the H2D transfer overlaps compute, dispatch
finds every input already resident, and the consumer's only wait is the
staging wait (RoundRecord.ingest_host_seconds). ``device_stage=False``
keeps placement on the consumer thread, where it is measured as
RoundRecord.ingest_device_seconds — the historical "transfer at
dispatch" cost the two knobs exist to remove.

Client SAMPLING stays inside ``sample_fn`` (the trainer's, which
snapshots pre-draw RNG/sampler state for checkpointing): the pipeline
calls it in round order from whichever thread stages the round, so a
prefetched run draws the exact same schedule as a blocking one.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.ingest.placement import CohortPlacer
from repro.ingest.prefetch import CohortPrefetcher
from repro.ingest.sources import DataSource
from repro.ingest.stack import stack_cohort_into

PyTree = Any


@dataclass
class StagedCohort:
    """One round's fully staged inputs + the consumer-side waits that
    produced them. ``batches``/``masks``/``ids`` are committed device
    values ready for zero-copy dispatch; ``clients`` is the UNPADDED
    sampled cohort (host), for loss masking and the schedule.

    ``release()`` returns the staging buffer to the ring and MUST be
    called (idempotent; a try/finally around the round's dispatch+sync)
    only after the round has synchronized on its results — a leaked slot
    deadlocks a later ``get``, an early release lets the producer
    overwrite buffers the device may still read."""
    round: int
    clients: np.ndarray
    batches: PyTree
    masks: Any
    ids: Any
    host_seconds: float = 0.0      # blocked on sample+read+stack staging
    device_seconds: float = 0.0    # blocked on H2D placement at dispatch
    _release: Optional[Callable[[], None]] = field(default=None, repr=False)

    def release(self):
        if self._release is not None:
            release, self._release = self._release, None
            release()


class CohortIngestPipeline:
    """Stages cohorts for the vectorized round; also owns the read stage
    (and the grow-once M shape bucket) for the serial reference path.

    ``sample_fn(t) -> (K,) client ids`` is called exactly once per round
    in round order. ``pad_to`` > K appends masked dummy clients so the
    cohort tiles a sharded client axis; dummy ids use the out-of-range
    ``num_clients`` sentinel (FedVARP's scatter drops them).

    MULTI-PROCESS (DESIGN.md §15): ``local_rows=(lo, hi)`` restricts the
    read/decode/stack stages to this host's slice of the padded cohort.
    Every process still draws the FULL schedule (seeded samplers make it
    identical across hosts — that shared draw IS the coordination), but
    only global rows [lo, hi) are read and staged here; the placer (which
    must carry the same ``local_rows``) assembles the global array from
    the per-host shards, so client batches never cross a host boundary
    host-side. ``sync_max_batches(tag, m) -> m'`` (launch/distributed.
    kv_allmax under a per-round tag) keeps the grow-once M bucket — and
    with it the stacked shape and jit signature — agreed across hosts.
    """

    def __init__(self, source: DataSource,
                 sample_fn: Callable[[int], np.ndarray], *,
                 num_clients: int, rounds: Optional[int], depth: int = 2,
                 device_stage: bool = True,
                 placer: Optional[CohortPlacer] = None,
                 pad_to: Optional[int] = None,
                 local_rows: Optional[tuple] = None,
                 sync_max_batches: Optional[Callable[[str, int], int]] = None,
                 stall_timeout: Optional[float] = None,
                 max_restarts: int = 0, restart_backoff: float = 0.05,
                 crash_hook: Optional[Callable[[int, int], bool]] = None):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.source = source
        self.sample_fn = sample_fn
        self.num_clients = num_clients
        # rounds=None -> open horizon (buffered-async waves: the number
        # of dispatches is dynamic); the ring backpressures on `depth`
        self.rounds = rounds
        self.depth = depth
        self.device_stage = device_stage
        self.placer = placer if placer is not None else CohortPlacer()
        self.pad_to = pad_to
        self.local_rows = local_rows
        self.sync_max_batches = sync_max_batches
        self._sync_calls: dict = {}     # round -> sync tags issued so far
        if local_rows is not None and pad_to is None:
            raise ValueError("local_rows staging needs pad_to (the global "
                             "padded cohort size)")
        self.stall_timeout = stall_timeout
        # producer supervision (DESIGN.md §12): a produce raise is
        # retried up to max_restarts times (lifetime budget) with
        # exponential backoff; crash_hook(t, attempt) -> bool is the
        # fault-injection seam (core/faults.FaultPlan.ingest_crash)
        self.max_restarts = max(0, int(max_restarts))
        self.restart_backoff = float(restart_backoff)
        self._crash_hook = crash_hook
        self._attempts: dict = {}       # round -> produce attempts so far
        self._sampled: dict = {}        # round -> drawn cohort (retry cache)
        self._blocking_restarts = 0     # stage_blocking's share of the tally
        self._blocking_rounds: dict = {}  # round -> stage_blocking restarts
        self._max_batches: Optional[int] = None
        self._ring: Optional[CohortPrefetcher] = None
        self._blocking_slot: dict = {}   # stage_blocking's private buffer

    # ---- shared read stage / shape bucket ----

    @property
    def max_batches(self) -> Optional[int]:
        """Grow-once M shape bucket (checkpointed as TrainerState.
        max_batches; the trainer's restore() writes it back)."""
        return self._max_batches

    @max_batches.setter
    def max_batches(self, value: Optional[int]):
        self._max_batches = value

    def client_lists(self, clients: Sequence[int], t: int):
        """READ (+decode) stage: materialize each sampled client's
        batches for round t and grow the M bucket to the cohort max."""
        per_client = [list(self.source.client_batches(int(c), t))
                      for c in clients]
        mx = max(len(b) for b in per_client)
        if self._max_batches is None or mx > self._max_batches:
            self._max_batches = mx      # grow-once; keeps jit cache small
        return per_client

    # ---- staging ----

    def _pad_ids(self, clients: np.ndarray) -> np.ndarray:
        ids = np.asarray(clients, np.int32)
        if self.pad_to is not None and self.pad_to > ids.shape[0]:
            # out-of-range sentinel ids: FedVARP's scatter DROPS them
            ids = np.concatenate(
                [ids, np.full(self.pad_to - ids.shape[0],
                              self.num_clients, np.int32)])
        return ids

    def _stage_host(self, t: int, slot: dict):
        """sample -> read -> stack into the slot's buffers.

        The drawn cohort is cached per round until staging SUCCEEDS: a
        supervised producer retry must not call ``sample_fn`` again —
        the trainer's sampler snapshots pre-draw RNG state per round,
        and a re-draw would shift every later round's schedule."""
        if t in self._sampled:
            clients = self._sampled[t]
        else:
            clients = self.sample_fn(t)
            self._sampled[t] = clients
        if self.local_rows is not None:
            # multi-process: the full schedule was drawn (identically on
            # every host), but READ only this host's slice of it
            lo, hi = self.local_rows
            k = len(clients)
            local = np.asarray(clients)[lo:min(hi, k)]
            if local.size == 0:
                raise ValueError(
                    f"host rows [{lo}, {hi}) hold no real clients of the "
                    f"{k}-client cohort — every host needs at least one; "
                    "use fewer processes or a larger cohort")
            lists = self.client_lists(local, t)
            if self.sync_max_batches is not None:
                n = self._sync_calls.get(t, 0)
                self._sync_calls[t] = n + 1
                self._max_batches = int(self.sync_max_batches(
                    f"{t}.{n}", int(self._max_batches)))
            batches, masks = stack_cohort_into(
                lists, self._max_batches, slot, pad_to=hi - lo)
            return (clients, batches, masks,
                    self._pad_ids(clients)[lo:hi])
        lists = self.client_lists(clients, t)
        batches, masks = stack_cohort_into(lists, self._max_batches, slot,
                                           pad_to=self.pad_to)
        return clients, batches, masks, self._pad_ids(clients)

    def _produce(self, t: int, slot: dict):
        """Ring-producer body. In device-staged mode the place stage
        runs here too, so the H2D wait lands on this thread (overlapped
        with device compute) instead of at dispatch. The crash hook
        fires BEFORE sampling so an injected crash + restart replays
        the exact no-fault RNG stream."""
        attempt = self._attempts.get(t, 0)
        self._attempts[t] = attempt + 1
        if self._crash_hook is not None and self._crash_hook(t, attempt):
            raise RuntimeError(
                f"injected ingest producer crash at round {t} "
                f"(attempt {attempt})")
        clients, batches, masks, ids = self._stage_host(t, slot)
        if self.device_stage:
            batches, masks, ids = self.placer.place(batches, masks, ids)
        self._sampled.pop(t, None)
        self._attempts.pop(t, None)
        return clients, batches, masks, ids

    def get(self, t: int) -> StagedCohort:
        """Prefetching consumer: blocks only until round t is staged
        (and, host-staged, on its own placement). Rounds must be
        consumed sequentially from the first ``get``; the caller owns
        the returned slot until ``StagedCohort.release()``."""
        if self._ring is None:
            self._ring = CohortPrefetcher(self._produce, t, self.rounds,
                                          slots=self.depth,
                                          stall_timeout=self.stall_timeout,
                                          max_restarts=self.max_restarts,
                                          restart_backoff=self.restart_backoff)
        tic = time.perf_counter()
        (clients, batches, masks, ids), slot = self._ring.get(t)
        host_s = time.perf_counter() - tic
        dev_s = 0.0
        if not self.device_stage:
            try:
                tic = time.perf_counter()
                batches, masks, ids = self.placer.place(batches, masks, ids)
                dev_s = time.perf_counter() - tic
            except BaseException:
                # failed placement cannot leak the slot — that would
                # deadlock the NEXT get() instead of erroring
                self._ring.release(slot)
                raise
        return StagedCohort(t, clients, batches, masks, ids, host_s, dev_s,
                            _release=lambda: self._ring.release(slot))

    def stage_blocking(self, t: int) -> StagedCohort:
        """Non-prefetching path: stage round t inline on the caller's
        thread (out-of-order rounds allowed). Reuses one private slot —
        valid because the caller synchronizes each round before staging
        the next (release() is a no-op here)."""
        tic = time.perf_counter()
        while True:
            try:
                attempt = self._attempts.get(t, 0)
                self._attempts[t] = attempt + 1
                if (self._crash_hook is not None
                        and self._crash_hook(t, attempt)):
                    raise RuntimeError(
                        f"injected ingest producer crash at round {t} "
                        f"(attempt {attempt})")
                clients, batches, masks, ids = self._stage_host(
                    t, self._blocking_slot)
                break
            except BaseException:
                if self._blocking_restarts >= self.max_restarts:
                    raise
                self._blocking_restarts += 1
                self._blocking_rounds[t] = self._blocking_rounds.get(t, 0) + 1
                if self.restart_backoff > 0:
                    time.sleep(self.restart_backoff * (2 ** attempt))
        self._sampled.pop(t, None)
        self._attempts.pop(t, None)
        host_s = time.perf_counter() - tic
        tic = time.perf_counter()
        batches, masks, ids = self.placer.place(batches, masks, ids)
        dev_s = time.perf_counter() - tic
        return StagedCohort(t, clients, batches, masks, ids, host_s, dev_s)

    # ---- lifecycle ----

    @property
    def started(self) -> bool:
        """True once the staging ring exists (some round was prefetched)."""
        return self._ring is not None

    @property
    def restart_count(self) -> int:
        """Total supervised producer recoveries so far (both the
        prefetch ring's and stage_blocking's), RoundRecord's
        ``ingest_restarts`` source."""
        ring = self._ring.restart_count if self._ring is not None else 0
        return ring + self._blocking_restarts

    def restarts_for(self, t: int) -> int:
        """Supervised recoveries attributed to round ``t``'s STAGING —
        keyed by the round index carried into produce_fn, not by
        whichever round was computing when the crash fired. Final once
        round t has been staged (the retry loop resolved before the
        staged item was handed out), which is exactly when the trainer
        reads it to fill RoundRecord.ingest_restarts."""
        ring = (self._ring.restart_rounds.get(t, 0)
                if self._ring is not None else 0)
        return ring + self._blocking_rounds.get(t, 0)

    def close(self):
        """Stop the staging ring. The source is CALLER-owned (sweeps
        share one across trainers) and is never closed here."""
        if self._ring is not None:
            self._ring.stop()
