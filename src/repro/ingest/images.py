"""Array-backed federated image pipeline: per-client datasets + seeded
batch iteration (moved here from the retired
data/pipeline.py — DESIGN.md §10).

Mirrors the paper's setup: each client holds a Dirichlet-skewed shard;
every local epoch shuffles with a round-dependent seed; batches are padded
by wrap-around so a client with fewer samples than the batch size still
yields one full batch (matches FedAvg-style implementations).

``StreamingImageSource`` is the DataSource (ingest/sources.py) view of
this pipeline: it hands the trainer the ``client_batches`` GENERATOR, so
the gather/slice work materializes lazily on the ingest path — inside the
staging ring's producer thread when prefetching is on, overlapping data
IO with the device round instead of requiring pre-built per-client lists.
The DISK-backed equivalents (CIFAR/TinyImageNet readers) live in
ingest/datasets.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List

import numpy as np

from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_image_dataset
from repro.ingest.sources import DataSource


@dataclass
class FederatedImageData:
    train_images: np.ndarray
    train_labels: np.ndarray
    test_images: np.ndarray
    test_labels: np.ndarray
    client_indices: List[np.ndarray]

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)


def build_federated_image_data(num_classes=10, num_clients=100, alpha=0.2,
                               samples_per_class=500, test_per_class=100,
                               image_size=32, seed=0,
                               noise=0.35) -> FederatedImageData:
    tr_x, tr_y = make_image_dataset(num_classes, samples_per_class,
                                    image_size=image_size, seed=seed,
                                    noise=noise)
    te_x, te_y = make_image_dataset(num_classes, test_per_class,
                                    image_size=image_size, seed=seed + 10_000,
                                    noise=noise)
    parts = dirichlet_partition(tr_y, num_clients, alpha, seed=seed)
    return FederatedImageData(tr_x, tr_y, te_x, te_y, parts)


def iter_batch_selections(idx: np.ndarray, batch_size: int, client: int,
                          round_num: int, local_epochs: int = 1):
    """The determinism-critical index iteration EVERY image source shares
    (this module's array-backed pipeline and the disk-backed sources in
    ingest/datasets.py): yields ``(sel, rng)`` per batch, where ``sel``
    indexes ``batch_size`` samples of the client's shard and ``rng`` is
    the round's ``RandomState(hash((client, round)))`` — shuffled per
    local epoch, tiny shards wrap-padded to one full batch, the
    remainder dropped. One source of truth for the "pure function of
    (client, round)" contract; callers may draw from ``rng`` between
    batches (augmentation) without breaking it."""
    rng = np.random.RandomState(hash((client, round_num)) % (2 ** 31))
    for _ in range(local_epochs):
        order = rng.permutation(len(idx))
        n = len(order)
        if n < batch_size:          # wrap-pad tiny clients to one full batch
            order = np.resize(order, batch_size)
            n = batch_size
        for start in range(0, n - batch_size + 1, batch_size):
            yield idx[order[start:start + batch_size]], rng


def client_batches(data: FederatedImageData, client: int, batch_size: int,
                   round_num: int, local_epochs: int = 1
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Yield batches for `local_epochs` epochs over the client's shard."""
    for sel, _ in iter_batch_selections(data.client_indices[client],
                                        batch_size, client, round_num,
                                        local_epochs):
        yield {"images": data.train_images[sel],
               "labels": data.train_labels[sel]}


class StreamingImageSource(DataSource):
    """Streams ``client_batches`` straight into the trainer's ingest path
    (ingest/sources.DataSource protocol): batches materialize as the
    cohort stacker consumes the generator — with prefetch on, on the
    staging thread, so shard gathering overlaps device compute.

    ``client_weights()`` exposes shard sizes for ``WeightedSampler``
    (participation proportional to data size)."""

    def __init__(self, data: FederatedImageData, batch_size: int,
                 local_epochs: int = 1):
        self.data = data
        self.batch_size = batch_size
        self.local_epochs = local_epochs

    def client_batches(self, client: int, round: int):
        return client_batches(self.data, client, self.batch_size, round,
                              self.local_epochs)

    def client_weights(self) -> np.ndarray:
        return np.asarray([len(ix) for ix in self.data.client_indices],
                          np.float64)
