"""On-disk dataset readers — the READ stage behind the disk-backed
DataSource impls (ingest/datasets.py; DESIGN.md §10).

Readers understand the datasets' STANDARD distribution formats, so a
directory produced by the official downloads works as-is, with no
network access at runtime:

  CIFAR-10    <root>/cifar-10-batches-py/{data_batch_*, test_batch}
              python pickles: {b"data": uint8 (N, 3072) row-major RGB
              planes, b"labels": [int]}
  CIFAR-100   <root>/cifar-100-python/{train, test}
              python pickles: {b"data": uint8 (N, 3072),
              b"fine_labels": [int]}
  TinyImageNet <root>/tiny-imagenet-200/
              wnids.txt; train/<wnid>/images/<img>.JPEG;
              val/images/<img>.JPEG + val/val_annotations.txt

CIFAR loads into memory as uint8 (the pickle format is not seekable;
~180 MB for the full set — decode to float happens per batch on the
ingest path, see ingest/datasets.py). TinyImageNet is indexed by PATH
and images are read+decoded lazily per batch — the full set does not
need to fit in host memory. Image files decode via PIL when present
(the standard JPEGs) and via ``np.load`` for ``.npy`` files — the
dependency-free format the committed test fixtures use; a JPEG tree
without PIL fails with an actionable error instead of an import crash.

``write_*_fixture`` emit tiny synthetic datasets in the EXACT on-disk
formats above: the committed CI fixtures (tests/fixtures/) are generated
with them, and they double as executable format documentation.
"""
from __future__ import annotations

import glob
import os
import pickle
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

IMG_EXTENSIONS = (".jpeg", ".jpg", ".png", ".bmp", ".npy")


def _resolve(root: str, inner: str) -> str:
    """Accept either the dataset directory itself or its parent."""
    cand = os.path.join(root, inner)
    if os.path.isdir(cand):
        return cand
    if os.path.isdir(root):
        return root
    raise FileNotFoundError(
        f"no dataset at {root!r} (expected it to be, or to contain, "
        f"{inner!r})")


def _unpickle(path: str) -> dict:
    with open(path, "rb") as f:
        return pickle.load(f, encoding="bytes")


def _rows_to_images(rows: np.ndarray) -> np.ndarray:
    """CIFAR's (N, 3072) row-major RGB planes -> (N, 32, 32, 3) uint8."""
    n = rows.shape[0]
    return rows.reshape(n, 3, 32, 32).transpose(0, 2, 3, 1)


@dataclass
class ArrayImageData:
    """An in-memory uint8 image dataset (CIFAR-shaped)."""
    train_images: np.ndarray    # (N, H, W, 3) uint8
    train_labels: np.ndarray    # (N,) int32
    test_images: np.ndarray
    test_labels: np.ndarray

    @property
    def num_classes(self) -> int:
        return int(self.train_labels.max()) + 1


def _load_cifar_files(paths: List[str], label_key: bytes
                      ) -> Tuple[np.ndarray, np.ndarray]:
    imgs, labels = [], []
    for p in paths:
        d = _unpickle(p)
        imgs.append(_rows_to_images(np.asarray(d[b"data"], np.uint8)))
        labels.append(np.asarray(d[label_key], np.int32))
    return np.concatenate(imgs), np.concatenate(labels)


def load_cifar10(root: str) -> ArrayImageData:
    d = _resolve(root, "cifar-10-batches-py")
    train = sorted(glob.glob(os.path.join(d, "data_batch_*")))
    test = os.path.join(d, "test_batch")
    if not train or not os.path.exists(test):
        raise FileNotFoundError(
            f"{d} does not look like cifar-10-batches-py (need "
            "data_batch_* and test_batch)")
    tr = _load_cifar_files(train, b"labels")
    te = _load_cifar_files([test], b"labels")
    return ArrayImageData(*tr, *te)


def load_cifar100(root: str) -> ArrayImageData:
    d = _resolve(root, "cifar-100-python")
    train, test = os.path.join(d, "train"), os.path.join(d, "test")
    if not (os.path.exists(train) and os.path.exists(test)):
        raise FileNotFoundError(
            f"{d} does not look like cifar-100-python (need train + test)")
    tr = _load_cifar_files([train], b"fine_labels")
    te = _load_cifar_files([test], b"fine_labels")
    return ArrayImageData(*tr, *te)


# ---------------- TinyImageNet (path-indexed, lazy decode) ----------------

@dataclass
class ImageFileIndex:
    """A disk-backed image dataset indexed by path; pixels are read and
    decoded lazily, per batch, on the ingest path."""
    train_paths: List[str]
    train_labels: np.ndarray    # (N,) int32 indices into wnids
    val_paths: List[str]
    val_labels: np.ndarray
    wnids: List[str]

    @property
    def num_classes(self) -> int:
        return len(self.wnids)


def _list_images(d: str) -> List[str]:
    out = [p for p in sorted(glob.glob(os.path.join(d, "*")))
           if p.lower().endswith(IMG_EXTENSIONS)]
    return out


def load_tiny_imagenet(root: str) -> ImageFileIndex:
    d = _resolve(root, "tiny-imagenet-200")
    wnids_file = os.path.join(d, "wnids.txt")
    if not os.path.exists(wnids_file):
        raise FileNotFoundError(f"{d} has no wnids.txt — not a "
                                "tiny-imagenet-200 layout")
    with open(wnids_file) as f:
        wnids = [line.strip() for line in f if line.strip()]
    wnid_idx = {w: i for i, w in enumerate(wnids)}
    train_paths, train_labels = [], []
    for w in wnids:
        imgs = _list_images(os.path.join(d, "train", w, "images"))
        if not imgs:
            raise FileNotFoundError(f"no images under train/{w}/images")
        train_paths.extend(imgs)
        train_labels.extend([wnid_idx[w]] * len(imgs))
    val_paths, val_labels = [], []
    ann = os.path.join(d, "val", "val_annotations.txt")
    if os.path.exists(ann):
        by_name = {}
        with open(ann) as f:
            for line in f:
                parts = line.split()
                if len(parts) >= 2 and parts[1] in wnid_idx:
                    by_name[parts[0]] = wnid_idx[parts[1]]
        for p in _list_images(os.path.join(d, "val", "images")):
            name = os.path.basename(p)
            if name in by_name:
                val_paths.append(p)
                val_labels.append(by_name[name])
    return ImageFileIndex(train_paths,
                          np.asarray(train_labels, np.int32),
                          val_paths, np.asarray(val_labels, np.int32),
                          wnids)


def decode_image_file(path: str, image_size: Optional[int] = None
                      ) -> np.ndarray:
    """One image file -> (H, W, 3) uint8. ``.npy`` decodes with numpy
    alone (the fixture format); JPEG/PNG/BMP need PIL and fail with an
    actionable message when it is absent (this container-friendly gating
    is why the committed fixtures are .npy)."""
    if path.lower().endswith(".npy"):
        img = np.load(path)
    else:
        try:
            from PIL import Image
        except ImportError as e:        # pragma: no cover - env dependent
            raise RuntimeError(
                f"decoding {path} needs PIL (pip install pillow), which "
                "is not available here; re-encode the dataset as .npy "
                "files (ingest.readers accepts them in the same layout)"
            ) from e
        with Image.open(path) as im:
            img = np.asarray(im.convert("RGB"))
    img = np.asarray(img, np.uint8)
    if img.ndim == 2:                   # grayscale -> 3 channels
        img = np.repeat(img[..., None], 3, axis=-1)
    if image_size is not None and img.shape[:2] != (image_size, image_size):
        raise ValueError(f"{path}: expected {image_size}x{image_size}, "
                         f"got {img.shape[:2]}")
    return img


class ImageDecodePool:
    """Bounded thread pool for the per-image file decode of path-indexed
    sources (TinyImageNet). The read+decode of a batch's images is
    embarrassingly parallel and GIL-friendly (PIL decode and np.load
    release the GIL around I/O), so a few workers hide disk latency on
    the ingest path without touching memory behavior — images still
    materialize one batch at a time.

    ``workers <= 1`` decodes serially on the caller's thread (the
    default — ExecConfig.decode_workers=0). OUTPUT ORDER IS THE INPUT
    ORDER regardless of completion order (Executor.map semantics), so
    the batch stack is bit-identical to the serial decode and the
    (client, round) determinism contract of ingest/images.py holds.

    The pool is created lazily on first parallel decode, so constructing
    a source with workers configured costs nothing until data flows;
    ``close()`` joins the workers (idempotent; also safe to never call —
    executor threads exit with the interpreter).
    """

    def __init__(self, workers: int = 0):
        self.workers = max(0, int(workers))
        self._pool = None

    def _ensure(self):
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=self.workers,
                thread_name_prefix="repro-img-decode")
        return self._pool

    def decode(self, paths: List[str], image_size: Optional[int] = None
               ) -> List[np.ndarray]:
        if self.workers <= 1 or len(paths) <= 1:
            return [decode_image_file(p, image_size) for p in paths]
        return list(self._ensure().map(
            lambda p: decode_image_file(p, image_size), paths))

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


# ---------------- fixture writers (format round-trip) ----------------

def _fixture_images(rng: np.random.RandomState, labels: np.ndarray,
                    size: int) -> np.ndarray:
    """Class-conditional uint8 blobs: the per-image mean is pinned to
    ~(label % 10) * 23 + 25, so reader tests can verify the
    label<->pixel association survives the format round-trip (catches
    e.g. transposed CIFAR planes)."""
    n = len(labels)
    base = (labels[:, None, None, None].astype(np.float32) % 10) * 23.0 + 5.0
    noise = rng.randint(0, 40, size=(n, size, size, 3))
    return np.clip(base + noise, 0, 255).astype(np.uint8)


def write_cifar10_fixture(root: str, *, per_class: int = 4,
                          test_per_class: int = 2, train_batches: int = 2,
                          seed: int = 0) -> str:
    """Emit a tiny cifar-10-batches-py tree under ``root`` (all 10
    classes, ``per_class`` train images each, split over
    ``train_batches`` data_batch_* files)."""
    rng = np.random.RandomState(seed)
    d = os.path.join(root, "cifar-10-batches-py")
    os.makedirs(d, exist_ok=True)

    def dump(path, labels):
        imgs = _fixture_images(rng, labels, 32)
        rows = imgs.transpose(0, 3, 1, 2).reshape(len(labels), 3072)
        with open(path, "wb") as f:
            pickle.dump({b"data": rows,
                         b"labels": [int(y) for y in labels]}, f)

    train_y = np.repeat(np.arange(10), per_class)
    rng.shuffle(train_y)
    for i, part in enumerate(np.array_split(train_y, train_batches)):
        dump(os.path.join(d, f"data_batch_{i + 1}"), part)
    test_y = np.repeat(np.arange(10), test_per_class)
    dump(os.path.join(d, "test_batch"), test_y)
    return d


def write_cifar100_fixture(root: str, *, num_classes: int = 100,
                           per_class: int = 1, test_per_class: int = 1,
                           seed: int = 0) -> str:
    """Emit a tiny cifar-100-python tree under ``root`` (fine labels
    0..num_classes-1; the real set has 100)."""
    rng = np.random.RandomState(seed)
    d = os.path.join(root, "cifar-100-python")
    os.makedirs(d, exist_ok=True)

    def dump(path, labels):
        imgs = _fixture_images(rng, labels, 32)
        rows = imgs.transpose(0, 3, 1, 2).reshape(len(labels), 3072)
        with open(path, "wb") as f:
            pickle.dump({b"data": rows,
                         b"fine_labels": [int(y) for y in labels]}, f)

    dump(os.path.join(d, "train"), np.repeat(np.arange(num_classes),
                                             per_class))
    dump(os.path.join(d, "test"), np.repeat(np.arange(num_classes),
                                            test_per_class))
    return d


def write_tiny_imagenet_fixture(root: str, *, num_wnids: int = 4,
                                per_wnid: int = 4, val_per_wnid: int = 1,
                                image_size: int = 64, seed: int = 0) -> str:
    """Emit a tiny tiny-imagenet-200 tree under ``root``. Images are
    written as ``.npy`` (decodable without PIL — the committed-fixture
    format); the directory layout matches the real download exactly."""
    rng = np.random.RandomState(seed)
    d = os.path.join(root, "tiny-imagenet-200")
    wnids = [f"n{90000000 + i:08d}" for i in range(num_wnids)]
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "wnids.txt"), "w") as f:
        f.write("\n".join(wnids) + "\n")
    for ci, w in enumerate(wnids):
        img_dir = os.path.join(d, "train", w, "images")
        os.makedirs(img_dir, exist_ok=True)
        labels = np.full(per_wnid, ci, np.int64)
        imgs = _fixture_images(rng, labels, image_size)
        for i in range(per_wnid):
            np.save(os.path.join(img_dir, f"{w}_{i}.npy"), imgs[i])
    val_dir = os.path.join(d, "val", "images")
    os.makedirs(val_dir, exist_ok=True)
    lines = []
    for ci, w in enumerate(wnids):
        labels = np.full(val_per_wnid, ci, np.int64)
        imgs = _fixture_images(rng, labels, image_size)
        for i in range(val_per_wnid):
            name = f"val_{w}_{i}.npy"
            np.save(os.path.join(val_dir, name), imgs[i])
            lines.append(f"{name}\t{w}\t0\t0\t{image_size}\t{image_size}")
    with open(os.path.join(d, "val", "val_annotations.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    return d
