# Staged ingest subsystem (DESIGN.md §10): everything between a
# DataSource and the fused cohort round's dispatch —
#
#   read -> decode/augment -> cohort-stack -> device-place
#
# One import point for the whole pipeline: the source protocol +
# adapters, the stacking stage, the depth-N staging ring, device
# placement, the orchestrating CohortIngestPipeline, the array-backed
# synthetic image pipeline, and the disk-backed dataset sources.
from repro.ingest.datasets import (CIFAR10Source, CIFAR100Source,
                                   DiskImageSource, TinyImageNetSource,
                                   augment_images, decode_images)
from repro.ingest.images import (FederatedImageData, StreamingImageSource,
                                 build_federated_image_data, client_batches)
from repro.ingest.pipeline import CohortIngestPipeline, StagedCohort
from repro.ingest.placement import CohortPlacer
from repro.ingest.prefetch import CohortPrefetcher
from repro.ingest.sources import (DataSource, IteratorDataSource,
                                  ListDataSource, as_data_source)
from repro.ingest.stack import stack_batches, stack_cohort, stack_cohort_into

__all__ = [
    "CIFAR10Source", "CIFAR100Source", "DiskImageSource",
    "TinyImageNetSource", "augment_images", "decode_images",
    "FederatedImageData", "StreamingImageSource",
    "build_federated_image_data", "client_batches",
    "CohortIngestPipeline", "StagedCohort", "CohortPlacer",
    "CohortPrefetcher",
    "DataSource", "IteratorDataSource", "ListDataSource", "as_data_source",
    "stack_batches", "stack_cohort", "stack_cohort_into",
]
