"""DEPRECATED shim — the client data pipeline moved to ``repro.ingest``
(ingest/images.py; DESIGN.md §10), next to the staged ingest subsystem's
disk-backed dataset sources (ingest/datasets.py).

Importing from this module still works for one release but warns
(attributed to the caller — the CI gate errors on DeprecationWarnings
raised FROM repro.*, so library code must import ``repro.ingest``
directly). The forwarded objects are IDENTICAL to the new ones.
"""
from __future__ import annotations

import warnings

_MOVED = ("FederatedImageData", "build_federated_image_data",
          "client_batches", "StreamingImageSource")


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.data.pipeline.{name} moved to repro.ingest.{name} "
            "(DESIGN.md §10); this alias will be removed next release",
            DeprecationWarning, stacklevel=2)
        import repro.ingest
        return getattr(repro.ingest, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
