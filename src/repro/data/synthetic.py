"""Synthetic datasets.

No real CIFAR/TinyImageNet is available offline, so the faithful FedDPC
reproduction trains on *class-conditional structured images*: each class
is a Gaussian mixture around a class template with per-class frequency
patterns — learnable by LeNet/ResNet but non-trivial, giving the same
qualitative optimization landscape (heterogeneous multi-class vision).

LM token streams (for the federated LLM substrate) are Zipf-sampled with
per-client topic skew.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_image_dataset(num_classes: int, samples_per_class: int,
                       image_size: int = 32, channels: int = 3,
                       seed: int = 0, noise: float = 0.35
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """-> images (N, H, W, C) float32 in [-1, 1], labels (N,) int32."""
    rng = np.random.RandomState(seed)
    h = w = image_size
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    templates = []
    for c in range(num_classes):
        fx, fy = 1 + (c % 5), 1 + ((c // 5) % 5)
        phase = 2 * np.pi * (c / max(num_classes, 1))
        base = (np.sin(2 * np.pi * fx * xx / w + phase)
                * np.cos(2 * np.pi * fy * yy / h))
        chans = np.stack([np.roll(base, shift=k * 3, axis=1)
                          for k in range(channels)], axis=-1)
        templates.append(chans)
    templates = np.stack(templates)                    # (C, H, W, ch)

    images = np.empty((num_classes * samples_per_class, h, w, channels),
                      np.float32)
    labels = np.empty((num_classes * samples_per_class,), np.int32)
    for c in range(num_classes):
        sl = slice(c * samples_per_class, (c + 1) * samples_per_class)
        jitter = rng.randn(samples_per_class, 1, 1, 1).astype(np.float32) * 0.2
        images[sl] = (templates[c][None] * (1 + jitter)
                      + noise * rng.randn(samples_per_class, h, w, channels))
        labels[sl] = c
    perm = rng.permutation(len(labels))
    return np.clip(images[perm], -3, 3), labels[perm]


def make_lm_dataset(num_docs: int, seq_len: int, vocab_size: int,
                    seed: int = 0, num_topics: int = 16
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Zipf token streams with topic structure.
    -> tokens (N, S) int32, topic (N,) int32 (the heterogeneity label)."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    base = 1.0 / ranks ** 1.1
    tokens = np.empty((num_docs, seq_len), np.int32)
    topics = rng.randint(0, num_topics, size=num_docs).astype(np.int32)
    for topic in range(num_topics):
        idx = np.where(topics == topic)[0]
        if len(idx) == 0:
            continue
        boost = np.ones(vocab_size)
        boost_idx = np.random.RandomState(seed * 997 + topic).choice(
            vocab_size, size=max(vocab_size // 20, 1), replace=False)
        boost[boost_idx] *= 8.0
        p = base * boost
        p /= p.sum()
        tokens[idx] = np.random.RandomState(seed * 31 + topic).choice(
            vocab_size, size=(len(idx), seq_len), p=p).astype(np.int32)
    return tokens, topics
