"""Heterogeneous client partitioning via Dirichlet allocation
(Yurochkin et al. scheme, as used by FedDPC §5.1).

For each class r, sample P_r ~ Dir_k(alpha) and give client j a
P_{r,j} fraction of class r's samples. Small alpha -> highly skewed
label distributions per client.
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    """-> list of index arrays, one per client (disjoint, covering all)."""
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    for _ in range(100):
        client_idx = [[] for _ in range(num_clients)]
        for r in classes:
            idx_r = np.where(labels == r)[0]
            rng.shuffle(idx_r)
            p = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(p) * len(idx_r)).astype(int)[:-1]
            for j, part in enumerate(np.split(idx_r, cuts)):
                client_idx[j].extend(part.tolist())
        sizes = [len(c) for c in client_idx]
        if min(sizes) >= min_size:
            break
    else:
        # retries exhausted: force-feed starved clients from the largest
        # ones so every client can form at least one batch
        for j in range(num_clients):
            while len(client_idx[j]) < min_size:
                donor = max(range(num_clients),
                            key=lambda i: len(client_idx[i]))
                if donor == j or len(client_idx[donor]) <= min_size:
                    break
                client_idx[j].append(client_idx[donor].pop())
    return [np.asarray(sorted(c), dtype=np.int64) for c in client_idx]


def partition_stats(labels: np.ndarray, parts: List[np.ndarray]) -> dict:
    classes = np.unique(labels)
    mat = np.zeros((len(parts), len(classes)))
    for j, idx in enumerate(parts):
        for ci, c in enumerate(classes):
            mat[j, ci] = np.sum(labels[idx] == c)
    row = mat / np.maximum(mat.sum(1, keepdims=True), 1)
    uni = np.full(len(classes), 1.0 / len(classes))
    tv = 0.5 * np.abs(row - uni).sum(1)           # total-variation from uniform
    return {"sizes": mat.sum(1).astype(int).tolist(),
            "mean_tv_from_uniform": float(tv.mean()),
            "max_tv_from_uniform": float(tv.max())}
