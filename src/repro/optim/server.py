"""Server-side optimizers as first-class registry citizens (DESIGN.md
§14): a ``ServerOptimizer`` consumes the POST-projection aggregate —
the new params every server rule proposes — so one implementation works
identically across the serial / vectorized / sharded / buffered-async
execution paths, and composes with ANY registered algorithm (FedDPC's
reduction-pass scalars are still computed from the raw deltas before
the optimizer ever sees the result).

The optimizer's view of a round is the pseudo-gradient

    g_t = params_t - proposed_{t+1}        (f32, per leaf)

i.e. the effective descent direction the algorithm's step applied
(its eta_g included). ``sgd`` accepts the proposal verbatim — it is
STATELESS and never enters the compiled round, which is what makes it
the bitwise anchor: with ``server_opt in (None, "sgd")`` the jit
signature and the traced program are byte-identical to the pre-layer
round. ``fedadam`` / ``fedyogi`` re-step from params_t with
moment-preconditioned magnitudes (Reddi et al. [9], the same
second-moment rules as the whole-algorithm fedadam/fedyogi entries in
core/baselines.py, here composable with every rule):

    m = b1 m + (1-b1) g
    v = b2 v + (1-b2) g^2                      (adam)
    v = v - (1-b2) g^2 sign(v - g^2)           (yogi)
    params_{t+1} = params_t - lr * m / (sqrt(v) + eps)

State is ``{"m": <params mirror>, "v": <params mirror>}`` in f32, so
``sharding/rules.cohort_state_specs`` path rules place the moments
exactly like the matching param leaf on the two-axis mesh, and the
trainer checkpoints the leaves bitwise through the aux sidecar
(``server_opt_{i}`` arrays — see core/api.save/restore).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class ServerOptimizer:
    """``init(params) -> state``; ``apply(params, proposed, state) ->
    (new_params, new_state)`` — pure functions, traced inside the round's
    jit. ``stateful=False`` marks a literal pass-through the trainer
    keeps OUT of the compiled program entirely (the bitwise anchor)."""
    name: str
    init: Callable[[PyTree], PyTree]
    apply: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]
    stateful: bool = True

    def config_dict(self) -> Dict[str, Any]:
        return {"name": self.name}


def _sgd() -> ServerOptimizer:
    def init(params):
        return None

    def apply(params, proposed, state):
        return proposed, state

    return ServerOptimizer("sgd", init, apply, stateful=False)


def _moment_init(params):
    z = lambda p: jnp.zeros(jnp.shape(p), jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}


def _make_adaptive(kind: str, lr: float = 0.1, b1: float = 0.9,
                   b2: float = 0.99, eps: float = 1e-3) -> ServerOptimizer:
    def apply(params, proposed, state):
        g = jax.tree.map(
            lambda p, q: p.astype(jnp.float32) - q.astype(jnp.float32),
            params, proposed)
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg,
                         state["m"], g)
        if kind == "adam":
            v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg,
                             state["v"], g)
        else:   # yogi
            v = jax.tree.map(
                lambda vv, gg: vv - (1 - b2) * gg * gg
                * jnp.sign(vv - gg * gg), state["v"], g)
        new = jax.tree.map(
            lambda p, mm, vv: (p.astype(jnp.float32)
                               - lr * mm / (jnp.sqrt(vv) + eps)
                               ).astype(p.dtype), params, m, v)
        return new, {"m": m, "v": v}

    return ServerOptimizer(f"fed{kind}", _moment_init, apply)


_REGISTRY: Dict[str, Callable[[], ServerOptimizer]] = {
    "sgd": _sgd,
    "fedadam": lambda: _make_adaptive("adam"),
    "fedyogi": lambda: _make_adaptive("yogi"),
}

SERVER_OPTIMIZER_NAMES = tuple(_REGISTRY)


def make_server_optimizer(name: Optional[str]) -> Optional[ServerOptimizer]:
    """Resolve a registry name; ``None`` and ``"sgd"`` both yield None —
    the trainer treats "no optimizer object" as the pass-through anchor,
    adding NOTHING to the jit signature (bitwise with the pre-layer
    round by construction)."""
    if name is None:
        return None
    if name not in _REGISTRY:
        raise ValueError(f"unknown server optimizer {name!r} "
                         f"(registered: {sorted(_REGISTRY)})")
    opt = _REGISTRY[name]()
    return opt if opt.stateful else None
