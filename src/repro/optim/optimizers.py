"""Minimal pure-pytree optimizers (SGD / momentum / AdamW) + schedules.

Interface mirrors optax: init(params) -> state; update(grads, state, params)
-> (updates, state); apply: params - updates (note the sign convention:
updates are SUBTRACTED, matching the paper's w <- w - eta*g).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], Tuple[PyTree, PyTree]]
    # update(grads, state, params, step) -> (updates, new_state)


def sgd(learning_rate: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        return jax.tree.map(lambda g: learning_rate * g, grads), state

    return Optimizer(init, update)


def momentum(learning_rate: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        new_m = jax.tree.map(lambda m, g: beta * m + g, state, grads)
        return jax.tree.map(lambda m: learning_rate * m, new_m), new_m

    return Optimizer(init, update)


def adamw(learning_rate: float, b1=0.9, b2=0.999, eps=1e-8,
          weight_decay=0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        def upd(m_, v_, p):
            mh = m_ / (1 - b1 ** t)
            vh = v_ / (1 - b2 ** t)
            u = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (learning_rate * u).astype(p.dtype)
        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](lr, **kw)


# ---------------- schedules ----------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)
