"""Three-term roofline from a compiled dry-run artifact (DESIGN.md §9).

  compute    = HLO_FLOPs        / (chips * PEAK_FLOPS_BF16)
  memory     = HLO_bytes        / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the *optimized* (post-SPMD)
HLO text and sum operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.  cost_analysis
reports per-device numbers for SPMD-partitioned modules, so terms are
divided by nothing further — ``chips`` enters only through the peak
rates when cost_analysis is whole-module (we detect which convention by
comparing with the mesh size).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

# e.g.  %ag = bf16[16,1024,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+(" +
    "|".join(_COLLECTIVE_OPS) + r")[\s(]")
# tuple-result collectives: (bf16[..], bf16[..]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s+(" +
    "|".join(_COLLECTIVE_OPS) + r")[\s(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the optimized HLO."""
    out = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        if m:
            out[m.group(3)] += _shape_bytes(m.group(1), m.group(2))
            continue
        m = _TUPLE_RE.search(line)
        if m:
            total = sum(_shape_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(m.group(1)))
            out[m.group(2)] += total
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: Dict[str, int] = field(default_factory=dict)
    model_flops_total: float = 0.0   # 6*N*D useful flops (whole step)
    memory_per_device: float = 0.0   # from memory_analysis

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_total / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs) — how much compiled compute
        is 'useful' model math (catches remat/redundancy waste)."""
        total_hlo = self.flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes": dict(self.coll_bytes),
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops_total,
            "useful_fraction": self.useful_fraction,
            "memory_per_device_bytes": self.memory_per_device,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens in the
    step; x3 for training (fwd+bwd). Decode processes B*1 tokens.
    Encoder-decoder (whisper): decoder length is capped at max_seq_len (the
    32k/500k shapes are cache-capacity stress shapes, not real decode
    lengths), plus the encoder runs once over encoder_seq_len frames."""
    counts = cfg.param_counts()
    n = counts["active"]
    seq = shape.seq_len
    if cfg.is_encoder_decoder:
        seq = min(seq, cfg.max_seq_len)
    if shape.kind == "train":
        tokens = shape.global_batch * seq
        mult = 6.0                      # 2 fwd + 4 bwd per param per token
    elif shape.kind == "prefill":
        tokens = shape.global_batch * seq
        mult = 2.0
    else:
        tokens = shape.global_batch * 1
        mult = 2.0
    return mult * n * tokens


def analyze_compiled(compiled, *, arch: str, shape_name: str, mesh_name: str,
                     chips: int, model_flops_total: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                    flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    model_flops_total=model_flops_total,
                    memory_per_device=mem)


def _fmt_secs(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def roofline_report(rl: Roofline) -> str:
    lines = [
        f"### {rl.arch} x {rl.shape} on {rl.mesh} ({rl.chips} chips)",
        f"- compute    term: {_fmt_secs(rl.t_compute)}  "
        f"({rl.flops:.3e} FLOP/device)",
        f"- memory     term: {_fmt_secs(rl.t_memory)}  "
        f"({rl.hbm_bytes:.3e} B/device)",
        f"- collective term: {_fmt_secs(rl.t_collective)}  "
        f"({rl.coll_total:.3e} B; " + ", ".join(
            f"{k}={v:.2e}" for k, v in rl.coll_bytes.items() if v) + ")",
        f"- dominant: **{rl.dominant}**",
        f"- MODEL_FLOPS={rl.model_flops_total:.3e}, "
        f"useful fraction={rl.useful_fraction:.3f}",
        f"- memory/device: {rl.memory_per_device / 1e9:.2f} GB",
    ]
    return "\n".join(lines)
