from repro.roofline.analysis import (analyze_compiled, collective_bytes,
                                     roofline_report, model_flops)
