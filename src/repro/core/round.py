"""Fused FL round step — local training vmapped over the client axis +
server aggregation in ONE jit'd program (DESIGN.md §2).

``make_cohort_round`` is the single implementation behind both execution
modes:

  simulation (core/api.py)   the FederatedTrainer's default path: the
      cohort's padded minibatch stacks arrive as one (K, M, ...) batch
      pytree with a (K, M) validity mask, and the whole round — K local
      trainings + the server rule — is one dispatch, donating the
      params/server-state buffers.
  cross-silo mesh (``make_fl_round_step``)   the (pod x data) axes form
      the CLIENT axis — each (pod, data) slice is one participating silo
      training a model-parallel replica (weights replicated over client
      axes, Megatron-sharded over ``model``). Partial participation =
      which silos show up this round; a pod boundary is a datacenter
      boundary.

FedDPC's epilogue stays collective-native under GSPMD: per-client scalars
<Δ_j,Δ_prev>, ||Δ_j||², ||Δ_prev||² reduce over every model-sharding axis
automatically (4 scalar all-reduces), the projection/scaling is
elementwise on the sharded update shards, and the client-mean is one
all-reduce over the client axes — asymptotically the same collective
volume as FedAvg (the paper's server loop is O(4k'd) *serial*; here it is
fused into the data-parallel reduction).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.codec import base as codec_base
from repro.core import client as client_mod
from repro.core import faults as faults_mod
from repro.core import projection as proj
from repro.core.baselines import ServerAlgo, client_kwargs, make_algorithm

PyTree = Any

# out-of-range id for quarantined / deadline-dropped rows: FedVARP's
# masked scatter (mode="drop") only ignores OUT-OF-RANGE ids — an
# in-range id would still clobber that client's table row even with its
# client_mask bit cleared (DESIGN.md §12)
ID_SENTINEL = 2_147_483_647      # jnp.iinfo(jnp.int32).max


def _bc(v: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (K,) vector over a client-stacked leaf."""
    return v.reshape((-1,) + (1,) * (x.ndim - 1))


def apply_fault_codes(deltas: PyTree, fault_codes: jnp.ndarray,
                      magnitude: float) -> PyTree:
    """Chaos harness (core/faults.py): corrupt the coded clients' rows of
    a client-stacked delta tree — CODE_NAN fills with NaN, CODE_EXPLODE
    multiplies by ``magnitude`` — AFTER local training, BEFORE
    validation/aggregation: exactly where a byzantine or numerically-
    diverged client would surface in a real deployment. Shared by the
    fused sync round and the buffered-async fold."""
    mult = jnp.where(fault_codes == faults_mod.CODE_EXPLODE,
                     jnp.float32(magnitude), jnp.float32(1.0))
    return jax.tree.map(
        lambda d: jnp.where(
            _bc(fault_codes == faults_mod.CODE_NAN, d), jnp.nan,
            d.astype(jnp.float32) * _bc(mult, d)).astype(d.dtype),
        deltas)


def apply_guard(deltas: PyTree, client_ids: jnp.ndarray, client_mask,
                guard_thresh, guard_cfg):
    """Update-guard validation (core/guards.py, DESIGN.md §12) on a
    client-stacked delta tree: per-row ||Δ||² + non-finite count (the
    same dim-preserving reduction pass FedDPC's projection scalars ride;
    fused Pallas form in kernels/feddpc_project.guard_dots), quarantine
    on any non-finite entry or norm > quarantine_mult x thresh, clip to
    clip_mult x thresh otherwise.

    Quarantined rows are ZEROED in-program — client_mask folding alone is
    NOT enough, because a masked row still multiplies into the masked
    means (0 x NaN = NaN) — their ids are replaced by the out-of-range
    sentinel, and they fold into ``client_mask``, so every server rule
    stays exact with zero rule changes. With thresh = +inf and finite
    rows every multiplier is exactly 1.0: the guarded computation IS the
    unguarded one. Returns (deltas, client_ids, client_mask, stats) with
    stats = {"quarantined": (K,) bool, "clipped": (K,) bool,
    "norm": (K,) f32 post-clip norms}."""
    sq = jax.vmap(proj.tree_sqnorm)(deltas)                   # (K,)
    nfin = jax.vmap(proj.tree_nonfinite_count)(deltas)        # (K,)
    norm = jnp.sqrt(sq)
    thresh = jnp.asarray(guard_thresh, jnp.float32)
    q_lim = jnp.float32(guard_cfg.quarantine_mult) * thresh
    c_lim = jnp.float32(guard_cfg.clip_mult) * thresh
    bad = (nfin > 0) | (norm > q_lim)
    # clip multiplier is EXACTLY 1.0 below the limit (and always 1.0
    # while thresh is +inf); a NaN norm compares False, so a non-finite
    # row takes cs=1.0 — harmless, it is where-selected to zero below
    cs = jnp.where(norm > c_lim, c_lim / jnp.maximum(norm, proj.EPS),
                   jnp.float32(1.0))
    clipped = jnp.logical_and(~bad, norm > c_lim)
    deltas = jax.tree.map(
        lambda d: jnp.where(_bc(bad, d), jnp.float32(0.0),
                            d.astype(jnp.float32) * _bc(cs, d)
                            ).astype(d.dtype), deltas)
    client_ids = jnp.where(bad, ID_SENTINEL, client_ids.astype(jnp.int32))
    client_mask = ~bad if client_mask is None else client_mask & ~bad
    stats = {"quarantined": bad, "clipped": clipped,
             "norm": jnp.minimum(norm, c_lim)}
    return deltas, client_ids, client_mask, stats


def make_cohort_round(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                      algo: ServerAlgo, eta_l: float, eta_g: float, *,
                      optimizer: str = "sgd",
                      jit: bool = True, donate: bool = True,
                      mesh=None, client_axis: str = "clients",
                      model_axis: str = "model",
                      pad_clients: bool = False,
                      real_clients: int = None,
                      shard_templates: Tuple[PyTree, PyTree] = None,
                      shardings=None,
                      guard: bool = False, guard_cfg=None,
                      inject_faults: bool = False,
                      deadline_mask: bool = False,
                      fault_magnitude: float = 1e12,
                      codec=None, codec_ef: bool = False,
                      server_opt=None, edges=None):
    """Returns cohort_round(server_state, params, batches, masks,
    client_ids, *extras) -> (new_params, new_server_state, losses, diag
    [, guard_stats]).

    The chaos-hardening extras (DESIGN.md §12) extend the signature in a
    FIXED order — ``inject_faults`` appends a (K,) int32 ``fault_codes``
    input (0 = pass through, faults.CODE_NAN fills the client's delta
    with NaN, faults.CODE_EXPLODE multiplies it by ``fault_magnitude``),
    ``deadline_mask`` appends a (K,) bool ``live_mask`` (False = the
    client timed out: its row folds out of ``client_mask`` and its id is
    replaced by an out-of-range sentinel so FedVARP's scatter drops it),
    and ``guard`` appends a scalar f32 ``guard_thresh`` and a trailing
    ``guard_stats`` output.

    The codec extras (repro/codec, DESIGN.md §13) extend that order: with
    a LOSSY ``codec`` (identity never enters the program) the round
    encodes each client's shipped delta, decodes it, and aggregates the
    DECODED values — so the simulated uplink carries exactly the codec's
    wire format. A stochastic codec appends a PRNG-key input after
    ``guard_thresh``; ``codec_ef=True`` appends a params-shaped f32
    error-feedback accumulator input after that, and a matching
    ``new_ef`` output after ``guard_stats``. EF uses broadcast
    compensation: every client ships ``Delta_j + ef`` and the new
    accumulator is the guarded-client mean of the sanitized
    (nonfinite-zeroed) quantization residuals. The guard, when enabled,
    reads the decoded (quantized-domain) norms; the fused Pallas dequant
    epilogue is only engaged when the guard is off, because quarantine/
    clip rewrite decoded rows that the payload scalars no longer
    describe.

    A STATEFUL ``server_opt`` (repro.optim.server, DESIGN.md §14)
    extends the order one last time: the optimizer's moment state is the
    LAST extra input and the updated state the LAST output. It consumes
    the POST-projection aggregate — ``algo.step``'s proposed params —
    and re-steps from the round's incoming params, so it composes with
    every registered rule without touching the rule's own math.
    ``server_opt=None`` (the sgd pass-through) leaves the program
    byte-identical to the pre-layer round.

    The guard validates every delta BEFORE the server rule sees it:
    per-client ||Δ||² + non-finite count (the reduction-pass sweep the
    FedDPC scalars come from; fused Pallas form in
    kernels/feddpc_project.guard_dots), quarantine on any non-finite
    entry or ||Δ|| > quarantine_mult x thresh, clip to
    clip_mult x thresh otherwise. Quarantined deltas are ZEROED in-
    program — client_mask folding alone is NOT enough, because FedDPC's
    scale=0 still multiplies the delta (0 x NaN = NaN) — their ids are
    sentineled, and their rows fold into ``client_mask``, so every
    server rule stays exact with zero rule changes. With thresh = +inf
    and no non-finite entries every multiplier is exactly 1.0 and the
    guarded round computes the unguarded round's values.
    ``guard_stats`` = {"quarantined": (K,) bool, "clipped": (K,) bool,
    "norm": (K,) f32 post-clip norms} — the trainer filters real rows
    and feeds accepted norms back into the rolling threshold window.

    batches: pytree with leading axes (K, M, ...) — K participating
    clients, M padded minibatches each; masks (K, M) bool marks the valid
    ones (None = all valid, skipping the masked-select pass entirely).
    client_ids (K,) int32 feeds stateful server rules (FedVARP's
    per-client table). The server-side ``extra`` (Delta_prev for the
    cm/ga client variants) is derived from server_state INSIDE the
    program, so the round is one closed jit'd function of
    (state, params, data).

    With jit=True the state/params buffers are donated: the round updates
    them in place, which keeps FedVARP's O(num_clients * d) table from
    being double-buffered every round.

    With ``mesh`` set (a mesh carrying ``client_axis``, e.g.
    launch/mesh.make_cohort_mesh), the K axis of batches/masks/client_ids
    gets a client-axis NamedSharding and the round runs data-parallel
    across the mesh devices with params/server-state replicated
    (sharding/rules.cohort_round_shardings — DESIGN.md §2). K should be a
    multiple of the axis size; with ``pad_clients=True`` the caller pads
    the cohort stack itself (dummy rows with all-False mask rows and
    out-of-range client_ids) and the round masks dummy clients out of
    every server mean and out of FedVARP's table.

    ``real_clients`` is how the caller communicates its pad count: the
    first ``real_clients`` rows are sampled clients, the rest padding.
    Prefer it over bare ``pad_clients=True``, which falls back to
    deriving the validity mask from ``masks.any(axis=1)`` — that
    reclassifies a genuinely sampled client whose every minibatch is
    invalid (a zero-data client) as padding, silently dropping its id
    from FedVARP's table while its (zero) delta still dilutes nothing
    but its loss row still enters ``losses``.

    A TWO-AXIS mesh (``model_axis`` present with size > 1, built by
    make_cohort_mesh(model=N)) additionally shards params / server state
    per leaf over ``model`` (§8 rules + trailing-dim fallback) so each
    client slice carries only 1/|model| of the weights. That layout
    needs shape templates: ``shard_templates=(params, server_state)``
    (only shapes are read). Deltas inherit the (clients, model) layout
    from the vmapped local training, the FedDPC reduction-pass scalars
    reduce over BOTH axes automatically (dim-preserving per-leaf sums ->
    GSPMD inserts the model-axis psum before the scale is formed), and
    the server rule sees ``model_sharded=True`` so the Pallas epilogue
    falls back to the reference path (its flatten would all-gather the
    shards).

    ``edges=E`` (E > 1) runs the server rule as a two-level hierarchical
    fold (DESIGN.md §15): the K cohort rows split into E equal contiguous
    edge groups, each edge folds its slice with the SAME fused epilogue
    (Pallas / codec-dequant grids included) to a partial summary, and the
    server combines the E summaries. FedDPC's reduction-pass scalars are
    dim-preserving sums, so only the final client-mean decomposes — the
    result is allclose to the flat fold (float summation order only). On
    a process-spanning clients mesh each contiguous group is one host's
    local shard, so the cross-host traffic is E summaries, not K rows.
    E must divide K (the padded cohort size).

    The per-variant local-training knobs (mu / cm_alpha / ga_beta) come
    from the algorithm's own hyperparameters (``algo.client_hparams``);
    anything the algorithm leaves unset keeps the local-update builder's
    defaults.
    """
    local = client_mod.make_cohort_local_update(
        loss_fn, eta_l, variant=algo.client_variant, optimizer=optimizer,
        **client_kwargs(algo))
    model_sharded = bool(
        mesh is not None and model_axis in mesh.axis_names
        and dict(zip(mesh.axis_names, mesh.devices.shape))[model_axis] > 1)
    # codec stage (repro/codec, DESIGN.md §13): only LOSSY codecs enter
    # the program — identity is a literal pass-through and compiles to
    # the no-codec round. Error feedback adds one params-shaped f32
    # accumulator input + output; stochastic rounding adds a key input.
    codec_lossy = bool(codec is not None and codec.lossy)
    ef_active = bool(codec_lossy and codec_ef)
    codec_stochastic = bool(codec_lossy
                            and getattr(codec, "stochastic", False))

    def cohort_round(server_state, params, batches, masks, client_ids,
                     *extras):
        it = iter(extras)
        fault_codes = next(it) if inject_faults else None
        live_mask = next(it) if deadline_mask else None
        guard_thresh = next(it) if guard else None
        codec_key = next(it) if codec_stochastic else None
        ef = next(it) if ef_active else None
        opt_state = next(it) if server_opt is not None else None
        extra = algo.client_extra(server_state)
        deltas, losses = local(params, batches, masks, extra)
        if inject_faults:
            deltas = apply_fault_codes(deltas, fault_codes, fault_magnitude)
        if real_clients is not None:
            # pad mask from the caller's pad count: rows >= real_clients
            # are padding, everything below is a sampled client — even
            # one whose every minibatch is masked (zero-data client)
            cm = jnp.arange(client_ids.shape[0]) < real_clients
        elif pad_clients and masks is not None:
            # legacy fallback for callers that only know "padded":
            # misclassifies zero-data clients as padding (see docstring)
            cm = masks.any(axis=1)
        else:
            cm = None
        if deadline_mask:
            client_ids = jnp.where(live_mask, client_ids.astype(jnp.int32),
                                   ID_SENTINEL)
            cm = live_mask if cm is None else cm & live_mask
        payload = shipped = decoded = None
        if codec_lossy:
            # what each client SHIPS: its delta plus the server-held
            # error-feedback residual (broadcast compensation — the same
            # accumulator is folded into every row, so a mean-style rule
            # recovers the compensation exactly in aggregate)
            shipped = deltas
            if ef_active:
                shipped = jax.tree.map(
                    lambda d, e: (d.astype(jnp.float32)
                                  + e.astype(jnp.float32)[None]
                                  ).astype(d.dtype), deltas, ef)
            payload = codec.encode_cohort(shipped, key=codec_key)
            decoded = codec.decode_cohort(payload)
            deltas = decoded
        gstats = None
        if guard:
            # quarantine/clip decisions read the DECODED (quantized-
            # domain) deltas — the values the server would aggregate
            deltas, client_ids, cm, gstats = apply_guard(
                deltas, client_ids, cm, guard_thresh, guard_cfg)
        new_ef = None
        if ef_active:
            # residual of the PRE-guard decode (pure quantization error;
            # a clipped row's shaved mass is a guard decision, not lost
            # signal), nonfinite-sanitized so faulty rows cannot poison
            # the accumulator, mean over the surviving clients only
            resid = codec_base.sanitized_residual(shipped, decoded)
            new_ef = proj.masked_client_mean(resid, cm)
        # the fused dequant epilogue re-derives the decoded rows from the
        # payload scalars, so it is only handed over when the guard has
        # NOT rewritten rows between decode and aggregation
        new_params, new_state, diag = algo.step(
            server_state, params, deltas, client_ids, eta_g, 0,
            client_mask=cm, model_sharded=model_sharded,
            encoded=(payload if codec_lossy and not guard else None),
            edges=edges)
        new_opt = None
        if server_opt is not None:
            # adaptive server step (DESIGN.md §14): precondition the
            # POST-projection aggregate — re-step from the round's
            # incoming params with moment-scaled magnitudes
            new_params, new_opt = server_opt.apply(params, new_params,
                                                   opt_state)
        outs = [new_params, new_state, losses, diag]
        if guard:
            outs.append(gstats)
        if ef_active:
            outs.append(new_ef)
        if server_opt is not None:
            outs.append(new_opt)
        return tuple(outs)

    if not jit:
        return cohort_round
    kw = {"donate_argnums": (0, 1) if donate else ()}
    if mesh is not None:
        if shardings is None:
            # `shardings` lets a caller that already built the
            # (in, out) pair (the trainer, which also pre-places the
            # state with it) avoid recomputing the per-leaf specs
            from repro.sharding.rules import cohort_round_shardings
            tmpl_p, tmpl_s = shard_templates or (None, None)
            shardings = cohort_round_shardings(
                mesh, client_axis, model_axis=model_axis, params=tmpl_p,
                server_state=tmpl_s)
        kw["in_shardings"], kw["out_shardings"] = shardings
    return jax.jit(cohort_round, **kw)


def make_fl_round_step(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                       eta_l: float, eta_g: float, lam: float = 1.0,
                       algorithm: str = "feddpc", *,
                       mesh=None, client_axis: str = "clients",
                       model_axis: str = "model",
                       params_template: PyTree = None):
    """Mesh-path wrapper: round_step(params, delta_prev, batches) ->
    (new_params, new_delta_prev, metrics).

    batches: pytree whose leaves have leading axes (K, M, ...) — K
    participating clients (sharded over the mesh client axes), M local
    steps each, all valid (no mask: the mesh path feeds fixed-shape
    silo streams). loss_fn(params, batch) -> scalar. Supports any
    algorithm whose server state is exactly {"delta_prev"} (feddpc,
    fedavg, fedexp, ...); per-client-stateful rules (fedvarp) need the
    full ``make_cohort_round`` interface.

    Two sharding modes share this one implementation:
      * mesh=None (default): returns the raw python fn — the cross-silo
        dry-run jits it with full Megatron param shardings externally
        (launch/dryrun.fl_round_dryrun).
      * mesh=<1-D client mesh>: returns a jit'd round with the same
        client-axis NamedSharding layout as the simulation trainer
        (batches sharded on K over ``client_axis``, params/delta_prev
        replicated) — the unified sharded round of DESIGN.md §2.
      * mesh=<two-axis (clients, model) mesh>: params AND delta_prev
        shard per leaf over ``model_axis`` (sharding/rules.
        cohort_param_specs); needs ``params_template`` for the leaf
        shapes, and fails loudly without it.
    """
    hyper = {"lam": lam} if algorithm in ("feddpc", "feddpc_m") else None
    algo = make_algorithm(algorithm, hyper)
    probe = algo.init({"w": jnp.zeros(())}, 1)
    if set(probe) != {"delta_prev"}:
        raise ValueError(
            f"make_fl_round_step supports algorithms whose server state is "
            f"exactly {{'delta_prev'}}; {algorithm!r} keeps {sorted(probe)} "
            f"— use make_cohort_round for stateful server rules")
    # mesh/model_axis are forwarded so the raw round still sees
    # model_sharded=True on a two-axis mesh (the Pallas-fallback contract
    # holds on this entry point too); jit=False leaves the sharding
    # layout to the external jit below
    cohort = make_cohort_round(loss_fn, algo, eta_l, eta_g, jit=False,
                               mesh=mesh, model_axis=model_axis)

    def round_step(params, delta_prev, batches):
        k = jax.tree.leaves(batches)[0].shape[0]
        ids = jnp.arange(k, dtype=jnp.int32)
        # masks=None: fixed-shape silo streams are all-valid, and the
        # select-free scan avoids an extra full-parameter pass per step
        new_params, new_state, losses, diag = cohort(
            {"delta_prev": delta_prev}, params, batches, None, ids)
        # fedavg is the collective-volume comparison baseline: drop its
        # diagnostics so the unused norm reduction is DCE'd and the
        # compiled round carries no extra all-reduce vs plain FedAvg
        if algorithm == "fedavg":
            diag = {}
        metrics = {"train_loss": losses.mean(), **diag}
        return new_params, new_state["delta_prev"], metrics

    if mesh is None:
        return round_step
    # same layout as the simulation trainer: (state, params, batches, ...)
    # -> here state==delta_prev (a tree mirroring params, so on a
    # two-axis mesh it takes the params' per-leaf specs) and metrics are
    # scalars (replicated)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis_sizes.get(model_axis, 1) > 1:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding.rules import cohort_param_specs, to_named
        if params_template is None:
            raise ValueError(
                f"mesh carries a {model_axis!r} axis of size "
                f"{axis_sizes[model_axis]}: make_fl_round_step needs "
                "params_template= for the per-leaf model specs")
        p_s = to_named(cohort_param_specs(params_template, mesh,
                                          client_axis, model_axis), mesh)
        b_s = NamedSharding(mesh, P(client_axis))
        rep = NamedSharding(mesh, P())
        return jax.jit(round_step, in_shardings=(p_s, p_s, b_s),
                       out_shardings=(p_s, p_s, rep))
    from repro.sharding.rules import cohort_round_shardings
    (st_s, p_s, b_s, _, _), (po_s, so_s, _, m_s) = cohort_round_shardings(
        mesh, client_axis)
    return jax.jit(round_step, in_shardings=(p_s, st_s, b_s),
                   out_shardings=(po_s, so_s, m_s))


def fl_round_input_specs(cfg, *, clients: int, local_steps: int,
                         local_batch: int, seq_len: int):
    """ShapeDtypeStructs for the round's batch stack (LM training)."""
    shape = (clients, local_steps, local_batch, seq_len)
    return {"tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
            "labels": jax.ShapeDtypeStruct(shape, jnp.int32)}
