"""Distributed FL round step — FedDPC as a collective-native epilogue on
the production mesh (DESIGN.md §2, cross-silo mode).

Mesh reading: the (pod x data) axes form the CLIENT axis — each (pod,
data) slice is one participating silo training a model-parallel replica
(weights replicated over client axes, Megatron-sharded over ``model``).
Partial participation = which silos show up this round; a pod boundary is
a datacenter boundary.

The whole round is ONE jit'd program:
  1. local training: vmap over the client axis of `local_steps` SGD steps
     (lax.scan over the client's microbatches)
  2. FedDPC epilogue: per-client scalars <Δ_j,Δ_prev>, ||Δ_j||², ||Δ_prev||²
     reduce over every model-sharding axis automatically under GSPMD (4
     scalar all-reduces), the projection/scaling is elementwise on the
     sharded update shards, and the client-mean is one all-reduce over the
     client axes — asymptotically the same collective volume as FedAvg
     (paper's server loop is O(4k'd) *serial*; here it is fused into the
     data-parallel reduction).

Under the single-pod mesh this trains 16 clients/round; multi-pod, 32.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import feddpc as feddpc_mod

PyTree = Any


def make_fl_round_step(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                       eta_l: float, eta_g: float, lam: float = 1.0,
                       algorithm: str = "feddpc"):
    """Returns round_step(params, delta_prev, batches) ->
    (new_params, new_delta_prev, metrics).

    batches: pytree whose leaves have leading axes (K, M, ...) — K
    participating clients (sharded over the mesh client axes), M local
    steps each. loss_fn(params, batch) -> scalar.
    """

    def local_update(params, batch_seq):
        def step(w, b):
            loss, g = jax.value_and_grad(loss_fn)(w, b)
            w = jax.tree.map(
                lambda p, gi: (p - eta_l * gi.astype(p.dtype)).astype(p.dtype),
                w, g)
            return w, loss

        w_fin, losses = jax.lax.scan(step, params, batch_seq)
        delta = jax.tree.map(
            lambda a, b_: (a.astype(jnp.float32) - b_.astype(jnp.float32))
            / eta_l, params, w_fin)
        return delta, losses.mean()

    def round_step(params, delta_prev, batches):
        deltas, losses = jax.vmap(
            lambda bs: local_update(params, bs))(batches)
        if algorithm == "feddpc":
            new_params, state, diag = feddpc_mod.server_step(
                {"delta_prev": delta_prev}, params, deltas, eta_g, lam)
        else:   # fedavg baseline (for collective-volume comparison)
            delta_t = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0), deltas)
            new_params = jax.tree.map(
                lambda w, d: (w.astype(jnp.float32) - eta_g * d
                              ).astype(w.dtype), params, delta_t)
            state = {"delta_prev": delta_t}
            diag = {}
        metrics = {"train_loss": losses.mean(), **diag}
        return new_params, state["delta_prev"], metrics

    return round_step


def fl_round_input_specs(cfg, *, clients: int, local_steps: int,
                         local_batch: int, seq_len: int):
    """ShapeDtypeStructs for the round's batch stack (LM training)."""
    shape = (clients, local_steps, local_batch, seq_len)
    return {"tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
            "labels": jax.ShapeDtypeStruct(shape, jnp.int32)}
