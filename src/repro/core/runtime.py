"""Pluggable client runtime models — the ARRIVAL axis of partial
participation (DESIGN.md §11).

The samplers (core/samplers.py) decide WHO participates each wave; a
``ClientRuntimeModel`` decides WHEN each sampled client's update comes
back, and whether it comes back at all. The buffered-async engine
(core/async_engine.py) turns those latencies into a virtual-time
arrival stream: updates computed against a wave's params snapshot land
in the server buffer out of order, and their staleness is whatever the
arrival order made it.

The regimes mirror the partial-participation literature's catalog
(arXiv:2506.02887; FedBuff's staleness model, arXiv:2106.06639):

    deterministic   every client takes the same fixed time — arrivals
                    keep wave order, staleness is identically zero at
                    concurrency 1 (the sync-equivalence anchor cell of
                    the regime matrix)
    exponential     i.i.d. exponential latencies + Bernoulli dropout —
                    the classic memoryless straggler model
    heavytail       Pareto latencies — a fat tail of stragglers whose
                    stale updates the staleness discount must tame
    markov          per-client fast/slow Markov chain — device state
                    (charging vs busy) persists across waves, so
                    slowness is CORRELATED per client; the chain is
                    runtime STATE and is checkpointed

Contract (same round-order RNG discipline as the samplers):

  * ``draw(rng, wave, clients) -> (latencies, dropped)`` consumes a
    FIXED number of draws per call for a given model class — the
    trainer calls it in wave order under its sampling lock, right after
    the sampler's draw for the same wave, so prefetched/staged waves
    replay bitwise on resume. ``DeterministicRuntime`` consumes ZERO
    draws (like ``CyclicSampler``), which is what keeps the async
    schedule equal to the sync schedule in the matrix anchor cell.
  * latencies are positive float64 virtual seconds, shape (len(clients),);
    ``dropped`` is a bool mask — dropped clients never reach the buffer
    (their compute is wasted, exactly the failure mode FedBuff models).
  * models with internal evolution expose ``state_dict``/
    ``load_state_dict``; ``config_dict`` echoes the constructor
    parameterization for the resume-compat check, mirroring
    ``ClientSampler``.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class ClientRuntimeModel:
    """Protocol + base class: subclass and implement ``draw``."""

    def draw(self, rng: np.random.RandomState, wave: int,
             clients: np.ndarray):
        """-> (latencies float64 (k,), dropped bool (k,))."""
        raise NotImplementedError

    # ---- checkpointing (stateless models need nothing) ----

    def state_dict(self) -> Dict:
        return {}

    def load_state_dict(self, state: Dict) -> None:
        pass

    def config_dict(self) -> Dict:
        return {"class": type(self).__name__}


class DeterministicRuntime(ClientRuntimeModel):
    """Every client takes exactly ``latency`` virtual seconds; nobody
    drops. Consumes ZERO RNG draws — the async engine's wave order is
    then the arrival order (the seq tiebreak in the virtual-time heap),
    so at concurrency 1 the buffered-async run is draw-for-draw AND
    arrival-for-arrival identical to the synchronous run: the
    staleness-0 / B=K anchor cell of the regime matrix."""

    def __init__(self, latency: float = 1.0):
        if not latency > 0:
            raise ValueError(f"latency must be positive, got {latency}")
        self.latency = float(latency)

    def draw(self, rng, wave, clients):
        k = len(clients)
        return (np.full(k, self.latency, np.float64),
                np.zeros(k, bool))

    def config_dict(self):
        return {**super().config_dict(), "latency": self.latency}


class ExponentialRuntime(ClientRuntimeModel):
    """I.i.d. exponential latencies (mean ``mean``) with Bernoulli
    dropout — the memoryless straggler model. Consumes exactly TWO rng
    draws per wave (one latency vector, one dropout vector; the dropout
    draw happens even at dropout=0.0 so the draw count is
    config-independent)."""

    def __init__(self, mean: float = 1.0, dropout: float = 0.0):
        if not mean > 0:
            raise ValueError(f"mean latency must be positive, got {mean}")
        if not 0.0 <= dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {dropout}")
        self.mean = float(mean)
        self.dropout = float(dropout)

    def draw(self, rng, wave, clients):
        k = len(clients)
        lat = rng.exponential(self.mean, size=k)
        dropped = rng.rand(k) < self.dropout
        return np.maximum(lat, 1e-9), dropped

    def config_dict(self):
        return {**super().config_dict(),
                "mean": self.mean, "dropout": self.dropout}


class HeavyTailRuntime(ClientRuntimeModel):
    """Pareto(shape) latencies scaled by ``scale`` — a fat straggler
    tail (smaller ``shape`` = fatter tail; shape <= 1 has infinite
    mean). Latency = scale * (1 + Pareto(shape)) >= scale, so the
    fastest client still pays the floor. Consumes exactly TWO rng draws
    per wave, like ``ExponentialRuntime``."""

    def __init__(self, shape: float = 1.5, scale: float = 1.0,
                 dropout: float = 0.0):
        if not shape > 0:
            raise ValueError(f"pareto shape must be positive, got {shape}")
        if not scale > 0:
            raise ValueError(f"scale must be positive, got {scale}")
        if not 0.0 <= dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {dropout}")
        self.shape = float(shape)
        self.scale = float(scale)
        self.dropout = float(dropout)

    def draw(self, rng, wave, clients):
        k = len(clients)
        lat = self.scale * (1.0 + rng.pareto(self.shape, size=k))
        dropped = rng.rand(k) < self.dropout
        return lat, dropped

    def config_dict(self):
        return {**super().config_dict(), "shape": self.shape,
                "scale": self.scale, "dropout": self.dropout}


class MarkovRuntime(ClientRuntimeModel):
    """Per-client two-state fast/slow Markov chain over ALL
    ``num_clients`` clients: a slow client (busy device, bad link)
    tends to STAY slow across waves, so straggling is correlated per
    client rather than i.i.d. — the regime where staleness concentrates
    on a fixed subset and uniform discounts are most stressed.

    Per wave, consumes exactly THREE rng draws: one (num_clients,)
    uniform vector evolving the whole chain (participants and
    bystanders alike, so the trajectory is independent of who was
    sampled), one latency vector, one dropout vector. The chain state
    is checkpointed via ``state_dict`` — resuming mid-run continues the
    exact trajectory."""

    def __init__(self, num_clients: int, fast: float = 1.0,
                 slow: float = 4.0, p_slow: float = 0.2,
                 p_fast: float = 0.5, dropout: float = 0.0):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        if not (0 < fast <= slow):
            raise ValueError(f"need 0 < fast <= slow, got {(fast, slow)}")
        if not (0.0 <= p_slow <= 1.0 and 0.0 < p_fast <= 1.0):
            raise ValueError((p_slow, p_fast))
        if not 0.0 <= dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {dropout}")
        self.num_clients = int(num_clients)
        self.fast = float(fast)
        self.slow = float(slow)
        self.p_slow = float(p_slow)      # fast -> slow transition prob
        self.p_fast = float(p_fast)      # slow -> fast transition prob
        self.dropout = float(dropout)
        self._slow_state: Optional[np.ndarray] = None   # (n,) bool

    def draw(self, rng, wave, clients):
        u = rng.rand(self.num_clients)
        if self._slow_state is None:
            # stationary distribution of the fast/slow chain
            pi = self.p_slow / max(self.p_slow + self.p_fast, 1e-12)
            self._slow_state = u < pi
        else:
            s = self._slow_state
            self._slow_state = np.where(s, u >= self.p_fast, u < self.p_slow)
        base = np.where(self._slow_state[np.asarray(clients, np.int64)],
                        self.slow, self.fast)
        lat = base * rng.exponential(1.0, size=len(clients))
        dropped = rng.rand(len(clients)) < self.dropout
        return np.maximum(lat, 1e-9), dropped

    def state_dict(self):
        return {} if self._slow_state is None else {
            "slow": self._slow_state.astype(np.uint8).tolist()}

    def load_state_dict(self, state):
        self._slow_state = (
            np.asarray(state["slow"], np.uint8).astype(bool)
            if state.get("slow") is not None else None)

    def config_dict(self):
        return {**super().config_dict(), "num_clients": self.num_clients,
                "fast": self.fast, "slow": self.slow,
                "p_slow": self.p_slow, "p_fast": self.p_fast,
                "dropout": self.dropout}


def make_runtime(name: str, num_clients: int, **kwargs
                 ) -> ClientRuntimeModel:
    """Build a runtime model by registry name (launch/train.py's
    ``--runtime`` flag and the bench sweep go through here)."""
    if name == "deterministic":
        return DeterministicRuntime(**kwargs)
    if name == "exponential":
        return ExponentialRuntime(**kwargs)
    if name == "heavytail":
        return HeavyTailRuntime(**kwargs)
    if name == "markov":
        return MarkovRuntime(num_clients, **kwargs)
    raise ValueError(f"unknown runtime model {name!r}; expected one of "
                     "deterministic/exponential/heavytail/markov")


def runtime_matrix(num_clients: int) -> Dict[str, ClientRuntimeModel]:
    """One representatively-configured instance of every runtime model
    — the arrival axis of the async bench sweep and the property tests.
    Exponential/heavytail carry real dropout so the drop path is
    exercised; markov uses a sticky slow state so correlation shows."""
    return {
        "deterministic": DeterministicRuntime(latency=1.0),
        "exponential": ExponentialRuntime(mean=1.0, dropout=0.1),
        "heavytail": HeavyTailRuntime(shape=1.2, scale=0.5, dropout=0.05),
        "markov": MarkovRuntime(num_clients, fast=0.5, slow=4.0,
                                p_slow=0.3, p_fast=0.4, dropout=0.05),
    }
