# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public surface (DESIGN.md §3): one import point for the composable
# FL engine — trainer + configs, samplers, data sources, and the
# algorithm registry with its hyperparameter dataclasses.
from repro.core.api import (AlgoConfig, ExecConfig, FLConfig,
                            FederatedTrainer, RoundRecord, TrainerState)
from repro.core.baselines import (ALGORITHM_NAMES, AdaptiveHyper,
                                  FedCMHyper, FedDPCHyper, FedDPCMHyper,
                                  FedExPHyper, FedGAHyper, FedProxHyper,
                                  ServerAlgo, default_hyper, get_algorithm,
                                  make_algorithm, register_algorithm)
from repro.core.samplers import (ClientSampler, CyclicSampler, MarkovSampler,
                                 UniformSampler, WeightedSampler)
# the data-source protocol lives in the staged ingest subsystem
# (DESIGN.md §10) and stays re-exported here as part of the §3 surface
from repro.ingest import (DataSource, IteratorDataSource, ListDataSource,
                          as_data_source)

__all__ = [
    "AlgoConfig", "ExecConfig", "FLConfig", "FederatedTrainer",
    "RoundRecord", "TrainerState",
    "ALGORITHM_NAMES", "AdaptiveHyper", "FedCMHyper", "FedDPCHyper",
    "FedDPCMHyper", "FedExPHyper", "FedGAHyper", "FedProxHyper",
    "ServerAlgo", "default_hyper", "get_algorithm", "make_algorithm",
    "register_algorithm",
    "DataSource", "IteratorDataSource", "ListDataSource", "as_data_source",
    "ClientSampler", "CyclicSampler", "MarkovSampler", "UniformSampler",
    "WeightedSampler",
]
