"""Baseline FL server rules the paper compares against (§3, §5.2.3):

  fedavg   plain mean of local updates (two-sided LRs)
  fedprox  fedavg server; clients add a proximal term (client variant)
  fedexp   extrapolated server LR from the POCS view (Jhunjhunwala et al.)
  fedga    fedavg server; clients initialize with a displacement along the
           previous global update (stateless reading of Dandi et al.)
  fedcm    fedavg server; clients mix a momentum-like term from the
           previous global update into every local gradient step
  fedvarp  server keeps the latest update of EVERY client and uses
           surrogate updates for absent ones (stateful, O(k d))
  feddpc   the paper's method (core/feddpc.py)
  feddpc_noscale  ablation: projection only (Fig 6)

Unified interface so the trainer can swap algorithms:

  algo.init(params, num_clients)                         -> server_state
  algo.step(state, params, deltas, client_ids, eta_g, t) -> (params', state', diag)
  algo.client_variant in {"plain","prox","cm","ga"}      local-training flavour
  algo.client_extra(state)    pytree broadcast to clients (e.g. Delta_{t-1})

deltas are client-stacked pytrees (leading axis k'), client_ids (k',) int32.

Every ``step`` runs inside the fused cohort round (core/round.py): it is
traced together with the vmapped local training into one jit'd program
whose state/params buffers are DONATED. Steps must therefore be pure
functions of traced inputs — client_ids arrives as a traced int32 array
(FedVARP's per-client table update is a gather + scatter on it), and all
branching on k'/shape must be static. ``client_extra`` is likewise traced
from server_state inside the program.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import feddpc as feddpc_mod
from repro.core import projection as proj

PyTree = Any


@dataclass(frozen=True)
class ServerAlgo:
    name: str
    init: Callable[[PyTree, int], PyTree]
    step: Callable[..., Tuple[PyTree, PyTree, Dict]]
    client_variant: str = "plain"
    # extracts what local training needs from server state (None if nothing)
    client_extra: Callable[[PyTree], Optional[PyTree]] = lambda s: None
    stateful_per_client: bool = False


def _mean_over_clients(deltas: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0),
                        deltas)


def _apply(params: PyTree, delta: PyTree, eta_g) -> PyTree:
    return jax.tree.map(
        lambda w, d: (w.astype(jnp.float32)
                      - eta_g * d.astype(jnp.float32)).astype(w.dtype),
        params, delta)


# ---------------- FedAvg ----------------

def _fedavg_init(params, num_clients):
    return {"delta_prev": proj.tree_zeros_like(params)}


def _fedavg_step(state, params, deltas, client_ids, eta_g, t, **_):
    delta_t = _mean_over_clients(deltas)
    return _apply(params, delta_t, eta_g), {"delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t)}


FEDAVG = ServerAlgo("fedavg", _fedavg_init, _fedavg_step)

# FedProx: same server as FedAvg; prox term applied in the client loop.
FEDPROX = ServerAlgo("fedprox", _fedavg_init, _fedavg_step,
                     client_variant="prox")

# FedGA: clients start from a displaced model along Delta_{t-1}.
FEDGA = ServerAlgo("fedga", _fedavg_init, _fedavg_step, client_variant="ga",
                   client_extra=lambda s: s["delta_prev"])

# FedCM: clients mix Delta_{t-1} into each local gradient.
FEDCM = ServerAlgo("fedcm", _fedavg_init, _fedavg_step, client_variant="cm",
                   client_extra=lambda s: s["delta_prev"])


# ---------------- FedExP ----------------

def _fedexp_step(state, params, deltas, client_ids, eta_g, t, eps=1e-3, **_):
    """eta_g_t = max(1, sum_j||Δ_j||² / (2 k' (||Δ̄||² + eps))) — the POCS
    extrapolation rule; then w ← w − eta_g · eta_g_t · Δ̄."""
    delta_t = _mean_over_clients(deltas)
    sq_each = jax.vmap(proj.tree_sqnorm)(deltas)               # (k',)
    kprime = sq_each.shape[0]
    sq_mean = proj.tree_sqnorm(delta_t)
    extrap = jnp.maximum(1.0, sq_each.sum() / (2 * kprime * (sq_mean + eps)))
    return _apply(params, delta_t, eta_g * extrap), {
        "delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t), "extrap": extrap}


FEDEXP = ServerAlgo("fedexp", _fedavg_init, _fedexp_step)


# ---------------- FedVARP ----------------

def _fedvarp_init(params, num_clients):
    zeros = proj.tree_zeros_like(params)
    table = jax.tree.map(
        lambda z: jnp.zeros((num_clients,) + z.shape, jnp.float32), params)
    return {"y": table, "delta_prev": zeros}


def _fedvarp_step(state, params, deltas, client_ids, eta_g, t, **_):
    """Δ_t = (1/k)Σ_i y_i + (1/k')Σ_{j∈S}(Δ_j − y_j);  y_j ← Δ_j for j∈S."""
    y = state["y"]
    k = jax.tree.leaves(y)[0].shape[0]
    y_sel = jax.tree.map(lambda tb: tb[client_ids], y)          # (k', ...)
    corr = jax.tree.map(
        lambda d, ys: jnp.mean(d.astype(jnp.float32) - ys, axis=0),
        deltas, y_sel)
    base = jax.tree.map(lambda tb: tb.mean(axis=0), y)
    delta_t = jax.tree.map(lambda b, c: b + c, base, corr)
    new_y = jax.tree.map(
        lambda tb, d: tb.at[client_ids].set(d.astype(jnp.float32)), y, deltas)
    return _apply(params, delta_t, eta_g), {
        "y": new_y, "delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t)}


FEDVARP = ServerAlgo("fedvarp", _fedvarp_init, _fedvarp_step,
                     stateful_per_client=True)


# ---------------- FedDPC (the paper) ----------------

def _make_feddpc(lam: float = 1.0, use_kernel: bool = False) -> ServerAlgo:
    def step(state, params, deltas, client_ids, eta_g, t, **_):
        return feddpc_mod.server_step(state, params, deltas, eta_g, lam,
                                      use_kernel=use_kernel)
    return ServerAlgo("feddpc", lambda p, n: feddpc_mod.init_state(p), step)


FEDDPC = _make_feddpc()


def _feddpc_noscale_step(state, params, deltas, client_ids, eta_g, t, **_):
    return feddpc_mod.server_step_projection_only(state, params, deltas, eta_g)


FEDDPC_NOSCALE = ServerAlgo(
    "feddpc_noscale", lambda p, n: feddpc_mod.init_state(p),
    _feddpc_noscale_step)


# ---------------- adaptive server optimizers (Reddi et al. [9]) ----------

def _adaptive_init(params, num_clients):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"delta_prev": proj.tree_zeros_like(params),
            "m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.float32)}


def _make_adaptive(kind: str, b1=0.9, b2=0.99, eps=1e-3) -> ServerAlgo:
    """FedAdam / FedYogi: the client-mean pseudo-gradient feeds a server-
    side adaptive optimizer (beyond-paper: the paper's two-sided-LR view
    generalized to adaptive server steps)."""

    def step(state, params, deltas, client_ids, eta_g, t_unused, **_):
        delta_t = _mean_over_clients(deltas)
        t = state["t"] + 1.0
        m = jax.tree.map(lambda mm, d: b1 * mm + (1 - b1) * d,
                         state["m"], delta_t)
        if kind == "adam":
            v = jax.tree.map(lambda vv, d: b2 * vv + (1 - b2) * d * d,
                             state["v"], delta_t)
        else:   # yogi
            v = jax.tree.map(
                lambda vv, d: vv - (1 - b2) * d * d * jnp.sign(vv - d * d),
                state["v"], delta_t)
        upd = jax.tree.map(lambda mm, vv: mm / (jnp.sqrt(vv) + eps), m, v)
        new_params = _apply(params, upd, eta_g)
        return new_params, {"delta_prev": delta_t, "m": m, "v": v, "t": t}, {
            "norm_global_update": proj.tree_norm(upd)}

    return ServerAlgo(f"fed{kind}", _adaptive_init, step)


FEDADAM = _make_adaptive("adam")
FEDYOGI = _make_adaptive("yogi")


# ---------------- FedDPC-M (beyond-paper composition) ----------------

def _make_feddpc_m(lam: float = 1.0, beta: float = 0.9) -> ServerAlgo:
    """FedDPC + server momentum on the aggregated (projected+scaled)
    update: m_t = beta m_{t-1} + Delta_t; w -= eta_g m_t. The projection
    is still against the raw previous Delta (paper semantics), momentum
    only smooths the applied step."""

    def init(params, num_clients):
        s = feddpc_mod.init_state(params)
        s["m"] = proj.tree_zeros_like(params)
        return s

    def step(state, params, deltas, client_ids, eta_g, t, **_):
        _, new_state, diag = feddpc_mod.server_step(
            {"delta_prev": state["delta_prev"]}, params, deltas, 0.0, lam)
        delta_t = new_state["delta_prev"]
        m = jax.tree.map(
            lambda mm, d: beta * mm.astype(jnp.float32)
            + d.astype(jnp.float32), state["m"], delta_t)
        new_params = _apply(params, m, eta_g)
        return new_params, {"delta_prev": delta_t, "m": m}, diag

    return ServerAlgo("feddpc_m", init, step)


FEDDPC_M = _make_feddpc_m()


# ---------------- registry ----------------

def get_algorithm(name: str, *, lam: float = 1.0,
                  use_kernel: bool = False) -> ServerAlgo:
    if name == "feddpc":
        return _make_feddpc(lam, use_kernel)
    if name == "feddpc_m":
        return _make_feddpc_m(lam)
    return {
        "fedavg": FEDAVG, "fedprox": FEDPROX, "fedexp": FEDEXP,
        "fedga": FEDGA, "fedcm": FEDCM, "fedvarp": FEDVARP,
        "feddpc_noscale": FEDDPC_NOSCALE,
        "fedadam": FEDADAM, "fedyogi": FEDYOGI,
    }[name]


ALGORITHM_NAMES = ("fedavg", "fedprox", "fedexp", "fedga", "fedcm",
                   "fedvarp", "feddpc", "feddpc_noscale", "fedadam",
                   "fedyogi", "feddpc_m")
