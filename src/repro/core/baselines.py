"""FL server rules the paper compares against (paper §3, §5.2.3):

  fedavg   plain mean of local updates (two-sided LRs)
  fedprox  fedavg server; clients add a proximal term (client variant)
  fedexp   extrapolated server LR from the POCS view (Jhunjhunwala et al.)
  fedga    fedavg server; clients initialize with a displacement along the
           previous global update (stateless reading of Dandi et al.)
  fedcm    fedavg server; clients mix a momentum-like term from the
           previous global update into every local gradient step
  fedvarp  server keeps the latest update of EVERY client and uses
           surrogate updates for absent ones (stateful, O(k d))
  feddpc   the paper's method (core/feddpc.py)
  feddpc_noscale  ablation: projection only (Fig 6)

Unified interface so the trainer can swap algorithms:

  algo.init(params, num_clients)                         -> server_state
  algo.step(state, params, deltas, client_ids, eta_g, t,
            client_mask=None) -> (params', state', diag)
  algo.client_variant in {"plain","prox","cm","ga"}      local-training flavour
  algo.client_extra(state)    pytree broadcast to clients (e.g. Delta_{t-1})
  algo.client_hparams         kwargs the local-update builder needs
                              (mu / cm_alpha / ga_beta for its variant)

deltas are client-stacked pytrees (leading axis k'), client_ids (k',) int32.
``client_mask`` (k',) bool marks REAL cohort rows when the sharded path
pads the cohort to a multiple of the client axis (DESIGN.md §2): masked
rows carry dummy clients whose deltas must not perturb the client mean,
FedExP's extrapolation count, or FedVARP's table — dummy client_ids are
out of range and are dropped by the scatter.  ``model_sharded`` (bool
kwarg, default False) declares that delta/param leaves are partitioned
over a mesh model axis (the two-axis round, §2); rules that only use
dim-preserving tree reductions can ignore it (GSPMD reduces the partials
for free), rules with layout-sensitive fast paths (FedDPC's Pallas
epilogue) must fall back.  Steps accept unknown kwargs (**_) so new
execution hints never break an algorithm that does not care.

Algorithms register through ``register_algorithm(name, HyperCls)``: each
carries a frozen hyperparameter dataclass (``FedDPCHyper(lam=...)``,
``FedProxHyper(mu=...)``, ...) and ``make_algorithm(name, hyper)`` builds
the ``ServerAlgo``.  ``get_algorithm(name, lam=..., use_kernel=...)`` is
the deprecated flat-kwargs shim kept for old callers.

Every ``step`` runs inside the fused cohort round (core/round.py): it is
traced together with the vmapped local training into one jit'd program
whose state/params buffers are DONATED. Steps must therefore be pure
functions of traced inputs — client_ids arrives as a traced int32 array
(FedVARP's per-client table update is a gather + scatter on it), and all
branching on k'/shape must be static. ``client_extra`` is likewise traced
from server_state inside the program.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import feddpc as feddpc_mod
from repro.core import projection as proj

PyTree = Any


@dataclass(frozen=True)
class ServerAlgo:
    name: str
    init: Callable[[PyTree, int], PyTree]
    step: Callable[..., Tuple[PyTree, PyTree, Dict]]
    client_variant: str = "plain"
    # extracts what local training needs from server state (None if nothing)
    client_extra: Callable[[PyTree], Optional[PyTree]] = lambda s: None
    stateful_per_client: bool = False
    # per-variant local-training knobs (mu / cm_alpha / ga_beta) sourced
    # from the algorithm's hyper dataclass; the round builder reads them
    client_hparams: Dict[str, float] = field(default_factory=dict)
    hyper: Any = None
    # the rule folds buffered-async staleness weights into its OWN
    # reduction scalars (step accepts staleness_weights=); False makes
    # the async engine pre-scale the buffered deltas instead (FedBuff
    # mean semantics) — see core/async_engine.py, DESIGN.md §11
    staleness_aware: bool = False


# masked client mean (padded dummy rows excluded): one implementation,
# shared with core/feddpc.py through core/projection.py
_mean_over_clients = proj.masked_client_mean


def _apply(params: PyTree, delta: PyTree, eta_g) -> PyTree:
    return jax.tree.map(
        lambda w, d: (w.astype(jnp.float32)
                      - eta_g * d.astype(jnp.float32)).astype(w.dtype),
        params, delta)


# ---------------- registry ----------------

@dataclass(frozen=True)
class AlgorithmSpec:
    name: str
    hyper_cls: Type
    build: Callable[[Any], ServerAlgo]


_REGISTRY: Dict[str, AlgorithmSpec] = {}


def register_algorithm(name: str, hyper_cls: Type = None):
    """Decorator: ``@register_algorithm("myalgo", MyHyper)`` over a
    ``build(hyper) -> ServerAlgo`` factory adds it to the registry (and
    to ``FLConfig``/CLI name resolution for free)."""
    def deco(build):
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = AlgorithmSpec(name, hyper_cls or NoHyper, build)
        return build
    return deco


def make_algorithm(name: str, hyper=None) -> ServerAlgo:
    """Build a ``ServerAlgo`` from the registry. ``hyper`` is the
    algorithm's hyper dataclass instance, a kwargs dict for it, or None
    for registry defaults."""
    try:
        spec = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown algorithm {name!r}; registered: "
                         f"{', '.join(sorted(_REGISTRY))}") from None
    if hyper is None:
        hyper = spec.hyper_cls()
    elif isinstance(hyper, dict):
        hyper = spec.hyper_cls(**hyper)
    elif not isinstance(hyper, spec.hyper_cls):
        raise TypeError(f"{name} expects {spec.hyper_cls.__name__} hyper-"
                        f"parameters, got {type(hyper).__name__}")
    # stamp the resolved hyper onto the (frozen) ServerAlgo so callers —
    # and the checkpoint echo — can recover the full parameterization
    return dataclasses.replace(spec.build(hyper), hyper=hyper)


def algorithm_hyper_cls(name: str) -> Type:
    return _REGISTRY[name].hyper_cls


def default_hyper(name: str, *, lam: float = 1.0, use_kernel: bool = False,
                  mu: float = 0.01, cm_alpha: float = 0.1,
                  ga_beta: float = 0.1):
    """Hyper instance built from the FLAT legacy knobs; None for
    algorithms none of them reach (registry defaults apply). The ONE
    mapping behind the FLConfig shim, the legacy ``get_algorithm``
    kwargs, and the lam-bearing CLI/benchmark entry points."""
    return {
        "feddpc": lambda: FedDPCHyper(lam=lam, use_kernel=use_kernel),
        "feddpc_m": lambda: FedDPCMHyper(lam=lam),
        "fedprox": lambda: FedProxHyper(mu=mu),
        "fedcm": lambda: FedCMHyper(alpha=cm_alpha),
        "fedga": lambda: FedGAHyper(beta=ga_beta),
    }.get(name, lambda: None)()


def client_kwargs(algo: ServerAlgo) -> Dict[str, float]:
    """Local-update kwargs the algorithm pins (its variant-specific
    hypers); knobs it leaves unset keep the builder defaults in
    core/client.py — the single source of those defaults."""
    return dict(algo.client_hparams)


# ---------------- hyperparameter dataclasses ----------------

@dataclass(frozen=True)
class NoHyper:
    pass


@dataclass(frozen=True)
class FedProxHyper:
    mu: float = 0.01                 # proximal strength


@dataclass(frozen=True)
class FedCMHyper:
    alpha: float = 0.1               # client-momentum mixing


@dataclass(frozen=True)
class FedGAHyper:
    beta: float = 0.1                # displacement along Delta_{t-1}


@dataclass(frozen=True)
class FedExPHyper:
    eps: float = 1e-3                # extrapolation denominator guard


@dataclass(frozen=True)
class FedDPCHyper:
    lam: float = 1.0                 # adaptive-scaling hyper-param
    use_kernel: bool = False         # route the epilogue through Pallas


@dataclass(frozen=True)
class FedDPCMHyper:
    lam: float = 1.0
    beta: float = 0.9                # server momentum


@dataclass(frozen=True)
class AdaptiveHyper:                 # FedAdam / FedYogi (Reddi et al. [9])
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3


# ---------------- FedAvg family ----------------

def _fedavg_init(params, num_clients):
    return {"delta_prev": proj.tree_zeros_like(params)}


def _fedavg_step(state, params, deltas, client_ids, eta_g, t,
                 client_mask=None, edges=None, **_):
    delta_t = _mean_over_clients(deltas, client_mask, edges=edges)
    return _apply(params, delta_t, eta_g), {"delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t)}


@register_algorithm("fedavg")
def _build_fedavg(h):
    return ServerAlgo("fedavg", _fedavg_init, _fedavg_step)


@register_algorithm("fedprox", FedProxHyper)
def _build_fedprox(h):
    # same server as FedAvg; prox term applied in the client loop
    return ServerAlgo("fedprox", _fedavg_init, _fedavg_step,
                      client_variant="prox", client_hparams={"mu": h.mu})


# ---------------- FedExP ----------------

def _fedexp_step(state, params, deltas, client_ids, eta_g, t, eps=1e-3,
                 client_mask=None, edges=None, **_):
    """eta_g_t = max(1, sum_j||Δ_j||² / (2 k' (||Δ̄||² + eps))) — the POCS
    extrapolation rule; then w ← w − eta_g · eta_g_t · Δ̄."""
    delta_t = _mean_over_clients(deltas, client_mask, edges=edges)
    sq_each = jax.vmap(proj.tree_sqnorm)(deltas)               # (k',)
    if client_mask is None:
        kprime = sq_each.shape[0]
    else:
        mf = client_mask.astype(jnp.float32)
        sq_each = sq_each * mf
        kprime = jnp.maximum(mf.sum(), 1.0)
    sq_mean = proj.tree_sqnorm(delta_t)
    if edges is not None and int(edges) > 1:
        # two-level: per-edge partial sums of ||Δ_j||², then the sum of
        # the E edge summaries — the scalar is dim-preserving, so it
        # composes across aggregation levels (DESIGN.md §15)
        sq_total = jnp.sum(jnp.sum(
            sq_each.reshape(int(edges), -1), axis=1))
    else:
        sq_total = sq_each.sum()
    extrap = jnp.maximum(1.0, sq_total / (2 * kprime * (sq_mean + eps)))
    return _apply(params, delta_t, eta_g * extrap), {
        "delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t), "extrap": extrap}


@register_algorithm("fedexp", FedExPHyper)
def _build_fedexp(h):
    return ServerAlgo("fedexp", _fedavg_init,
                      functools.partial(_fedexp_step, eps=h.eps))


# ---------------- FedGA / FedCM ----------------

@register_algorithm("fedga", FedGAHyper)
def _build_fedga(h):
    # clients start from a displaced model along Delta_{t-1}
    return ServerAlgo("fedga", _fedavg_init, _fedavg_step,
                      client_variant="ga",
                      client_extra=lambda s: s["delta_prev"],
                      client_hparams={"ga_beta": h.beta})


@register_algorithm("fedcm", FedCMHyper)
def _build_fedcm(h):
    # clients mix Delta_{t-1} into each local gradient
    return ServerAlgo("fedcm", _fedavg_init, _fedavg_step,
                      client_variant="cm",
                      client_extra=lambda s: s["delta_prev"],
                      client_hparams={"cm_alpha": h.alpha})


# ---------------- FedVARP ----------------

def _fedvarp_init(params, num_clients):
    zeros = proj.tree_zeros_like(params)
    table = jax.tree.map(
        lambda z: jnp.zeros((num_clients,) + z.shape, jnp.float32), params)
    return {"y": table, "delta_prev": zeros}


def _fedvarp_step(state, params, deltas, client_ids, eta_g, t,
                  client_mask=None, edges=None, **_):
    """Δ_t = (1/k)Σ_i y_i + (1/k')Σ_{j∈S}(Δ_j − y_j);  y_j ← Δ_j for j∈S.

    Padded dummy rows (client_mask False) carry out-of-range ids: the
    gather fills zeros, the correction mean skips them, and the scatter
    DROPS them so no real client's table row is clobbered."""
    y = state["y"]
    if client_mask is None:
        y_sel = jax.tree.map(lambda tb: tb[client_ids], y)      # (k', ...)
        new_y = jax.tree.map(
            lambda tb, d: tb.at[client_ids].set(d.astype(jnp.float32)),
            y, deltas)
    else:
        y_sel = jax.tree.map(
            lambda tb: tb.at[client_ids].get(mode="fill", fill_value=0.0), y)
        new_y = jax.tree.map(
            lambda tb, d: tb.at[client_ids].set(d.astype(jnp.float32),
                                                mode="drop"), y, deltas)
    # the correction mean goes two-level under edges=; the y-table
    # scatter is edge-resident state that rides along unchanged, and
    # base = y.mean(axis=0) is server-local state
    corr = _mean_over_clients(
        jax.tree.map(lambda d, ys: d.astype(jnp.float32) - ys,
                     deltas, y_sel), client_mask, edges=edges)
    base = jax.tree.map(lambda tb: tb.mean(axis=0), y)
    delta_t = jax.tree.map(lambda b, c: b + c, base, corr)
    return _apply(params, delta_t, eta_g), {
        "y": new_y, "delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t)}


@register_algorithm("fedvarp")
def _build_fedvarp(h):
    return ServerAlgo("fedvarp", _fedvarp_init, _fedvarp_step,
                      stateful_per_client=True)


# ---------------- FedDPC (the paper) ----------------

@register_algorithm("feddpc", FedDPCHyper)
def _build_feddpc(h):
    def step(state, params, deltas, client_ids, eta_g, t,
             client_mask=None, model_sharded=False,
             staleness_weights=None, encoded=None, edges=None, **_):
        return feddpc_mod.server_step(state, params, deltas, eta_g, h.lam,
                                      use_kernel=h.use_kernel,
                                      client_mask=client_mask,
                                      model_sharded=model_sharded,
                                      staleness_weights=staleness_weights,
                                      encoded=encoded, edges=edges)
    return ServerAlgo("feddpc", lambda p, n: feddpc_mod.init_state(p), step,
                      staleness_aware=True)


def _feddpc_noscale_step(state, params, deltas, client_ids, eta_g, t,
                         client_mask=None, edges=None, **_):
    return feddpc_mod.server_step_projection_only(
        state, params, deltas, eta_g, client_mask=client_mask, edges=edges)


@register_algorithm("feddpc_noscale")
def _build_feddpc_noscale(h):
    return ServerAlgo("feddpc_noscale", lambda p, n: feddpc_mod.init_state(p),
                      _feddpc_noscale_step)


# ---------------- adaptive server optimizers (Reddi et al. [9]) ----------

def _adaptive_init(params, num_clients):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"delta_prev": proj.tree_zeros_like(params),
            "m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.float32)}


def _make_adaptive(kind: str, h: AdaptiveHyper) -> ServerAlgo:
    """FedAdam / FedYogi: the client-mean pseudo-gradient feeds a server-
    side adaptive optimizer (beyond-paper: the paper's two-sided-LR view
    generalized to adaptive server steps)."""
    b1, b2, eps = h.b1, h.b2, h.eps

    def step(state, params, deltas, client_ids, eta_g, t_unused,
             client_mask=None, edges=None, **_):
        delta_t = _mean_over_clients(deltas, client_mask, edges=edges)
        t = state["t"] + 1.0
        m = jax.tree.map(lambda mm, d: b1 * mm + (1 - b1) * d,
                         state["m"], delta_t)
        if kind == "adam":
            v = jax.tree.map(lambda vv, d: b2 * vv + (1 - b2) * d * d,
                             state["v"], delta_t)
        else:   # yogi
            v = jax.tree.map(
                lambda vv, d: vv - (1 - b2) * d * d * jnp.sign(vv - d * d),
                state["v"], delta_t)
        upd = jax.tree.map(lambda mm, vv: mm / (jnp.sqrt(vv) + eps), m, v)
        new_params = _apply(params, upd, eta_g)
        return new_params, {"delta_prev": delta_t, "m": m, "v": v, "t": t}, {
            "norm_global_update": proj.tree_norm(upd)}

    return ServerAlgo(f"fed{kind}", _adaptive_init, step)


@register_algorithm("fedadam", AdaptiveHyper)
def _build_fedadam(h):
    return _make_adaptive("adam", h)


@register_algorithm("fedyogi", AdaptiveHyper)
def _build_fedyogi(h):
    return _make_adaptive("yogi", h)


# ---------------- FedDPC-M (beyond-paper composition) ----------------

@register_algorithm("feddpc_m", FedDPCMHyper)
def _build_feddpc_m(h):
    """FedDPC + server momentum on the aggregated (projected+scaled)
    update: m_t = beta m_{t-1} + Delta_t; w -= eta_g m_t. The projection
    is still against the raw previous Delta (paper semantics), momentum
    only smooths the applied step."""
    lam, beta = h.lam, h.beta

    def init(params, num_clients):
        s = feddpc_mod.init_state(params)
        s["m"] = proj.tree_zeros_like(params)
        return s

    def step(state, params, deltas, client_ids, eta_g, t,
             client_mask=None, model_sharded=False,
             staleness_weights=None, edges=None, **_):
        _, new_state, diag = feddpc_mod.server_step(
            {"delta_prev": state["delta_prev"]}, params, deltas, 0.0, lam,
            client_mask=client_mask, model_sharded=model_sharded,
            staleness_weights=staleness_weights, edges=edges)
        delta_t = new_state["delta_prev"]
        m = jax.tree.map(
            lambda mm, d: beta * mm.astype(jnp.float32)
            + d.astype(jnp.float32), state["m"], delta_t)
        new_params = _apply(params, m, eta_g)
        return new_params, {"delta_prev": delta_t, "m": m}, diag

    return ServerAlgo("feddpc_m", init, step, staleness_aware=True)


# ---------------- legacy flat-kwargs shim ----------------

# module-level prebuilt instances, kept for old importers
FEDAVG = make_algorithm("fedavg")
FEDPROX = make_algorithm("fedprox")
FEDEXP = make_algorithm("fedexp")
FEDGA = make_algorithm("fedga")
FEDCM = make_algorithm("fedcm")
FEDVARP = make_algorithm("fedvarp")
FEDDPC = make_algorithm("feddpc")
FEDDPC_NOSCALE = make_algorithm("feddpc_noscale")
FEDADAM = make_algorithm("fedadam")
FEDYOGI = make_algorithm("fedyogi")
FEDDPC_M = make_algorithm("feddpc_m")


def get_algorithm(name: str, *, lam: float = 1.0,
                  use_kernel: bool = False) -> ServerAlgo:
    """DEPRECATED closure factory: the flat (lam, use_kernel) kwargs only
    parameterize the feddpc family.  Use ``make_algorithm(name, hyper)``
    with the algorithm's hyper dataclass instead."""
    warnings.warn(
        "get_algorithm(name, lam=..., use_kernel=...) is deprecated; use "
        "make_algorithm(name, hyper) with the per-algorithm hyper "
        "dataclass (e.g. FedDPCHyper)", DeprecationWarning, stacklevel=2)
    return make_algorithm(name, default_hyper(name, lam=lam,
                                              use_kernel=use_kernel))


ALGORITHM_NAMES = tuple(_REGISTRY)
