"""Deterministic fault injection for chaos-testing the round engine
(DESIGN.md §12).

A ``FaultPlan`` is a SEEDED, STATELESS description of every fault a run
will experience: each query is a pure function of (plan seed, injector
salt, round index), so the same plan replays bit-identically under
save/resume, across prefetch depths, and across the sync/async engines —
the same derivation discipline as the samplers' round-order RNG contract
(core/runtime.py), but with NO sequential stream to desynchronize: a
restarted producer or a re-run round re-derives the identical faults.

Injector classes (the registry, ``FAULT_KINDS``):

  nan_delta       client delta filled with NaN after local training
  explode_delta   client delta multiplied by ``magnitude`` (norm blow-up)
  client_hang     client latency boosted past any round deadline
  ingest_crash    staging producer raises before sampling round t
  ckpt_corrupt    checkpoint step written corrupted (truncate / bitflip /
                  missing digest sidecar) — consumed by tests/benches via
                  ``corrupt_checkpoint``
  edge_drop       hierarchical round shape (DESIGN.md §15): an EDGE
                  aggregator (one host's cohort slice) drops mid-round —
                  its summary never reaches the server, which folds the
                  surviving E-1 summaries through the existing
                  client_mask path (the edge's rows mask out exactly
                  like deadline-dropped clients)

The first two surface as ``delta_codes`` consumed INSIDE the jit'd round
(core/round.py folds them in as a (K,) int32 input); hangs surface as a
``latency_boost`` added to the runtime model's draw at sampling time;
ingest crashes raise from the staging producer (the supervised prefetcher
retries the round — core/ingest/prefetch.py); checkpoint corruption is
applied by the test/bench harness between save and resume.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Type

import numpy as np

# delta fault codes, consumed by core/round.py's injection input
CODE_OK = 0
CODE_NAN = 1
CODE_EXPLODE = 2

# latency added to a hung client's runtime draw: past any finite deadline
HANG_LATENCY = 1e9


# ---------------- injector registry ----------------

FAULT_KINDS: Dict[str, Type["FaultInjector"]] = {}


def register_fault(cls: Type["FaultInjector"]) -> Type["FaultInjector"]:
    if cls.kind in FAULT_KINDS:
        raise ValueError(f"fault kind {cls.kind!r} already registered")
    FAULT_KINDS[cls.kind] = cls
    return cls


@dataclass(frozen=True)
class FaultInjector:
    """One fault class: fires on ``rounds`` (empty = seeded by ``rate``
    per round), targeting ``clients`` (empty = seeded per sampled
    client). Frozen + pure: all randomness is re-derived per query."""
    kind: str = ""
    rate: float = 0.0                    # per-client (or per-round) P(fire)
    rounds: Tuple[int, ...] = ()         # explicit rounds; () = all rounds
    clients: Tuple[int, ...] = ()        # explicit client ids; () = seeded
    magnitude: float = 1e12              # explode multiplier / corrupt arg

    def _round_active(self, t: int) -> bool:
        return not self.rounds or t in self.rounds

    def _rng(self, seed: int, t: int) -> np.random.Generator:
        # per-(plan, kind, round) stream: stateless, order-independent
        salt = int(np.frombuffer(self.kind.encode().ljust(8, b"\0")[:8],
                                 np.uint64)[0] & 0x7FFFFFFF)
        return np.random.default_rng((int(seed), salt, int(t)))

    def client_hits(self, seed: int, t: int,
                    sampled: np.ndarray) -> np.ndarray:
        """(k,) bool mask over the round's sampled client ids."""
        sampled = np.asarray(sampled)
        if not self._round_active(t):
            return np.zeros(sampled.shape, bool)
        if self.clients:
            return np.isin(sampled, np.asarray(self.clients))
        if self.rate <= 0.0:
            return np.zeros(sampled.shape, bool)
        # seeded per CLIENT ID (not per row) so the hit set is invariant
        # to sampling order and identical across sync/async regimes
        rng = self._rng(seed, t)
        u = rng.random(int(np.max(sampled, initial=0)) + 1)
        return u[sampled] < self.rate

    def round_fires(self, seed: int, t: int) -> bool:
        if not self._round_active(t):
            return False
        if self.rounds:                  # explicit rounds always fire
            return True
        return self.rate > 0.0 and self._rng(seed, t).random() < self.rate


@register_fault
@dataclass(frozen=True)
class NaNDelta(FaultInjector):
    kind: str = "nan_delta"


@register_fault
@dataclass(frozen=True)
class ExplodeDelta(FaultInjector):
    kind: str = "explode_delta"


@register_fault
@dataclass(frozen=True)
class ClientHang(FaultInjector):
    kind: str = "client_hang"


@register_fault
@dataclass(frozen=True)
class IngestCrash(FaultInjector):
    kind: str = "ingest_crash"


@register_fault
@dataclass(frozen=True)
class CkptCorrupt(FaultInjector):
    kind: str = "ckpt_corrupt"
    mode: str = "truncate"               # truncate | bitflip | drop_digest


@register_fault
@dataclass(frozen=True)
class EdgeDrop(FaultInjector):
    """Process-loss / mesh-partition injector: ``clients`` doubles as
    explicit EDGE indices (0..E-1); ``rate`` seeds per edge per round.
    The queried id space is the edge index, so the hit set is invariant
    to which clients each edge happens to hold."""
    kind: str = "edge_drop"


# ---------------- the plan ----------------

@dataclass(frozen=True)
class FaultPlan:
    """Seeded bundle of injectors with the query surface the engine
    consumes. Stateless: every method is a pure function of
    (seed, round) so replay under save/resume is automatic."""
    seed: int = 0
    injectors: Tuple[FaultInjector, ...] = ()
    explode_magnitude: float = 1e12

    @classmethod
    def seeded(cls, seed: int, *, nan_rate: float = 0.0,
               explode_rate: float = 0.0, hang_rate: float = 0.0,
               ingest_crash_rate: float = 0.0,
               nan_rounds: Sequence[int] = (), nan_clients: Sequence[int] = (),
               explode_rounds: Sequence[int] = (),
               explode_clients: Sequence[int] = (),
               hang_rounds: Sequence[int] = (),
               hang_clients: Sequence[int] = (),
               ingest_crash_rounds: Sequence[int] = (),
               edge_drop_rate: float = 0.0,
               edge_drop_rounds: Sequence[int] = (),
               edge_drop_edges: Sequence[int] = (),
               explode_magnitude: float = 1e12) -> "FaultPlan":
        inj = []
        if nan_rate or nan_rounds or nan_clients:
            inj.append(NaNDelta(rate=nan_rate, rounds=tuple(nan_rounds),
                                clients=tuple(nan_clients)))
        if explode_rate or explode_rounds or explode_clients:
            inj.append(ExplodeDelta(rate=explode_rate,
                                    rounds=tuple(explode_rounds),
                                    clients=tuple(explode_clients),
                                    magnitude=explode_magnitude))
        if hang_rate or hang_rounds or hang_clients:
            inj.append(ClientHang(rate=hang_rate, rounds=tuple(hang_rounds),
                                  clients=tuple(hang_clients)))
        if ingest_crash_rate or ingest_crash_rounds:
            inj.append(IngestCrash(rate=ingest_crash_rate,
                                   rounds=tuple(ingest_crash_rounds)))
        if edge_drop_rate or edge_drop_rounds or edge_drop_edges:
            inj.append(EdgeDrop(rate=edge_drop_rate,
                                rounds=tuple(edge_drop_rounds),
                                clients=tuple(edge_drop_edges)))
        return cls(seed=seed, injectors=tuple(inj),
                   explode_magnitude=explode_magnitude)

    def _of(self, kind: str):
        return [i for i in self.injectors if i.kind == kind]

    @property
    def active(self) -> bool:
        return bool(self.injectors)

    @property
    def injects_deltas(self) -> bool:
        return bool(self._of("nan_delta") or self._of("explode_delta"))

    def delta_codes(self, t: int, sampled: np.ndarray) -> np.ndarray:
        """(k,) int32 codes for the round's sampled clients — consumed by
        the jit'd round's injection input. NaN wins over explode when an
        id is targeted by both."""
        sampled = np.asarray(sampled)
        codes = np.zeros(sampled.shape, np.int32)
        for inj in self._of("explode_delta"):
            codes[inj.client_hits(self.seed, t, sampled)] = CODE_EXPLODE
        for inj in self._of("nan_delta"):
            codes[inj.client_hits(self.seed, t, sampled)] = CODE_NAN
        return codes

    def delta_targets(self, t: int, sampled: np.ndarray) -> np.ndarray:
        """(k,) bool: the plan's quarantine target set for round t — what
        a correct guard must flag (acceptance oracle for tests/CI)."""
        return self.delta_codes(t, sampled) != CODE_OK

    def latency_boost(self, t: int, sampled: np.ndarray) -> np.ndarray:
        """(k,) f64 added to the runtime model's latency draw."""
        sampled = np.asarray(sampled)
        boost = np.zeros(sampled.shape, np.float64)
        for inj in self._of("client_hang"):
            boost[inj.client_hits(self.seed, t, sampled)] = HANG_LATENCY
        return boost

    @property
    def injects_edges(self) -> bool:
        return bool(self._of("edge_drop"))

    def edge_drops(self, t: int, num_edges: int) -> np.ndarray:
        """(E,) bool — edges whose summary never reaches the server this
        round. Pure in (seed, round), like every other query: the same
        plan replays the same partitions under save/resume and across
        prefetch depths. The engine folds a dropped edge's rows out of
        ``client_mask`` (DESIGN.md §15), so the server aggregates the
        surviving E-1 summaries with zero rule changes."""
        drops = np.zeros(int(num_edges), bool)
        for inj in self._of("edge_drop"):
            drops |= inj.client_hits(self.seed, t, np.arange(num_edges))
        return drops

    def ingest_crash(self, t: int, attempt: int = 0) -> bool:
        """Crash the staging producer for round t?  Only the FIRST
        attempt crashes: the supervised retry re-derives this with
        attempt=1+ and proceeds — bounded recovery by construction."""
        if attempt > 0:
            return False
        return any(i.round_fires(self.seed, t)
                   for i in self._of("ingest_crash"))

    def ckpt_corruption(self, step: int) -> Optional[str]:
        """Corruption mode ('truncate'|'bitflip'|'drop_digest') for the
        checkpoint written at ``step``, or None. Applied by the
        test/bench harness via ``corrupt_checkpoint``."""
        for inj in self._of("ckpt_corrupt"):
            if inj.round_fires(self.seed, step):
                return getattr(inj, "mode", "truncate")
        return None

    # ---- checkpoint echo ----

    def config_dict(self) -> dict:
        return {"seed": self.seed,
                "explode_magnitude": self.explode_magnitude,
                "injectors": [dataclasses.asdict(i) for i in self.injectors]}

    @classmethod
    def from_config(cls, cfg: dict) -> "FaultPlan":
        inj = []
        for d in cfg.get("injectors", []):
            d = dict(d)
            kind = d.pop("kind")
            for k in ("rounds", "clients"):
                d[k] = tuple(d.get(k, ()))
            inj.append(FAULT_KINDS[kind](kind=kind, **d))
        return cls(seed=cfg["seed"], injectors=tuple(inj),
                   explode_magnitude=cfg.get("explode_magnitude", 1e12))


def corrupt_checkpoint(ckpt_dir: str, step: int, mode: str) -> str:
    """Damage the checkpoint at ``step`` in place (test/bench harness for
    the ckpt_corrupt injector): 'truncate' chops state.npz mid-file,
    'bitflip' flips one byte in it, 'drop_digest' deletes the manifest
    sidecar that carries the content digests. Returns the damaged path."""
    import os
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    state = os.path.join(d, "state.npz")
    if mode == "truncate":
        n = os.path.getsize(state)
        with open(state, "r+b") as fh:
            fh.truncate(max(1, n // 2))
        return state
    if mode == "bitflip":
        with open(state, "r+b") as fh:
            fh.seek(os.path.getsize(state) // 2)
            b = fh.read(1)
            fh.seek(-1, 1)
            fh.write(bytes([b[0] ^ 0xFF]))
        return state
    if mode == "drop_digest":
        manifest = os.path.join(d, "manifest.json")
        os.remove(manifest)
        return manifest
    raise ValueError(f"unknown corruption mode {mode!r}")
