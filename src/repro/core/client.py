"""Client-side local training (paper Algorithm 1, lines 4-13).

``make_local_update`` builds ONE jit-compiled function reused for every
client and round: it scans over a fixed-shape stack of minibatches with a
validity mask (clients with fewer samples than the stack pad with masked
batches), runs the local optimizer, and returns the pseudo-gradient

    Delta_j = (w_{t-1} - w_j) / eta_l                    (line 12)

``make_cohort_local_update`` is the same program vmapped over a leading
client axis: batches (K, M, ...), masks (K, M) -> deltas stacked (K, ...)
plus per-client mean losses (K,). The global params and the server-side
``extra`` state (Delta_prev for the cm/ga variants) are broadcast to every
client; the mask axis is per-client, so ragged cohorts (clients with
different minibatch counts) are handled by padding to the cohort max.

Client variants (selected by the server algorithm):
  plain  SGD/momentum/AdamW on the local loss
  prox   FedProx: + mu/2 ||w - w_global||^2 added to every local gradient
  cm     FedCM:   g <- alpha*g + (1-alpha)*Delta_prev  (client momentum)
  ga     FedGA:   local model initialized at w - beta*eta_l*Delta_prev

The host-ingest helpers that used to live here (``stack_batches`` /
``stack_cohort`` / ``stack_cohort_into`` / ``CohortPrefetcher``) live in
the staged ingest subsystem — ``repro.ingest`` (DESIGN.md §10); the
one-release deprecation aliases are gone.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, get_optimizer

PyTree = Any


def _build_local_update(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                        eta_l: float, variant: str, optimizer: str,
                        mu: float, cm_alpha: float, ga_beta: float):
    """Raw (un-jitted) per-client local update; shared by the serial and
    the cohort-vectorized paths."""
    opt: Optimizer = get_optimizer(optimizer, eta_l)

    def local_update(global_params, batches, mask, extra):
        w0 = global_params
        if variant == "ga" and extra is not None:
            # displacement init along the previous global direction
            w0 = jax.tree.map(
                lambda w, d: (w.astype(jnp.float32)
                              - ga_beta * eta_l * d.astype(jnp.float32)
                              ).astype(w.dtype), global_params, extra)

        def step(carry, xs):
            params, opt_state, i, loss_sum, nvalid = carry
            if mask is None:            # fixed-shape stream: no select pass
                batch, valid = xs, None
            else:
                batch, valid = xs
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if variant == "prox":
                grads = jax.tree.map(
                    lambda g, p, p0: g + mu * (p.astype(jnp.float32)
                                               - p0.astype(jnp.float32)),
                    grads, params, global_params)
            if variant == "cm" and extra is not None:
                grads = jax.tree.map(
                    lambda g, d: cm_alpha * g
                    + (1.0 - cm_alpha) * d.astype(g.dtype), grads, extra)
            updates, new_opt_state = opt.update(grads, opt_state, params, i)
            new_params = jax.tree.map(lambda p, u: (p - u).astype(p.dtype),
                                      params, updates)
            if valid is None:
                params, opt_state = new_params, new_opt_state
                loss_sum = loss_sum + loss
                nvalid = nvalid + 1.0
            else:
                # masked batches are no-ops
                keep = lambda new, old: jax.tree.map(
                    lambda a, b: jnp.where(valid, a, b), new, old)
                params = keep(new_params, params)
                opt_state = keep(new_opt_state, opt_state)
                loss_sum = loss_sum + jnp.where(valid, loss, 0.0)
                nvalid = nvalid + valid.astype(jnp.float32)
            return (params, opt_state, i + 1, loss_sum, nvalid), None

        m = jax.tree.leaves(batches)[0].shape[0]
        carry0 = (w0, opt.init(w0), jnp.zeros((), jnp.int32),
                  jnp.zeros(()), jnp.zeros(()))
        (w, _, _, loss_sum, nvalid), _ = jax.lax.scan(
            step, carry0, batches if mask is None else (batches, mask),
            length=m)
        delta = jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
            / eta_l, global_params, w)
        return delta, loss_sum / jnp.maximum(nvalid, 1.0)

    return local_update


def make_local_update(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                      eta_l: float,
                      variant: str = "plain",
                      optimizer: str = "sgd",
                      mu: float = 0.01,
                      cm_alpha: float = 0.1,
                      ga_beta: float = 0.1,
                      jit: bool = True):
    """Returns fn(global_params, batches, mask, extra) ->
    (delta, mean_loss)  where batches is a pytree with leading axis M
    (minibatch stack), mask (M,) bool, extra = Delta_prev or None.
    mask=None means every batch is valid AND skips the masked-select pass
    over the parameters entirely (the mesh path's fixed-shape streams).
    """
    fn = _build_local_update(loss_fn, eta_l, variant, optimizer,
                             mu, cm_alpha, ga_beta)
    return jax.jit(fn) if jit else fn


def make_cohort_local_update(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                             eta_l: float,
                             variant: str = "plain",
                             optimizer: str = "sgd",
                             mu: float = 0.01,
                             cm_alpha: float = 0.1,
                             ga_beta: float = 0.1,
                             jit: bool = False):
    """Cohort-vectorized local training: fn(global_params, batches, masks,
    extra) -> (deltas, losses) with batches (K, M, ...), masks (K, M) or
    None (all valid, select-free), deltas client-stacked (K, ...), losses
    (K,). params/extra broadcast."""
    fn = _build_local_update(loss_fn, eta_l, variant, optimizer,
                             mu, cm_alpha, ga_beta)
    cohort = jax.vmap(fn, in_axes=(None, 0, 0, None))
    return jax.jit(cohort) if jit else cohort
