"""Client-side local training (paper Algorithm 1, lines 4-13).

``make_local_update`` builds ONE jit-compiled function reused for every
client and round: it scans over a fixed-shape stack of minibatches with a
validity mask (clients with fewer samples than the stack pad with masked
batches), runs the local optimizer, and returns the pseudo-gradient

    Delta_j = (w_{t-1} - w_j) / eta_l                    (line 12)

``make_cohort_local_update`` is the same program vmapped over a leading
client axis: batches (K, M, ...), masks (K, M) -> deltas stacked (K, ...)
plus per-client mean losses (K,). The global params and the server-side
``extra`` state (Delta_prev for the cm/ga variants) are broadcast to every
client; the mask axis is per-client, so ragged cohorts (clients with
different minibatch counts) are handled by padding to the cohort max.

Client variants (selected by the server algorithm):
  plain  SGD/momentum/AdamW on the local loss
  prox   FedProx: + mu/2 ||w - w_global||^2 added to every local gradient
  cm     FedCM:   g <- alpha*g + (1-alpha)*Delta_prev  (client momentum)
  ga     FedGA:   local model initialized at w - beta*eta_l*Delta_prev

Host ingest (DESIGN.md §2): ``stack_batches``/``stack_cohort`` build the
padded (K, M, ...) cohort stack; ``stack_cohort_into`` does the same into
preallocated buffers, and ``CohortPrefetcher`` stages round t+1's stack
in a background thread while round t runs on device.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, get_optimizer

PyTree = Any


def _build_local_update(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                        eta_l: float, variant: str, optimizer: str,
                        mu: float, cm_alpha: float, ga_beta: float):
    """Raw (un-jitted) per-client local update; shared by the serial and
    the cohort-vectorized paths."""
    opt: Optimizer = get_optimizer(optimizer, eta_l)

    def local_update(global_params, batches, mask, extra):
        w0 = global_params
        if variant == "ga" and extra is not None:
            # displacement init along the previous global direction
            w0 = jax.tree.map(
                lambda w, d: (w.astype(jnp.float32)
                              - ga_beta * eta_l * d.astype(jnp.float32)
                              ).astype(w.dtype), global_params, extra)

        def step(carry, xs):
            params, opt_state, i, loss_sum, nvalid = carry
            if mask is None:            # fixed-shape stream: no select pass
                batch, valid = xs, None
            else:
                batch, valid = xs
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if variant == "prox":
                grads = jax.tree.map(
                    lambda g, p, p0: g + mu * (p.astype(jnp.float32)
                                               - p0.astype(jnp.float32)),
                    grads, params, global_params)
            if variant == "cm" and extra is not None:
                grads = jax.tree.map(
                    lambda g, d: cm_alpha * g
                    + (1.0 - cm_alpha) * d.astype(g.dtype), grads, extra)
            updates, new_opt_state = opt.update(grads, opt_state, params, i)
            new_params = jax.tree.map(lambda p, u: (p - u).astype(p.dtype),
                                      params, updates)
            if valid is None:
                params, opt_state = new_params, new_opt_state
                loss_sum = loss_sum + loss
                nvalid = nvalid + 1.0
            else:
                # masked batches are no-ops
                keep = lambda new, old: jax.tree.map(
                    lambda a, b: jnp.where(valid, a, b), new, old)
                params = keep(new_params, params)
                opt_state = keep(new_opt_state, opt_state)
                loss_sum = loss_sum + jnp.where(valid, loss, 0.0)
                nvalid = nvalid + valid.astype(jnp.float32)
            return (params, opt_state, i + 1, loss_sum, nvalid), None

        m = jax.tree.leaves(batches)[0].shape[0]
        carry0 = (w0, opt.init(w0), jnp.zeros((), jnp.int32),
                  jnp.zeros(()), jnp.zeros(()))
        (w, _, _, loss_sum, nvalid), _ = jax.lax.scan(
            step, carry0, batches if mask is None else (batches, mask),
            length=m)
        delta = jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
            / eta_l, global_params, w)
        return delta, loss_sum / jnp.maximum(nvalid, 1.0)

    return local_update


def make_local_update(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                      eta_l: float,
                      variant: str = "plain",
                      optimizer: str = "sgd",
                      mu: float = 0.01,
                      cm_alpha: float = 0.1,
                      ga_beta: float = 0.1,
                      jit: bool = True):
    """Returns fn(global_params, batches, mask, extra) ->
    (delta, mean_loss)  where batches is a pytree with leading axis M
    (minibatch stack), mask (M,) bool, extra = Delta_prev or None.
    mask=None means every batch is valid AND skips the masked-select pass
    over the parameters entirely (the mesh path's fixed-shape streams).
    """
    fn = _build_local_update(loss_fn, eta_l, variant, optimizer,
                             mu, cm_alpha, ga_beta)
    return jax.jit(fn) if jit else fn


def make_cohort_local_update(loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
                             eta_l: float,
                             variant: str = "plain",
                             optimizer: str = "sgd",
                             mu: float = 0.01,
                             cm_alpha: float = 0.1,
                             ga_beta: float = 0.1,
                             jit: bool = False):
    """Cohort-vectorized local training: fn(global_params, batches, masks,
    extra) -> (deltas, losses) with batches (K, M, ...), masks (K, M) or
    None (all valid, select-free), deltas client-stacked (K, ...), losses
    (K,). params/extra broadcast."""
    fn = _build_local_update(loss_fn, eta_l, variant, optimizer,
                             mu, cm_alpha, ga_beta)
    cohort = jax.vmap(fn, in_axes=(None, 0, 0, None))
    return jax.jit(cohort) if jit else cohort


def stack_batches(batch_list, max_batches: int):
    """Pad a list of same-shape batch pytrees to (max_batches, ...) + mask."""
    import numpy as np
    n = len(batch_list)
    assert 1 <= n <= max_batches, (n, max_batches)
    stacked = jax.tree.map(lambda *xs: np.stack(xs), *batch_list)
    if n < max_batches:
        pad = max_batches - n
        stacked = jax.tree.map(
            lambda x: np.concatenate(
                [x, np.repeat(x[-1:], pad, axis=0)], axis=0), stacked)
    mask = np.arange(max_batches) < n
    return stacked, mask


def stack_cohort(per_client_batches, max_batches: int, pad_to: int = None):
    """Stack K clients' batch lists into one (K, M, ...) pytree + (K, M)
    mask — the input of ``make_cohort_local_update``. M = max_batches is
    the shape bucket; ragged clients pad with masked repeats.

    ``pad_to`` > K appends DUMMY clients (copies of the last real row
    with an all-False mask row) so uneven cohorts shard over a client
    axis whose size does not divide K (DESIGN.md §2): a fully-masked
    client runs a no-op local scan (delta == 0) and the server rules
    exclude it from every mean via the derived client validity mask.
    """
    import numpy as np
    pairs = [stack_batches(b, max_batches) for b in per_client_batches]
    batches = jax.tree.map(lambda *xs: np.stack(xs), *[p[0] for p in pairs])
    masks = np.stack([p[1] for p in pairs])
    k = len(per_client_batches)
    if pad_to is not None and pad_to > k:
        pad = pad_to - k
        batches = jax.tree.map(
            lambda x: np.concatenate(
                [x, np.repeat(x[-1:], pad, axis=0)], axis=0), batches)
        masks = np.concatenate(
            [masks, np.zeros((pad,) + masks.shape[1:], bool)], axis=0)
    return batches, masks


def stack_cohort_into(per_client_batches, max_batches: int, slot: dict,
                      pad_to: int = None):
    """``stack_cohort`` into PREALLOCATED host buffers (DESIGN.md §2).

    ``slot`` is a mutable dict owned by the caller (one per prefetch
    buffer): its (K, M, ...) arrays + (K, M) mask are allocated on first
    use and reused every round — reallocation happens only when the
    cohort shape grows/changes (grow-once M bucketing keeps that rare),
    so the per-round np.stack allocations disappear from the ingest path.
    Returns (batches_pytree, mask) views backed by the slot's buffers;
    they stay valid until the slot is refilled.

    ``pad_to`` appends dummy clients exactly as ``stack_cohort`` does
    (copies of the last real row, all-False mask rows).
    """
    import numpy as np
    k, m = len(per_client_batches), max_batches
    kp = k if pad_to is None else max(pad_to, k)
    leaves0, treedef = jax.tree_util.tree_flatten(per_client_batches[0][0])
    shapes = tuple((np.shape(x), np.asarray(x).dtype) for x in leaves0)
    key = (kp, m, treedef, shapes)
    if slot.get("key") != key:
        slot["key"] = key
        slot["bufs"] = [np.empty((kp, m) + s, dt) for s, dt in shapes]
        slot["mask"] = np.empty((kp, m), bool)
    bufs, mask = slot["bufs"], slot["mask"]
    for j, blist in enumerate(per_client_batches):
        n = len(blist)
        assert 1 <= n <= m, (n, m)
        for i, b in enumerate(blist):
            for buf, x in zip(bufs, jax.tree_util.tree_flatten(b)[0]):
                buf[j, i] = x
        if n < m:                       # ragged: pad with masked repeats
            for buf in bufs:
                buf[j, n:] = buf[j, n - 1]
        mask[j] = np.arange(m) < n
    for j in range(k, kp):              # dummy clients: masked copies
        for buf in bufs:
            buf[j] = buf[k - 1]
        mask[j] = False
    return jax.tree_util.tree_unflatten(treedef, bufs), mask


class CohortPrefetcher:
    """Double-buffered host ingest for the fused cohort round.

    A daemon thread runs ``produce_fn(t, slot)`` for t = start..end-1 IN
    ROUND ORDER (so RNG-driven client sampling inside it draws the exact
    same sequence as the blocking path), staging round t+1's cohort into
    a free buffer slot while round t's program runs on device. With the
    default two slots the producer stays at most one round ahead and
    never overwrites a buffer the device may still be reading: the
    consumer releases a slot only after it has synchronized on the
    round's results.

        item, slot = pf.get(t)     # blocks only until round t is staged
        ... dispatch + sync ...
        pf.release(slot)
    """

    def __init__(self, produce_fn, start: int, end: int, slots: int = 2):
        import queue
        import threading
        self._end = end
        self._ready = queue.Queue()
        self._free = queue.Queue()
        for _ in range(max(2, slots)):
            self._free.put({})
        self._exc = None
        self._stopped = False
        self._thread = threading.Thread(
            target=self._loop, args=(produce_fn, start, end), daemon=True,
            name="cohort-prefetch")
        self._thread.start()

    def _loop(self, produce_fn, start, end):
        try:
            for t in range(start, end):
                slot = self._free.get()
                if slot is None:        # stop() sentinel
                    return
                item = produce_fn(t, slot)
                self._ready.put((t, item, slot))
        except BaseException as e:      # surfaced on the next get()
            self._exc = e
            self._ready.put((None, None, None))

    def get(self, t: int):
        import queue
        if t >= self._end:
            raise RuntimeError(
                f"round {t} is past the configured horizon ({self._end} "
                "rounds were prefetched); raise ExecConfig.rounds or set "
                "ExecConfig.prefetch=False to run extra rounds")
        while True:
            try:
                got, item, slot = self._ready.get(timeout=1.0)
                break
            except queue.Empty:
                # a dead producer with an empty queue would otherwise
                # hang forever (e.g. rounds re-run after a completed run)
                if not self._thread.is_alive():
                    try:
                        # drain once more: the producer's final put may
                        # have landed between the timeout and this check
                        got, item, slot = self._ready.get_nowait()
                        break
                    except queue.Empty:
                        raise RuntimeError(
                            f"prefetch producer exited (rounds consumed "
                            f"or stopped) — round {t} was never staged; "
                            "set ExecConfig.prefetch=False to re-run rounds"
                        ) from self._exc
        if got is None:                 # producer-failure sentinel; a round
            # staged BEFORE the failure is still valid and returned above.
            # Re-poison so every later get() fails too instead of hanging.
            self._ready.put((None, None, None))
            raise RuntimeError("cohort prefetch thread failed") from self._exc
        if got != t:
            raise RuntimeError(
                f"prefetched round {got} but round {t} was requested — "
                "prefetching requires run_round(t) in sequential order "
                "(set ExecConfig.prefetch=False for out-of-order rounds)")
        return item, slot

    def release(self, slot: dict):
        self._free.put(slot)

    def stop(self):
        if not self._stopped:
            self._stopped = True
            self._free.put(None)        # unblock the producer if waiting
