"""Buffered-async round engine — FedBuff-style streaming aggregation
(DESIGN.md §11; FedBuff, arXiv:2106.06639).

The synchronous trainer steps the server once per fully-finished cohort;
under real partial participation stragglers hold every round hostage.
Here the cohort dispatches are WAVES: wave w's clients train against the
params snapshot current at dispatch time, their updates travel for
runtime-model latencies (core/runtime.py), and finished updates stream
into a server-side buffer as they arrive. Every ``buffer_size`` (B)
arrivals the server folds the buffer in one step; an update computed
against snapshot version v and folded at version t carries staleness
s = t - v and a discount weight

    w(s) = (1 + s) ** (-alpha)          (exactly 1.0 at s = 0)

folded into the aggregation — for FedDPC, multiplied into the
reduction-pass ``scale`` so the projection geometry is computed on the
RAW delta and only the applied magnitude is discounted (the
staleness-discounted projection coefficient of DESIGN.md §11); for
mean-style rules, pre-scaled onto the buffered deltas (FedBuff
semantics).

Time is VIRTUAL: a (finish_time, seq) min-heap orders arrivals, the
clock jumps to each pop, and latencies come from the pluggable runtime
model whose draws happen inside the trainer's sampling lock in wave
order — so the whole async trajectory is a pure function of (seed,
configuration) and replays bitwise across prefetch depths and
checkpoint/resume cuts. The ``seq`` tiebreak makes equal-latency
arrivals pop in dispatch order, which is what pins the anchor cell of
the regime matrix: DeterministicRuntime + concurrency 1 + B = K yields
arrival order == cohort order and staleness identically 0, i.e. the
synchronous round.

``concurrency`` bounds how many waves may be in flight at once; new
waves dispatch whenever the in-flight count drops below it (or the heap
runs dry), so higher concurrency trades staleness for utilization —
the axis the --async-sweep benchmark walks.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Callable, List, Tuple

import jax
import numpy as np

PyTree = Any


@dataclass(order=False)
class BufferEntry:
    """One in-flight (or buffered) client update. ``version`` is the
    server-params version the delta was computed against; staleness at
    fold time is ``fold_version - version``. Ordered by (finish, seq)
    in the virtual-time heap — ``seq`` is a global dispatch counter, so
    equal finish times resolve in dispatch order (deterministic)."""
    client: int
    wave: int
    version: int
    seq: int
    finish: float          # virtual arrival time
    loss: float
    delta: PyTree          # one client's update (tree mirroring params)


class BufferedAsyncEngine:
    """Virtual-time wave dispatcher + server-side arrival buffer.

    Collaborators (all trainer-owned, so the engine stays free of jit /
    algorithm specifics):

      pipeline       CohortIngestPipeline staging wave cohorts in wave
                     order (rounds=None: the wave horizon is open)
      wave_update    (params, server_state, batches, masks) ->
                     (deltas (Kp, ...), losses (Kp,)) — the jit'd
                     cohort local update against the CURRENT snapshot
      fold           (server_state, params, deltas (B, ...), ids (B,),
                     weights (B,)) -> (new_params, new_state, diag) —
                     the jit'd staleness-weighted server step
      runtime_take   wave -> (latencies (k,), dropped (k,)) — the
                     latency draws captured at sampling time under the
                     trainer's lock (round-order RNG contract)
    """

    def __init__(self, *, pipeline, wave_update: Callable,
                 fold: Callable, runtime_take: Callable,
                 buffer_size: int, alpha: float = 0.5,
                 concurrency: int = 1, prefetch: bool = True,
                 deadline: float = None, fold_extras: Callable = None,
                 fold_returns_stats: bool = False):
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        if alpha < 0:
            raise ValueError(f"staleness alpha must be >= 0, got {alpha}")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be positive, got {deadline}")
        self.pipeline = pipeline
        self.wave_update = wave_update
        self.fold = fold
        self.runtime_take = runtime_take
        self.buffer_size = int(buffer_size)
        self.alpha = float(alpha)
        self.concurrency = int(concurrency)
        self.prefetch = prefetch
        # round deadline in virtual seconds (DESIGN.md §12): stop
        # collecting arrivals once the next one would land more than
        # ``deadline`` past the round's start and fold the PARTIAL
        # buffer (at least one arrival always folds — an empty fold is
        # undefined). Stragglers stay in flight and fold later with
        # their staleness discount; nothing is discarded.
        self.deadline = None if deadline is None else float(deadline)
        # chaos hooks (trainer-owned, DESIGN.md §12): fold_extras maps
        # the arrival list to extra jit inputs (fault codes, guard
        # threshold); fold_returns_stats marks a fold returning a 4th
        # guard-stats element that run_server_round surfaces in metrics
        self.fold_extras = fold_extras
        self.fold_returns_stats = fold_returns_stats
        # ---- virtual-time state (all checkpointed — see api.save) ----
        self.clock = 0.0               # virtual time of the last arrival
        self.seq = 0                   # global dispatch counter (tiebreak)
        self.wave_frontier = 0         # next wave to dispatch
        self.version = 0               # server folds performed so far
        self._heap: List[Tuple[float, int, BufferEntry]] = []

    # ---- wave dispatch ----

    def _live_waves(self) -> int:
        return len({e.wave for (_, _, e) in self._heap})

    def _dispatch_wave(self, params, server_state):
        """Stage + train the next wave against the current snapshot and
        push its surviving updates onto the arrival heap. Returns
        (n_pushed, host_seconds, device_seconds)."""
        w = self.wave_frontier
        staged = (self.pipeline.get(w) if self.prefetch
                  else self.pipeline.stage_blocking(w))
        try:
            deltas, losses = self.wave_update(
                params, server_state, staged.batches, staged.masks)
            # host sync on the losses: the program is done, the staged
            # inputs are consumed, and the slot can be reused; the
            # per-client delta slices below read the program's OUTPUT
            losses_h = np.asarray(losses, np.float32)
            lat, dropped = self.runtime_take(w)
            pushed = 0
            for j in range(len(staged.clients)):
                if dropped[j]:
                    continue           # never arrives (wasted compute)
                entry = BufferEntry(
                    client=int(staged.clients[j]), wave=w,
                    version=self.version, seq=self.seq,
                    finish=self.clock + float(lat[j]),
                    loss=float(losses_h[j]),
                    delta=jax.tree.map(lambda x, j=j: x[j], deltas))
                heapq.heappush(self._heap,
                               (entry.finish, entry.seq, entry))
                self.seq += 1
                pushed += 1
        finally:
            staged.release()
        self.wave_frontier = w + 1
        return pushed, staged.host_seconds, staged.device_seconds

    # ---- server round ----

    def run_server_round(self, t: int, params, server_state):
        """Collect the next ``buffer_size`` arrivals (dispatching waves
        as concurrency allows) and fold them into one server step.
        Returns (new_params, new_server_state, metrics)."""
        arrivals: List[BufferEntry] = []
        host_s = dev_s = 0.0
        empty_streak = 0
        start_clock = self.clock
        deadline_fired = 0
        wave_start = self.wave_frontier
        shipped = 0                    # updates pushed in flight this round
        while len(arrivals) < self.buffer_size:
            # top up in-flight waves: always at least one pending
            # arrival, and up to `concurrency` waves in flight
            while not self._heap or self._live_waves() < self.concurrency:
                if self._heap and self._live_waves() >= self.concurrency:
                    break
                n, h, d = self._dispatch_wave(params, server_state)
                shipped += n
                host_s += h
                dev_s += d
                empty_streak = 0 if n else empty_streak + 1
                if empty_streak >= 100:
                    # dropout < 1 makes an infinite all-dropped run a
                    # probability-zero event; a runtime model violating
                    # that surfaces here instead of spinning forever
                    raise RuntimeError(
                        f"{empty_streak} consecutive waves dropped every "
                        "client — runtime model starves the buffer")
            if (self.deadline is not None and arrivals
                    and self._heap[0][0] > start_clock + self.deadline):
                # partial-buffer fold (DESIGN.md §12): the next arrival
                # would land past the deadline — fold what we have; the
                # stragglers stay in flight and fold later, discounted
                # by whatever staleness the wait earned them
                deadline_fired = 1
                break
            finish, _, entry = heapq.heappop(self._heap)
            self.clock = max(self.clock, finish)
            arrivals.append(entry)
        stale = np.asarray([self.version - e.version for e in arrivals],
                           np.float64)
        # (1+s)^(-alpha): exactly 1.0 at s=0, so the anchor cell's fold
        # multiplies by literal 1.0f and stays bitwise-equivalent
        weights = ((1.0 + stale) ** (-self.alpha)).astype(np.float32)
        ids = np.asarray([e.client for e in arrivals], np.int32)
        stacked = jax.tree.map(lambda *xs: jax.numpy.stack(xs),
                               *[e.delta for e in arrivals])
        extras = self.fold_extras(arrivals) if self.fold_extras else ()
        out = self.fold(
            server_state, params, stacked, jax.numpy.asarray(ids),
            jax.numpy.asarray(weights), *extras)
        gstats = None
        if self.fold_returns_stats:
            params, server_state, diag, gstats = out
        else:
            params, server_state, diag = out
        self.version += 1
        metrics = {
            "train_loss": float(np.mean([e.loss for e in arrivals])),
            "staleness_mean": float(stale.mean()),
            "staleness_max": float(stale.max()),
            "diag": diag,
            "host_seconds": host_s,
            "device_seconds": dev_s,
            "n_arrivals": len(arrivals),
            # uplink accounting (DESIGN.md §13): updates SHIPPED (pushed
            # in flight) while this round collected — bytes are paid at
            # ship time whether or not this fold consumed the update
            # (stragglers fold later; runtime dropouts never shipped)
            "n_shipped": shipped,
            # waves dispatched during this round's collection
            # [wave_start, wave_end): the staging rounds whose ingest
            # restarts this server round should be charged with
            "wave_start": wave_start,
            "wave_end": self.wave_frontier,
            "deadline_fired": deadline_fired,
            "deadline_dropped": (self.buffer_size - len(arrivals)
                                 if deadline_fired else 0),
            "guard_stats": gstats,
        }
        return params, server_state, metrics

    # ---- checkpointing (driven by FederatedTrainer.save/restore) ----

    def inflight(self) -> List[BufferEntry]:
        """In-flight entries in (finish, seq) heap order — dispatched
        but not yet arrived/folded. The arrival buffer itself is always
        empty between rounds (run_server_round folds exactly what it
        collects), so this IS the full streaming state."""
        return [e for (_, _, e) in sorted(self._heap,
                                          key=lambda x: (x[0], x[1]))]

    def load_inflight(self, entries: List[BufferEntry]) -> None:
        self._heap = [(e.finish, e.seq, e) for e in entries]
        heapq.heapify(self._heap)
