"""FederatedTrainer — simulation-mode FL driver (reproduces the paper).

Composable engine (DESIGN.md §3): the trainer orchestrates four
pluggable pieces behind one loop —

  ClientSampler (core/samplers.py)   WHO participates each round:
      uniform / weighted-by-data-size / cyclic block / Markov
      availability; ``sampler.sample(rng, round) -> ids``.
  DataSource (repro.ingest)          WHERE batches come from:
      ``source.client_batches(client, round)``; materialized on the
      ingest path, so a streaming source (ingest.StreamingImageSource,
      the disk-backed CIFAR/TinyImageNet sources in ingest.datasets)
      overlaps disk IO with device compute through the staging ring.
  algorithm registry (core/baselines.py)   HOW updates aggregate:
      ``AlgoConfig(name, hyper=FedDPCHyper(...))`` resolves through
      ``make_algorithm``; per-algorithm hyperparameter dataclasses
      replace the old flat lam/mu/... kwargs.
  ExecConfig                         HOW the loop executes: rounds,
      cohort size, vectorize/shard/prefetch/async-eval levers.

The flat ``FLConfig`` is kept as a deprecated-but-working shim: passing
it to ``FederatedTrainer`` warns and splits into (AlgoConfig, ExecConfig)
with round-for-round identical results.

The default round is **cohort-vectorized** (exec.vectorize=True): all
clients_per_round clients' padded minibatch stacks are stacked into one
(K, M, ...) batch pytree and the whole round — local training vmapped
over the client axis, fused with the server step — runs as ONE jit'd
program per round (core/round.py ``make_cohort_round``), donating the
params/server-state buffers. exec.vectorize=False keeps the historical
serial path (one jit dispatch per client + a host-side stack), retained
as the reference for the equivalence tests.

Shape bucketing: M is padded to the cohort max and ``_max_batches`` only
grows (grow-once), so the jit cache holds one program per (K, M) bucket
and later rounds with fewer batches re-use the compiled round.

Scaling levers (DESIGN.md §2), all on by construction or by one flag:
  exec.shard_clients  client-axis NamedSharding over the local devices —
      the (K, M, ...) cohort stack runs data-parallel across the mesh,
      params/server state replicated. K that does not divide the axis is
      PADDED with masked dummy clients to the next multiple (the server
      rules exclude them via the derived client validity mask).
  exec.shard_model    M > 1 composes a `model` axis with the client axis
      (a (devices//M, M) two-axis mesh): params/server state shard PER
      LEAF over `model` (§8 rules + trailing-dim fallback) inside each
      client slice — the layout for models larger than one device's HBM.
  exec.prefetch       staged ingest (DESIGN.md §10): a daemon thread
      stages upcoming cohorts (sampling + source reads + decode +
      stacking into a depth-``prefetch_depth`` ring of preallocated
      buffers, + device placement when ``device_stage``) while round t
      runs on device, so run_round blocks only on device completion
      (repro.ingest.CohortIngestPipeline).
  exec.async_eval     eval_fn runs on a params snapshot in a worker
      thread, overlapped with the next round; the accuracy folds into
      its RoundRecord at the next eval boundary / finalize() / run() end.

Checkpointing: ``save(dir)`` writes the full ``TrainerState`` (params,
server state, RNG + sampler state, round, shape bucket, history) through
checkpoint/checkpoint.py; ``FederatedTrainer.resume(dir, ...)`` restores
it into a fresh trainer whose continued run reproduces the uninterrupted
one round for round.

The trainer is a context manager — ``with FederatedTrainer(...) as tr:``
guarantees the prefetch thread and any pending eval future are released.

Works for any (loss_fn, params, data source): the paper's vision models
and the framework's LM architectures both plug in through the same API.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
import warnings
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client as client_mod
from repro.core import round as round_mod
from repro.core.baselines import ServerAlgo, default_hyper, make_algorithm
from repro.core.samplers import ClientSampler, UniformSampler
from repro.ingest import (CohortIngestPipeline, CohortPlacer, DataSource,
                          as_data_source, stack_batches)

PyTree = Any

# deterministic per-process trainer ordinal for multi-process KV-store
# namespacing (every process constructs trainers in the same SPMD order,
# so the ordinal agrees across hosts where id()/PIDs would not)
_MP_SEQ = itertools.count()


@dataclass
class AlgoConfig:
    """WHAT to optimize: the server rule + its hyperparameters.

    ``hyper`` is the algorithm's registered dataclass (baselines.
    FedDPCHyper, FedProxHyper, ...) or a kwargs dict for it; None takes
    registry defaults."""
    name: str = "feddpc"
    eta_l: float = 0.1               # client learning rate
    eta_g: float = 1.0               # server learning rate
    local_optimizer: str = "sgd"
    hyper: Any = None
    # ---- uplink compression (repro.codec, DESIGN.md §13) ----
    # delta codec by registry name ("identity" / "bf16" / "int8" /
    # "int8_sym" / "int8_sr"); None = no codec (identical to "identity",
    # which is a literal pass-through). Lives on AlgoConfig because a
    # lossy codec changes WHAT the server aggregates — ExecConfig.codec
    # can override it per execution regime.
    codec: Optional[str] = None
    # server-side error feedback: clients ship Delta_j + ef, the server
    # keeps the mean sanitized quantization residual as the next ef
    codec_ef: bool = False
    # ---- server-side optimizer (repro.optim.server, DESIGN.md §14) ----
    # registry name: "sgd" (the default — accepts every rule's proposed
    # step verbatim, bitwise the pre-layer round), "fedadam", "fedyogi".
    # Lives on AlgoConfig because the optimizer changes WHAT the server
    # step computes; ExecConfig.server_opt can override it per regime.
    server_opt: str = "sgd"


@dataclass
class ExecConfig:
    """HOW to run it: loop shape + the execution levers of DESIGN.md §2."""
    rounds: int = 50
    clients_per_round: int = 10
    seed: int = 0
    eval_every: int = 5
    vectorize: bool = True           # one fused program per round (default)
    shard_clients: bool = False      # client-axis NamedSharding over devices
    # model-axis shards per client slice: >1 builds the two-axis
    # (clients, model) mesh (DESIGN.md §2) — params/server state shard per
    # leaf over `model` (the >HBM regime), batches stay on the client
    # axis. Must divide the device count; implies the sharded path.
    shard_model: int = 1
    prefetch: bool = True            # staged ingest ring (vectorized path)
    # staging-ring depth (DESIGN.md §10): number of cohort buffers the
    # ingest pipeline cycles through — the producer thread stages up to
    # prefetch_depth rounds beyond the oldest round still in flight.
    # 2 = the historical double buffer; raise it when source reads are
    # bursty (disk-backed datasets) so slow rounds amortize
    prefetch_depth: int = 2
    # run the device-place stage (jax.device_put against the round's
    # actual sharding) on the staging thread, so H2D transfer overlaps
    # compute instead of serializing at dispatch; False keeps placement
    # on the consumer thread, where RoundRecord.ingest_device_seconds
    # measures it (the depth sweep's baseline — DESIGN.md §10)
    device_stage: bool = True
    # overlap eval_fn with the next round: accuracy folds into its
    # RoundRecord when ready (at latest at the next eval boundary /
    # finalize()/run() end) — read it from history, not from the record
    # run_round just returned; set False for strictly inline eval
    async_eval: bool = True
    # ---- buffered-async regime (DESIGN.md §11) ----
    # FedBuff-style streaming aggregation: cohorts become WAVES trained
    # against possibly-stale snapshots, updates stream into a server
    # buffer through the pluggable runtime model (core/runtime.py), and
    # the server steps every buffer_size arrivals with the staleness
    # discount (1+s)^(-staleness_alpha) folded into the aggregation
    async_buffer: bool = False
    # arrivals per server step (B); None -> clients_per_round, which at
    # async_concurrency=1 under DeterministicRuntime IS the sync round
    buffer_size: Optional[int] = None
    staleness_alpha: float = 0.5
    # max waves in flight at once: >1 lets fresh waves overlap stale
    # stragglers (staleness > 0 appears), 1 keeps waves serial
    async_concurrency: int = 1
    # staging-ring stall deadline (seconds): a producer thread alive but
    # stuck inside produce_fn raises instead of spinning forever; None
    # keeps the historical wait-forever behavior
    ingest_stall_s: Optional[float] = None
    # ---- self-healing ingest (DESIGN.md §12) ----
    # bounded supervised restart of a crashed staging producer: up to
    # this many retries PER ROUND with exponential backoff before the
    # failure poisons the ring; 0 keeps the historical fail-fast
    ingest_max_restarts: int = 0
    ingest_restart_backoff_s: float = 0.05
    # ---- chaos hardening (DESIGN.md §12) ----
    # update guard: validate every arriving client delta (non-finite /
    # exploded-norm quarantine via client_mask folding, norm clipping
    # against a rolling robust threshold) before the server rule sees it
    guard: bool = False
    guard_quarantine_mult: float = 1e3
    guard_clip_mult: float = 1e2
    guard_window: int = 64
    guard_min_history: int = 8
    # round deadline in VIRTUAL seconds (the runtime model's latency
    # unit). Sync engines drop-and-mask clients whose latency exceeds
    # it; the buffered-async engine folds a PARTIAL buffer rather than
    # waiting past the deadline for stragglers. None = wait forever.
    round_deadline: Optional[float] = None
    # ---- uplink compression overrides (repro.codec, DESIGN.md §13) ----
    # execution-level codec override: None defers to AlgoConfig.codec
    # (the primary home of the knob); a regime entry in EXEC_REGIMES can
    # set it so the cross-regime matrix auto-enrolls codec cells
    codec: Optional[str] = None
    codec_ef: Optional[bool] = None
    # execution-level server-optimizer override (repro.optim.server,
    # DESIGN.md §14): None defers to AlgoConfig.server_opt (the knob's
    # primary home); regime entries set it for matrix auto-enrollment
    server_opt: Optional[str] = None
    # ---- run-health monitor (repro.health, DESIGN.md §14) ----
    # consume every RoundRecord through a HealthMonitor: rolling-median
    # loss spike / non-finite detection, staleness + quarantine-rate
    # trend alarms, and (patience set) an early-stop hook run() honors;
    # detector state checkpoints through the aux sidecar
    health: bool = False
    health_window: int = 32
    health_min_history: int = 8
    health_spike_mult: float = 3.0
    health_patience: Optional[int] = None
    # stream every health verdict to a JSONL tracker file (repro.health.
    # JsonlHealthSink — the wandb-style pluggable sink); None = receipts
    # only. Multi-process runs write it on process 0 only.
    health_log: Optional[str] = None
    # ---- hierarchical edge aggregation (DESIGN.md §15) ----
    # two-level server fold: the padded cohort splits into `edges` equal
    # contiguous row groups ("edge aggregators"), each folds its slice
    # into one partial summary, and the server combines the E summaries
    # instead of K raw deltas — the round shape of a multi-host cohort,
    # where each edge is one host's local clients and the server's
    # fan-in drops from K deltas to E summaries. None / 1 = flat fold.
    # Must divide the PADDED cohort size; serial-reference equivalent
    # by construction (tests/test_regime_matrix.py `multihost` cell).
    edges: Optional[int] = None
    # bounded thread pool for the per-image file decode of disk-backed
    # sources (ingest/readers.py) — a driver hint like batch_size: the
    # trainer never reads it, source constructors do. 0 = serial decode.
    decode_workers: int = 0
    # data-shape hints for drivers that build sources from raw datasets
    # (the trainer itself never reads them)
    batch_size: int = 256
    local_epochs: int = 1


@dataclass
class FLConfig:
    """DEPRECATED flat config — the pre-registry surface. Passing it to
    ``FederatedTrainer`` warns and splits into (AlgoConfig, ExecConfig);
    results are round-for-round identical to the split spelling."""
    algorithm: str = "feddpc"
    rounds: int = 50
    clients_per_round: int = 10
    eta_l: float = 0.1
    eta_g: float = 1.0
    lam: float = 1.0                 # FedDPC adaptive-scaling hyper-param
    mu: float = 0.01                 # FedProx
    cm_alpha: float = 0.1            # FedCM
    ga_beta: float = 0.1             # FedGA
    batch_size: int = 256
    local_epochs: int = 1
    local_optimizer: str = "sgd"
    seed: int = 0
    eval_every: int = 5
    use_kernel: bool = False         # route FedDPC epilogue through Pallas
    vectorize: bool = True
    shard_clients: bool = False
    prefetch: bool = True
    async_eval: bool = True

    def split(self) -> Tuple[AlgoConfig, ExecConfig]:
        """Map the flat knobs onto the composable configs; the per-
        algorithm hypers pick up whichever flat field used to feed them."""
        hyper = default_hyper(
            self.algorithm, lam=self.lam, use_kernel=self.use_kernel,
            mu=self.mu, cm_alpha=self.cm_alpha, ga_beta=self.ga_beta)
        algo = AlgoConfig(name=self.algorithm, eta_l=self.eta_l,
                          eta_g=self.eta_g,
                          local_optimizer=self.local_optimizer, hyper=hyper)
        exe = ExecConfig(rounds=self.rounds,
                         clients_per_round=self.clients_per_round,
                         seed=self.seed, eval_every=self.eval_every,
                         vectorize=self.vectorize,
                         shard_clients=self.shard_clients,
                         prefetch=self.prefetch, async_eval=self.async_eval,
                         batch_size=self.batch_size,
                         local_epochs=self.local_epochs)
        return algo, exe


# Execution regimes of the cross-regime equivalence matrix
# (tests/test_regime_matrix.py): name -> ExecConfig overrides relative to
# the serial reference. Every regime must be round-for-round allclose to
# ``serial`` for every registered algorithm x sampler; a NEW execution
# lever added to ExecConfig registers its regime here and the matrix
# auto-enrolls it. ``sharded2d`` is written for the 8-device harness the
# matrix forces on CPU (4-way model sharding leaves a 2-way client axis —
# the (2 clients x 4 model) acceptance mesh); real accelerator runs pick
# shard_model to fit their topology.
EXEC_REGIMES = {
    "serial": {"vectorize": False},
    "vectorized": {},
    "sharded1d": {"shard_clients": True},
    "sharded2d": {"shard_clients": True, "shard_model": 4},
    # staged ingest (DESIGN.md §10): the deep device-staged ring on every
    # mesh shape (the prefetch_depth=4 acceptance configuration), plus
    # the consumer-thread-placement / single-buffer degenerate point
    "staged": {"prefetch_depth": 4},
    "staged1d": {"shard_clients": True, "prefetch_depth": 4},
    "staged2d": {"shard_clients": True, "shard_model": 4,
                 "prefetch_depth": 4},
    "hoststaged": {"device_stage": False, "prefetch_depth": 1},
    # buffered-async streaming aggregation (DESIGN.md §11): with the
    # default DeterministicRuntime, buffer_size=K and concurrency 1 the
    # wave schedule, arrival order and staleness-0 discounts reproduce
    # the synchronous round — the anchor cell the matrix pins
    "async_buffer": {"async_buffer": True},
    # chaos-hardened rounds (DESIGN.md §12): with no faults injected the
    # guard's every multiplier is literally 1.0 (threshold starts +inf),
    # so the guarded round must reproduce the unguarded serial reference
    "guarded": {"guard": True},
    # delta codecs (repro.codec, DESIGN.md §13): identity must reproduce
    # the no-codec round BITWISE; the lossy cells must track the serial
    # reference within the documented codec tolerance
    # (tests/_regime_matrix_check.py CODEC_TOL), including the two-axis
    # mesh and the buffered-async anchor
    "codec_identity": {"codec": "identity"},
    "codec_bf16": {"codec": "bf16"},
    "codec_int8": {"codec": "int8"},
    "codec_int8_2d": {"codec": "int8", "shard_clients": True,
                      "shard_model": 4},
    "codec_int8_async": {"codec": "int8", "async_buffer": True},
    # server-side optimizers (repro.optim.server, DESIGN.md §14): the
    # adaptive cells must track a serial reference run with the SAME
    # server_opt (the optimizer consumes the post-projection aggregate,
    # so it is regime-independent by construction), including the
    # two-axis mesh — where the moment state shards with the params —
    # and the buffered-async anchor
    "server_fedadam": {"server_opt": "fedadam"},
    "server_fedyogi": {"server_opt": "fedyogi"},
    "server_fedadam_2d": {"server_opt": "fedadam", "shard_clients": True,
                          "shard_model": 4},
    "server_fedyogi_2d": {"server_opt": "fedyogi", "shard_clients": True,
                          "shard_model": 4},
    "server_fedadam_async": {"server_opt": "fedadam", "async_buffer": True},
    "server_fedyogi_async": {"server_opt": "fedyogi", "async_buffer": True},
    # hierarchical edge aggregation (DESIGN.md §15): the two-level fold
    # on the 8-device harness (the padded cohort splits into 2 edges)
    # must reproduce the flat serial fold — the single-process anchor of
    # the multi-process cohort; the real 2-process cell runs out of
    # process through tests/_multihost_worker.py (spawn_local)
    "multihost": {"shard_clients": True, "edges": 2},
}


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_accuracy: Optional[float] = None
    seconds: float = 0.0
    # total time this round spent blocked on cohort ingest —
    # ingest_host_seconds + ingest_device_seconds (kept for continuity
    # with pre-split records/benches)
    ingest_seconds: float = 0.0
    # blocked on HOST staging: sampling + source reads + decode +
    # stacking (with prefetch on, just the wait for the staged round)
    ingest_host_seconds: float = 0.0
    # blocked on DEVICE placement at dispatch (H2D transfer); ~0 when
    # ExecConfig.device_stage moved it onto the staging thread
    ingest_device_seconds: float = 0.0
    diagnostics: Dict[str, float] = field(default_factory=dict)
    # buffered-async regime only (DESIGN.md §11): staleness statistics
    # of the arrivals this server step folded — kept OUT of diagnostics
    # (whose keys the cross-regime matrix requires equal to the sync
    # run); identically 0.0 in every synchronous regime
    staleness_mean: float = 0.0
    staleness_max: float = 0.0
    # ---- health counters (DESIGN.md §12) — identically 0 in a healthy
    # run, and kept OUT of diagnostics for the same matrix reason ----
    quarantined: int = 0           # deltas zeroed + masked by the guard
    clipped: int = 0               # deltas norm-clipped by the guard
    deadline_fired: int = 0        # 1 if the round hit round_deadline
    deadline_dropped: int = 0      # clients dropped by the deadline
    ingest_restarts: int = 0       # staging-producer restarts this round
    # uplink bytes this round: clients-that-shipped x the codec's wire
    # bytes per delta (repro.codec, DESIGN.md §13) — full-precision f32
    # bytes with no codec, so compression wins are measured, not
    # asserted; kept OUT of diagnostics for the same matrix reason
    comm_bytes_up: int = 0
    # hierarchical split of the uplink (DESIGN.md §15): client->edge
    # bytes (the per-client deltas, paid to the nearest aggregator) vs
    # edge->server bytes (live edges x one raw-f32 params-shaped partial
    # summary each). Flat rounds report edge_up=0 and server_up=
    # comm_bytes_up, so edge_up + server_up is comparable across round
    # shapes and the K->E server fan-in reduction is measurable.
    comm_bytes_edge_up: int = 0
    comm_bytes_server_up: int = 0
    edge_dropped: int = 0          # edge summaries lost to process faults


@dataclass
class TrainerState:
    """Everything needed to continue a run exactly where it stopped:
    the checkpoint unit of ``FederatedTrainer.save()/resume()``.

    ``round`` is the NEXT round to run; ``rng_state`` / ``sampler_state``
    are the values they held BEFORE that round's cohort was sampled (the
    prefetcher stages ahead, so the trainer snapshots them at sampling
    time — resuming re-draws the staged-but-unconsumed rounds exactly)."""
    params: PyTree
    server_state: PyTree
    round: int
    max_batches: Optional[int]
    rng_state: tuple                     # np.random.RandomState.get_state()
    sampler_state: Dict
    schedule: List[np.ndarray]
    history: List[RoundRecord]
    # buffered-async regime: runtime-model state (e.g. the Markov
    # fast/slow chain) as of the next wave to dispatch; None elsewhere
    runtime_state: Optional[Dict] = None
    # server-optimizer preconditioner state (repro.optim.server,
    # DESIGN.md §14): {"m","v"} params mirrors for fedadam/fedyogi,
    # None for the stateless sgd anchor
    opt_state: Optional[PyTree] = None


def _coerce_cfg(cfg, algo) -> Tuple[AlgoConfig, ExecConfig]:
    """Resolve the (cfg, algo) pair __init__ and resume() both accept:
    a deprecated flat FLConfig warns (attributed to the END caller,
    stacklevel=3 — the CI gate errors on warnings raised FROM repro.*)
    and splits; an ExecConfig pairs with ``algo`` or defaults."""
    if isinstance(cfg, FLConfig):
        if algo is not None:
            raise ValueError("pass either a flat FLConfig or "
                             "algo=AlgoConfig(...), not both")
        warnings.warn(
            "FLConfig is deprecated: pass ExecConfig(...) plus "
            "algo=AlgoConfig(name=..., hyper=...) (see DESIGN.md §3 "
            "for the migration table)", DeprecationWarning, stacklevel=3)
        return cfg.split()
    if cfg is None or isinstance(cfg, ExecConfig):
        return (algo if algo is not None else AlgoConfig(),
                cfg if cfg is not None else ExecConfig())
    raise TypeError(f"cfg must be ExecConfig or FLConfig, "
                    f"got {type(cfg).__name__}")


class FederatedTrainer:
    """loss_fn(params, batch) -> scalar; eval_fn(params) -> accuracy.

    ``data`` is a DataSource (or a legacy ``batch_fn(client, round) ->
    list`` callable, auto-wrapped in ListDataSource).  ``cfg`` is an
    ExecConfig (pair it with ``algo=AlgoConfig(...)``) or a deprecated
    flat FLConfig.  ``sampler`` defaults to the paper's uniform-without-
    replacement participation."""

    def __init__(self, loss_fn: Callable, params: PyTree, num_clients: int,
                 data, cfg=None,
                 eval_fn: Optional[Callable[[PyTree], float]] = None, *,
                 algo: Optional[AlgoConfig] = None,
                 sampler: Optional[ClientSampler] = None,
                 runtime=None, fault_plan=None, health_sink=None):
        algo_cfg, exec_cfg = _coerce_cfg(cfg, algo)
        if (runtime is not None and not exec_cfg.async_buffer
                and exec_cfg.round_deadline is None):
            raise ValueError(
                "a runtime model drives the buffered-async regime or a "
                "round deadline — pass ExecConfig(async_buffer=True) or "
                "ExecConfig(round_deadline=...) with it")
        if (exec_cfg.round_deadline is not None
                and exec_cfg.round_deadline <= 0):
            raise ValueError(f"round_deadline must be positive, "
                             f"got {exec_cfg.round_deadline}")
        if exec_cfg.async_buffer and not exec_cfg.vectorize:
            raise ValueError("async_buffer dispatches whole waves through "
                             "the cohort-vectorized update; it cannot "
                             "combine with vectorize=False")
        self.cfg = exec_cfg                   # execution knobs
        self.algo_cfg = algo_cfg
        # private copy: the fused round donates the params buffers, and the
        # caller's tree must stay valid (sweeps reuse one init across runs)
        self.params = jax.tree.map(lambda x: jnp.array(x, copy=True), params)
        self.num_clients = num_clients
        self.source: DataSource = as_data_source(data)
        self.eval_fn = eval_fn
        self.sampler: ClientSampler = sampler if sampler is not None else \
            UniformSampler(num_clients, exec_cfg.clients_per_round)
        self.algo: ServerAlgo = make_algorithm(algo_cfg.name, algo_cfg.hyper)
        self.server_state = self.algo.init(self.params, num_clients)
        # ---- multi-process cohort execution (DESIGN.md §15) ----
        # jax.distributed (launch/distributed.maybe_initialize) must have
        # run BEFORE construction; the device queries above already bound
        # the backend, so process_count() is final here. shard_clients is
        # the mode switch: with it the cohort mesh spans every process
        # (each host stages its local client slice); without it the
        # trainer stays PROCESS-LOCAL (default device, no cross-process
        # traffic) — which is how a worker computes its in-job serial
        # reference.
        self._nprocs = jax.process_count()
        self._mp = self._nprocs > 1 and exec_cfg.shard_clients
        self._mp_seq = next(_MP_SEQ) if self._mp else 0
        self._save_seq = 0
        if self._mp:
            if not exec_cfg.vectorize:
                raise ValueError(
                    "multi-process execution drives the fused cohort "
                    "round; it cannot combine with vectorize=False")
            if exec_cfg.shard_model > 1:
                raise ValueError(
                    "multi-process model sharding is not supported: the "
                    "clients axis is the process-spanning one "
                    "(DESIGN.md §15)")
            if exec_cfg.async_buffer:
                raise ValueError(
                    "the buffered-async engine is single-process; "
                    "multi-process runs use the synchronous cohort round")
        elif self._nprocs > 1 and exec_cfg.shard_model > 1:
            raise ValueError(
                "shard_model without shard_clients would build a "
                "process-spanning model mesh — in a multi-process job "
                "set shard_clients=True (cohort mode) or leave both off "
                "(process-local trainer)")
        # ---- chaos hardening (DESIGN.md §12) ----
        self.fault_plan = fault_plan
        self._inject_deltas = (fault_plan is not None
                               and fault_plan.injects_deltas)
        self._guard = None
        if exec_cfg.guard:
            from repro.core.guards import GuardConfig, UpdateGuard
            self._guard = UpdateGuard(GuardConfig(
                quarantine_mult=exec_cfg.guard_quarantine_mult,
                clip_mult=exec_cfg.guard_clip_mult,
                window=exec_cfg.guard_window,
                min_history=exec_cfg.guard_min_history))
        # ---- delta codec (repro.codec, DESIGN.md §13) ----
        # ExecConfig overrides (regime enrollment) defer to AlgoConfig,
        # the knob's primary home
        from repro.codec import make_codec, tree_nbytes
        codec_name = (exec_cfg.codec if exec_cfg.codec is not None
                      else algo_cfg.codec)
        want_ef = (exec_cfg.codec_ef if exec_cfg.codec_ef is not None
                   else algo_cfg.codec_ef)
        self._codec = make_codec(codec_name)
        self._codec_lossy = bool(self._codec is not None
                                 and self._codec.lossy)
        if want_ef and not self._codec_lossy:
            raise ValueError(
                "codec_ef=True needs a LOSSY codec (bf16/int8 family): "
                f"codec={codec_name!r} has no quantization residual to "
                "feed back")
        self._codec_ef = bool(self._codec_lossy and want_ef)
        self._codec_stochastic = bool(self._codec_lossy
                                      and self._codec.stochastic)
        self._codec_key = (jax.random.PRNGKey(exec_cfg.seed)
                           if self._codec_stochastic else None)
        # server-side error-feedback accumulator: params-shaped f32,
        # carried in TrainerState (checkpointed; bitwise on resume)
        self._ef = (jax.tree.map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), self.params)
            if self._codec_ef else None)
        # per-client uplink bytes (host-side static accounting for
        # RoundRecord.comm_bytes_up): the codec's wire bytes, or the raw
        # full-precision tree without one
        self._client_bytes_up = (
            self._codec.client_bytes(self.params)
            if self._codec is not None else tree_nbytes(self.params))
        # ---- server-side optimizer (repro.optim.server, DESIGN.md §14)
        # resolved like the codec: ExecConfig override defers to
        # AlgoConfig; sgd/None resolve to NO optimizer object, keeping
        # the jit signature byte-identical to the pre-layer round
        from repro.optim.server import make_server_optimizer
        self._server_opt = make_server_optimizer(
            exec_cfg.server_opt if exec_cfg.server_opt is not None
            else algo_cfg.server_opt)
        self._opt_state = (self._server_opt.init(self.params)
                           if self._server_opt is not None else None)
        self._opt_shardings = None
        # ---- run-health monitor (repro.health, DESIGN.md §14) ----
        self._health = None
        if ((exec_cfg.health_log or health_sink is not None)
                and not exec_cfg.health):
            raise ValueError(
                "health_log / health_sink stream the run-health "
                "monitor's verdicts — set ExecConfig(health=True) too")
        if exec_cfg.health_log and health_sink is not None:
            raise ValueError("pass either ExecConfig.health_log or "
                             "health_sink=..., not both")
        if exec_cfg.health:
            from repro.health.monitor import (HealthConfig, HealthMonitor,
                                              JsonlHealthSink)
            sink = health_sink
            if sink is None and exec_cfg.health_log:
                # one tracker file per RUN: process 0 only — every
                # process observes identical replicated records, so the
                # non-writers' monitors run sink-less
                if not self._mp or jax.process_index() == 0:
                    sink = JsonlHealthSink(exec_cfg.health_log)
            self._health = HealthMonitor(HealthConfig(
                window=exec_cfg.health_window,
                min_history=exec_cfg.health_min_history,
                spike_mult=exec_cfg.health_spike_mult,
                patience=exec_cfg.health_patience,
                clients_per_round=exec_cfg.clients_per_round), sink=sink)
        # sync engines mask timed-out clients out of the round; the async
        # engine instead stops collecting arrivals at the deadline (the
        # partial-buffer fold), so only the sync paths take the mask input
        self._deadline_mask = (exec_cfg.round_deadline is not None
                               and not exec_cfg.async_buffer)
        self.mesh = (self._build_mesh()
                     if exec_cfg.shard_clients or exec_cfg.shard_model > 1
                     else None)
        # uneven cohorts on the sharded path: pad K up to the next multiple
        # of the CLIENT axis (on a two-axis mesh the model axis does not
        # count) with masked dummy clients (DESIGN.md §2)
        k = exec_cfg.clients_per_round
        ndev = 1 if self.mesh is None else int(self.mesh.devices.shape[0])
        self._pad_to = -(-k // ndev) * ndev
        # ---- hierarchical edge aggregation (DESIGN.md §15) ----
        self._edges = (int(exec_cfg.edges)
                       if (exec_cfg.edges or 0) > 1 else None)
        if self._edges is not None:
            if exec_cfg.async_buffer:
                raise ValueError(
                    "edges reshapes the synchronous server fold; it "
                    "cannot combine with async_buffer")
            if self._pad_to % self._edges:
                raise ValueError(
                    f"edges={self._edges} must divide the padded cohort "
                    f"size {self._pad_to} (clients_per_round="
                    f"{k} padded to the client axis)")
        # bytes of ONE edge's partial summary on the edge->server uplink:
        # a raw-f32 params-shaped aggregate (comm accounting for
        # RoundRecord.comm_bytes_server_up)
        self._summary_bytes_up = int(sum(
            int(np.prod(np.shape(leaf))) * 4
            for leaf in jax.tree_util.tree_leaves(self.params)))
        # process-loss faults surface as lost EDGE summaries: the server
        # folds the surviving E-1 through the SAME live-mask input the
        # round-deadline path compiles (core/faults.EdgeDrop)
        self._edge_faults = (fault_plan is not None
                             and fault_plan.injects_edges
                             and self._edges is not None)
        self._live_mask_input = self._deadline_mask or self._edge_faults
        # cohort shardings are built ONCE and shared by the round's jit,
        # the initial placement, and restore()'s re-placement
        self._round_shardings = None
        if self.mesh is not None:
            from repro.sharding.rules import cohort_round_shardings
            self._round_shardings = cohort_round_shardings(
                self.mesh, params=self.params,
                server_state=self.server_state)
        # fused path: local training + server step, one program per round.
        # real_clients carries the pad count so a zero-data client (all
        # minibatches masked) still counts as SAMPLED — the legacy
        # masks.any() fallback would reclassify it as padding.
        # The chaos extras (fault codes / live mask / guard threshold +
        # guard stats, DESIGN.md §12) extend the jit signature; the BASE
        # 5-in/4-out sharding pair stays untouched because _placements()
        # and the ingest placer unpack it by position.
        round_shardings = self._round_shardings
        if round_shardings is not None and self._mp:
            # multi-process: the (K,) losses leave the program REPLICATED
            # (a tiny gloo allgather) so every host reads them without an
            # eager cross-process op; inputs keep the base layout
            from jax.sharding import NamedSharding, PartitionSpec as P
            outs_mp = list(round_shardings[1])
            outs_mp[2] = NamedSharding(self.mesh, P())
            round_shardings = (round_shardings[0], tuple(outs_mp))
        if round_shardings is not None and (
                self._inject_deltas or self._live_mask_input or self._guard
                or self._codec_stochastic or self._codec_ef
                or self._server_opt is not None):
            from jax.sharding import NamedSharding, PartitionSpec as P
            rep = NamedSharding(self.mesh, P())
            cli = NamedSharding(self.mesh, P("clients"))
            ins = list(round_shardings[0])
            outs = list(round_shardings[1])
            if self._inject_deltas:
                ins.append(cli)              # fault_codes (K,)
            if self._live_mask_input:
                ins.append(cli)              # live_mask (K,)
            if self._guard is not None:
                ins.append(rep)              # guard_thresh scalar
                # guard stats come back to the host on every process in a
                # multi-process run: replicate them there
                outs.append(rep if self._mp else cli)
            if self._codec_stochastic:
                ins.append(rep)              # per-round PRNG key
            if self._codec_ef:
                # the EF accumulator mirrors the params tree, so it
                # takes the params' shardings (per-leaf on a two-axis
                # mesh) on the way in AND out
                ins.append(ins[1])
                outs.append(ins[1])
            if self._server_opt is not None:
                # moment state is {"m","v"} params mirrors: on a two-axis
                # mesh the §8 path rules ("m/w1" matches "w1") give every
                # moment leaf the spec of its param leaf (co-location,
                # DESIGN.md §14); on a 1-D client mesh the params prefix
                # sharding (replicated) covers the whole dict
                axis_sizes = dict(zip(self.mesh.axis_names,
                                      self.mesh.devices.shape))
                if axis_sizes.get("model", 1) > 1:
                    from repro.sharding.rules import (cohort_state_specs,
                                                      to_named)
                    o_sh = to_named(cohort_state_specs(
                        self._opt_state, self.params, self.mesh), self.mesh)
                else:
                    o_sh = {"m": ins[1], "v": ins[1]}
                self._opt_shardings = o_sh
                ins.append(o_sh)             # opt state LAST in and out
                outs.append(o_sh)
            round_shardings = (tuple(ins), tuple(outs))
        self._cohort_round = round_mod.make_cohort_round(
            loss_fn, self.algo, algo_cfg.eta_l, algo_cfg.eta_g,
            optimizer=algo_cfg.local_optimizer, mesh=self.mesh,
            pad_clients=self._pad_to > k,
            real_clients=k if self._pad_to > k else None,
            shardings=round_shardings,
            guard=self._guard is not None,
            guard_cfg=None if self._guard is None else self._guard.config,
            inject_faults=self._inject_deltas,
            deadline_mask=self._live_mask_input,
            fault_magnitude=(fault_plan.explode_magnitude
                             if fault_plan is not None else 1e12),
            codec=self._codec, codec_ef=self._codec_ef,
            server_opt=self._server_opt, edges=self._edges)
        if self.mesh is not None:
            # pre-place so the first round's donation matches: replicated
            # on the 1-D client mesh, per-leaf model-sharded on a
            # two-axis mesh. A process-spanning mesh is never fully
            # addressable, so placement routes through put_global (per-
            # host shard assembly) instead of a plain device_put.
            p_sh, s_sh = self._placements()
            if self._mp:
                self.params = self._put_replicated(self.params, p_sh)
                self.server_state = self._put_replicated(
                    self.server_state, s_sh)
                if self._ef is not None:
                    self._ef = self._put_replicated(self._ef, p_sh)
                if self._opt_state is not None:
                    self._opt_state = self._put_replicated(
                        self._opt_state, p_sh)
            else:
                self.params = jax.device_put(self.params, p_sh)
                self.server_state = jax.device_put(self.server_state, s_sh)
                if self._ef is not None:
                    self._ef = jax.device_put(self._ef, p_sh)
                if self._opt_state is not None:
                    self._opt_state = jax.device_put(self._opt_state,
                                                     self._opt_shardings)
        # serial reference path (exec.vectorize=False): per-client dispatch
        from repro.core.baselines import client_kwargs
        self.local_update = client_mod.make_local_update(
            loss_fn, algo_cfg.eta_l, variant=self.algo.client_variant,
            optimizer=algo_cfg.local_optimizer, **client_kwargs(self.algo))
        # cm=None is the historical unmasked path; the serial chaos path
        # passes the live/quarantine fold exactly like the fused round
        self._server_step = jax.jit(
            lambda st, p, d, ids, cm: self.algo.step(
                st, p, d, ids, algo_cfg.eta_g, 0, client_mask=cm,
                edges=self._edges))
        # serial variant with the server optimizer fused in: same step,
        # then the optimizer re-steps from the round's incoming params
        # with moment-preconditioned magnitudes (DESIGN.md §14)
        self._server_step_opt = None
        if self._server_opt is not None:
            sopt = self._server_opt

            def _step_opt(st, p, d, ids, cm, opt):
                new_p, new_st, diag = self.algo.step(
                    st, p, d, ids, algo_cfg.eta_g, 0, client_mask=cm,
                    edges=self._edges)
                new_p, new_opt = sopt.apply(p, new_p, opt)
                return new_p, new_st, diag, new_opt

            self._server_step_opt = jax.jit(_step_opt)
        self.rng = np.random.RandomState(exec_cfg.seed)
        self.history: List[RoundRecord] = []
        self.schedule: List[np.ndarray] = []     # sampled cohort per round
        # staged ingest pipeline (DESIGN.md §10): read -> decode/augment
        # -> cohort-stack -> device-place, with a depth-N staging ring;
        # placement targets the round's ACTUAL input sharding (the same
        # NamedSharding its jit was built with), so dispatch finds every
        # input already resident
        input_sh = (self._round_shardings[0][2]
                    if self._round_shardings is not None else None)
        # multi-process ingest (DESIGN.md §15): each host reads/decodes/
        # stacks ONLY its local slice of the padded cohort (the rows its
        # devices own under the clients sharding) and the placer
        # assembles the global arrays from the per-host shards — no
        # client batch crosses a host boundary host-side
        local_rows = None
        sync_mb = None
        if self._mp:
            from repro.launch import distributed as dist_mod
            from repro.sharding.rules import local_row_range
            local_rows = local_row_range(input_sh, self._pad_to)
            seq = self._mp_seq
            sync_mb = (lambda tag, m:
                       dist_mod.kv_allmax(f"t{seq}/maxb/{tag}", m))
        self._pipeline = CohortIngestPipeline(
            self.source, self._sample_clients,
            num_clients=num_clients,
            # the async engine dispatches a DYNAMIC number of waves
            # (dropout re-draws, buffer_size != K): open horizon, the
            # ring backpressures on depth
            rounds=None if exec_cfg.async_buffer else exec_cfg.rounds,
            depth=exec_cfg.prefetch_depth,
            device_stage=exec_cfg.device_stage,
            placer=CohortPlacer(
                input_sh, local_rows=local_rows,
                global_rows=self._pad_to if local_rows else None),
            pad_to=self._pad_to,
            local_rows=local_rows, sync_max_batches=sync_mb,
            stall_timeout=exec_cfg.ingest_stall_s,
            max_restarts=exec_cfg.ingest_max_restarts,
            restart_backoff=exec_cfg.ingest_restart_backoff_s,
            crash_hook=(fault_plan.ingest_crash
                        if fault_plan is not None else None))
        # buffered-async engine (DESIGN.md §11): owns the virtual-time
        # wave heap; the runtime model's draws ride the sampling lock.
        # A round deadline WITHOUT async_buffer also needs a runtime
        # model (latencies decide who times out) — default deterministic
        self._runtime = None
        self._engine = None
        self._wave_runtime: Dict[int, tuple] = {}
        if exec_cfg.async_buffer or exec_cfg.round_deadline is not None:
            from repro.core.runtime import DeterministicRuntime
            self._runtime = (runtime if runtime is not None
                             else DeterministicRuntime())
        if exec_cfg.async_buffer:
            self._engine = self._build_async_engine(loss_fn, algo_cfg,
                                                    exec_cfg)
        self._start_round = 0                    # advanced by restore()
        self._pending_eval = None                # (RoundRecord, Future)
        self._async_eval = eval_fn is not None and exec_cfg.async_eval
        # sampling-time snapshots for save(): the prefetcher draws the RNG
        # ahead of consumed rounds, so each round's pre-draw state is
        # captured under this lock (see save())
        self._sample_lock = threading.Lock()
        self._round_caps: Dict[int, dict] = {}

    # ---- internals ----

    @property
    def _max_batches(self) -> Optional[int]:
        """Grow-once M shape bucket — owned by the ingest pipeline,
        surfaced here because it is checkpointed TrainerState."""
        return self._pipeline.max_batches

    @_max_batches.setter
    def _max_batches(self, value: Optional[int]):
        self._pipeline.max_batches = value

    @property
    def _prefetcher(self):
        """The pipeline's staging ring (None until the first prefetched
        round) — read-only; lifecycle belongs to the pipeline."""
        return self._pipeline._ring

    def _build_mesh(self):
        from repro.launch import mesh as mesh_mod
        return mesh_mod.make_cohort_mesh(model=self.cfg.shard_model)

    def _build_async_engine(self, loss_fn, algo_cfg, exec_cfg):
        """Wire the buffered-async engine: the round splits at the
        arrival buffer into a jit'd WAVE update (local training against
        the dispatch-time snapshot) and a jit'd staleness-weighted FOLD
        (the server step over the buffered deltas). A staleness-aware
        rule (FedDPC family) takes the discounts as its own reduction-
        pass scalars; any other rule gets the buffered deltas pre-scaled
        (FedBuff mean semantics)."""
        from repro.codec import base as codec_base
        from repro.core import projection as proj
        from repro.core.async_engine import BufferedAsyncEngine
        from repro.core.baselines import client_kwargs
        local = client_mod.make_cohort_local_update(
            loss_fn, algo_cfg.eta_l, variant=self.algo.client_variant,
            optimizer=algo_cfg.local_optimizer, **client_kwargs(self.algo))
        algo, eta_g = self.algo, algo_cfg.eta_g
        model_sharded = bool(
            self.mesh is not None and "model" in self.mesh.axis_names
            and dict(zip(self.mesh.axis_names,
                         self.mesh.devices.shape))["model"] > 1)
        # codec stage (repro.codec, DESIGN.md §13): the wave ENCODES its
        # cohort before the entries hit the arrival heap — in-flight and
        # checkpointed entries carry the quantized wire payload, and the
        # fold decodes (staleness weights compose with the dequant
        # scales: both are per-arrival multipliers on the reduction-pass
        # scalars). EF updates at encode time, in dispatch order.
        codec_obj = self._codec if self._codec_lossy else None
        codec_stoch = self._codec_stochastic
        codec_ef = self._codec_ef
        k_real = exec_cfg.clients_per_round

        def wave_update(params, server_state, batches, masks, *codec_in):
            it = iter(codec_in)
            key = next(it) if codec_stoch else None
            ef = next(it) if codec_ef else None
            extra = algo.client_extra(server_state)
            deltas, losses = local(params, batches, masks, extra)
            if codec_obj is None:
                return deltas, losses
            shipped = deltas
            if ef is not None:
                shipped = jax.tree.map(
                    lambda d, e: (d.astype(jnp.float32)
                                  + e.astype(jnp.float32)[None]
                                  ).astype(d.dtype), deltas, ef)
            payload = codec_obj.encode_cohort(shipped, key=key)
            if ef is None:
                return payload, losses
            dec = codec_obj.decode_cohort(payload)
            resid = codec_base.sanitized_residual(shipped, dec)
            # padded dummy rows (sharded path) ship nothing: mask them
            # out of the accumulator mean
            cmw = jnp.arange(losses.shape[0]) < k_real
            new_ef = proj.masked_client_mean(resid, cmw)
            return payload, losses, new_ef

        inject = self._inject_deltas
        guard = self._guard is not None
        guard_cfg = None if self._guard is None else self._guard.config
        magnitude = (self.fault_plan.explode_magnitude
                     if self.fault_plan is not None else 1e12)
        sopt = self._server_opt

        def fold(server_state, params, deltas, ids, weights, *chaos):
            # the buffered arrivals carry the codec wire payload: decode
            # FIRST, then the chaos extras (DESIGN.md §12) in the same
            # fixed order as the fused sync round: fault codes re-derived
            # per ARRIVAL (so checkpointed in-flight entries stay clean
            # and resume bitwise), then the guard threshold — both
            # operate on the decoded (quantized-domain) values, exactly
            # like the sync round's guard
            chaos = list(chaos)
            # server-optimizer state rides LAST (the same fixed-order
            # convention as the fused sync round, DESIGN.md §14)
            opt_state = chaos.pop() if sopt is not None else None
            encoded = None
            if codec_obj is not None:
                encoded = deltas
                deltas = codec_obj.decode_cohort(deltas)
            it = iter(chaos)
            if inject:
                deltas = round_mod.apply_fault_codes(deltas, next(it),
                                                     magnitude)
                encoded = None       # payload no longer matches the rows
            cm = gstats = None
            if guard:
                deltas, ids, cm, gstats = round_mod.apply_guard(
                    deltas, ids, cm, next(it), guard_cfg)
                encoded = None
            if algo.staleness_aware:
                out = algo.step(server_state, params, deltas, ids, eta_g,
                                0, client_mask=cm,
                                model_sharded=model_sharded,
                                staleness_weights=weights,
                                encoded=encoded)
            else:
                pre = jax.tree.map(
                    lambda x: weights.reshape((-1,) + (1,) * (x.ndim - 1))
                    * x.astype(jnp.float32), deltas)
                out = algo.step(server_state, params, pre, ids, eta_g, 0,
                                client_mask=cm, model_sharded=model_sharded)
            new_opt = None
            if sopt is not None:
                # precondition the POST-projection aggregate: re-step
                # from this fold's incoming params (DESIGN.md §14)
                new_p, new_opt = sopt.apply(params, out[0], opt_state)
                out = (new_p,) + tuple(out[1:])
            res = out + (gstats,) if guard else out
            if sopt is not None:
                res = tuple(res) + (new_opt,)
            return res

        fold_extras = None
        if inject or guard:
            def fold_extras(entries):
                out = []
                if inject:
                    # per-(kind, wave) streams are prefix-stable in the
                    # client id, so per-entry derivation equals the
                    # whole-cohort call made by the sync engines
                    out.append(jnp.asarray(np.asarray(
                        [self.fault_plan.delta_codes(
                            e.wave, np.asarray([e.client]))[0]
                         for e in entries], np.int32)))
                if guard:
                    out.append(jnp.float32(self._guard.threshold()))
                return tuple(out)

        wave_kw: Dict[str, Any] = {}
        # NO donation on the wave: params/server_state survive for the
        # next wave of the same server round; the fold donates both
        fold_kw: Dict[str, Any] = {"donate_argnums": (0, 1)}
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.sharding.rules import async_round_shardings
            w_in, w_out, f_in, f_out = async_round_shardings(
                self.mesh, params=self.params,
                server_state=self.server_state,
                codec_payload=codec_obj is not None)
            rep = NamedSharding(self.mesh, P())
            # codec extras on the wave: the PRNG key replicates, the EF
            # accumulator mirrors the params tree (w_in[0]) in and out
            if codec_stoch:
                w_in = w_in + (rep,)
            if codec_ef:
                w_in = w_in + (w_in[0],)
                w_out = w_out + (w_in[0],)
            # chaos extras are tiny (B,) / scalar host-built arrays and
            # the guard stats come straight back to the host: replicate
            f_in = f_in + (rep,) * (int(inject) + int(guard))
            if guard:
                f_out = f_out + (rep,)
            if sopt is not None:
                # moment state co-locates with the params on the fold
                # side too (same shardings the fused round was built
                # with — full param-mirror shapes on every mesh)
                f_in = f_in + (self._opt_shardings,)
                f_out = f_out + (self._opt_shardings,)
            wave_kw.update(in_shardings=w_in, out_shardings=w_out)
            fold_kw.update(in_shardings=f_in, out_shardings=f_out)
        jit_wave = jax.jit(wave_update, **wave_kw)
        wave_call = jit_wave
        if codec_stoch or codec_ef:
            # python wrapper around the jit: feeds the per-wave PRNG key
            # and the CURRENT EF accumulator, and commits the new one —
            # EF advances in dispatch order (deterministic; the engine
            # dispatches waves in wave_frontier order), so save/resume
            # replays it bitwise
            def wave_call(params, server_state, batches, masks):
                args = [params, server_state, batches, masks]
                if codec_stoch:
                    args.append(jax.random.fold_in(
                        self._codec_key, self._engine.wave_frontier))
                if codec_ef:
                    args.append(self._ef)
                out = jit_wave(*args)
                if codec_ef:
                    payload, losses, self._ef = out
                    return payload, losses
                return out
        jit_fold = jax.jit(fold, **fold_kw)
        fold_call = jit_fold
        if sopt is not None:
            # python wrapper: feeds the CURRENT moment state and commits
            # the new one — the optimizer only advances at folds (server
            # rounds), so a mid-buffer checkpoint carries exactly the
            # round-boundary state and resumes bitwise
            def fold_call(server_state, params, deltas, ids, weights,
                          *extras):
                out = list(jit_fold(server_state, params, deltas, ids,
                                    weights, *extras, self._opt_state))
                self._opt_state = out.pop()
                return tuple(out)
        return BufferedAsyncEngine(
            pipeline=self._pipeline,
            wave_update=wave_call,
            fold=fold_call,
            runtime_take=self._runtime_take,
            buffer_size=(exec_cfg.buffer_size
                         or exec_cfg.clients_per_round),
            alpha=exec_cfg.staleness_alpha,
            concurrency=exec_cfg.async_concurrency,
            prefetch=exec_cfg.prefetch,
            deadline=exec_cfg.round_deadline,
            fold_extras=fold_extras,
            fold_returns_stats=guard)

    def _runtime_take(self, wave: int):
        """Hand the engine the (latencies, dropped) pair captured for
        this wave at sampling time (round-order RNG contract)."""
        with self._sample_lock:
            return self._wave_runtime.pop(wave)

    def _placements(self):
        """(params, server_state) shardings on the trainer's mesh —
        replicated on a 1-D client mesh, per-leaf model-sharded on a
        two-axis mesh; read from the pair built once at construction
        (the same trees the round's jit donates against)."""
        (s_sh, p_sh, _, _, _), _ = self._round_shardings
        return p_sh, s_sh

    def _put_replicated(self, tree, sh):
        """Place a host tree with a (replicated) sharding on a process-
        spanning mesh, where plain device_put is illegal — every host
        holds the full value (ingest/placement.put_global)."""
        from repro.ingest.placement import put_global
        return jax.tree.map(lambda x: put_global(x, sh), tree)

    def _comm_fields(self, shipped_count: int,
                     live_edges: Optional[int] = None) -> Dict[str, int]:
        """Uplink accounting split by round shape (DESIGN.md §15): flat
        rounds pay everything on the server uplink; hierarchical rounds
        pay the per-client deltas to the edges and one raw-f32 summary
        per LIVE edge to the server."""
        up = self._client_bytes_up * int(shipped_count)
        if self._edges is None:
            return {"comm_bytes_up": up, "comm_bytes_edge_up": 0,
                    "comm_bytes_server_up": up}
        e = self._edges if live_edges is None else int(live_edges)
        return {"comm_bytes_up": up, "comm_bytes_edge_up": up,
                "comm_bytes_server_up": e * self._summary_bytes_up}

    def _entry_delta_template(self) -> PyTree:
        """ShapeDtypeStruct tree of ONE async BufferEntry's delta: the
        raw params tree without a codec, the codec's single-client wire
        payload with one — the async checkpoint arrays stack its leaves
        per entry with exact dtypes (int8 q codes stay int8 on disk)."""
        if self._codec_lossy:
            stacked = self._codec.encoded_template(self.params, 1)
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(tuple(s.shape)[1:], s.dtype),
                stacked)
        return self.params

    def _sample_clients(self, t: int) -> np.ndarray:
        with self._sample_lock:
            self._round_caps[t] = {
                "rng": self.rng.get_state(),
                "sampler": self.sampler.state_dict(),
                "max_batches": self._max_batches,
                **({"runtime": self._runtime.state_dict()}
                   if self._runtime is not None else {}),
            }
            # retention must cover the staging look-ahead: the producer
            # samples up to prefetch_depth rounds past the consumed
            # frontier, and state() needs the pre-draw capture of the
            # NEXT UNCONSUMED round — keep depth + 2 rounds of slack so
            # a depth-N ring never evicts a capture save() will ask for
            horizon = t - (self.cfg.prefetch_depth + 2)
            for old in [r for r in self._round_caps if r < horizon]:
                del self._round_caps[old]
            clients = np.asarray(self.sampler.sample(self.rng, t))
            k = self.cfg.clients_per_round
            if clients.shape != (k,):
                raise ValueError(
                    f"sampler returned shape {clients.shape}; the fused "
                    f"round needs exactly clients_per_round={k} ids")
            if clients.min() < 0 or clients.max() >= self.num_clients:
                raise ValueError(f"sampler returned out-of-range ids "
                                 f"(num_clients={self.num_clients})")
            if len(np.unique(clients)) != k:
                # duplicates would double-count a delta in every mean and
                # desync FedVARP's table scatter from its correction term
                raise ValueError(f"sampler returned duplicate client ids: "
                                 f"{clients.tolist()}")
            self.schedule.append(clients)
            if self._runtime is not None:
                # runtime draws ride the SAME lock, right after the
                # sampler's, so wave t always consumes sampler-then-
                # runtime in wave order — prefetched waves replay
                # bitwise on resume (round-order RNG contract)
                lat, dropped = self._runtime.draw(self.rng, t, clients)
                lat = np.asarray(lat, np.float64)
                if self.fault_plan is not None:
                    # hang injection (core/faults.py): stateless, derived
                    # AFTER the runtime draw, so the RNG stream is the
                    # no-faults stream and the boost replays on resume
                    lat = lat + self.fault_plan.latency_boost(t, clients)
                self._wave_runtime[t] = (lat, np.asarray(dropped, bool))
        return clients

    def _round_batches(self, clients: Sequence[int], t: int):
        lists = self._pipeline.client_lists(clients, t)
        return [stack_batches(b, self._max_batches) for b in lists]

    def _run_round_vectorized(self, t: int):
        staged = (self._pipeline.get(t) if self.cfg.prefetch
                  else self._pipeline.stage_blocking(t))
        chaos = (self._inject_deltas or self._live_mask_input
                 or self._guard is not None or self._codec_stochastic
                 or self._codec_ef or self._server_opt is not None)
        try:
            if not chaos:
                self.params, self.server_state, losses, diag = \
                    self._cohort_round(
                        self.server_state, self.params, staged.batches,
                        staged.masks, staged.ids)
                # syncs on the round's result: after this the device is
                # done with the inputs and the staging slot is reusable;
                # dummy padded clients sit past the real K and report
                # loss 0. Multi-process: losses left the program
                # replicated, so the host read works on every process.
                n = len(staged.clients)
                train_loss = float(np.asarray(losses)[:n].mean())
                return (train_loss, diag, staged.host_seconds,
                        staged.device_seconds, self._comm_fields(n))
            # ---- chaos-hardened / codec-extra round (DESIGN.md §12,
            # §13): same program, extended by the fixed-order extras ----
            n = len(staged.clients)
            kp = int(np.shape(staged.ids)[0])        # padded cohort size
            args = [self.server_state, self.params, staged.batches,
                    staged.masks, staged.ids]
            extra: Dict[str, Any] = {}
            live = np.ones(n, bool)
            shipped = np.ones(n, bool)
            if self._inject_deltas:
                codes = np.zeros(kp, np.int32)
                codes[:n] = self.fault_plan.delta_codes(t, staged.clients)
                args.append(jnp.asarray(codes))
            edge_down = 0
            if self._live_mask_input:
                lv = np.zeros(kp, bool)
                lv[:n] = True
                if self._deadline_mask:
                    lat, dropped = self._runtime_take(t)
                    live = (~dropped) & (lat <= self.cfg.round_deadline)
                    # runtime dropouts never produced an update;
                    # deadline-late clients DID ship one — it just
                    # arrived too late for the fold (uplink accounting
                    # below)
                    shipped = ~dropped
                    lv[:n] = live
                    extra["deadline_dropped"] = int((~live).sum())
                    extra["deadline_fired"] = int((~live).any())
                if self._edge_faults:
                    # a lost process drops its whole edge's summary: mask
                    # every row of the dropped edges so the server folds
                    # the surviving E-1 partials (clients still SHIPPED
                    # to their edge — only the edge->server hop is lost)
                    edrop = self.fault_plan.edge_drops(t, self._edges)
                    edge_live = np.repeat(~edrop, kp // self._edges)
                    lv &= edge_live
                    live = live & edge_live[:n]
                    edge_down = int(edrop.sum())
                    extra["edge_dropped"] = edge_down
                args.append(jnp.asarray(lv))
            if self._guard is not None:
                args.append(jnp.float32(self._guard.threshold()))
            if self._codec_stochastic:
                args.append(jax.random.fold_in(self._codec_key, t))
            if self._codec_ef:
                args.append(self._ef)
            if self._server_opt is not None:
                args.append(self._opt_state)
            outs = list(self._cohort_round(*args))
            if self._server_opt is not None:
                self._opt_state = outs.pop()
            if self._codec_ef:
                self._ef = outs.pop()
            if self._guard is not None:
                gstats = outs.pop()
            self.params, self.server_state, losses, diag = outs
            if self._guard is not None:
                q = np.asarray(gstats["quarantined"])[:n]
                c = np.asarray(gstats["clipped"])[:n]
                norms = np.asarray(gstats["norm"])[:n]
                # deadline-dropped rows are already counted as dropped;
                # a row that is both dropped and bad counts once
                extra["quarantined"] = int((q & live).sum())
                extra["clipped"] = int((c & live).sum())
                self._guard.observe(norms[live & ~q],
                                    quarantined=extra["quarantined"],
                                    clipped=extra["clipped"])
            # uplink accounting: bytes are counted when a delta is
            # SHIPPED, regardless of whether the fold uses it — a
            # deadline-dropped client still paid its uplink; a runtime
            # dropout never sent anything; a dropped EDGE loses its
            # server-uplink summary (live edges only pay that hop)
            extra.update(self._comm_fields(
                int(shipped.sum()),
                live_edges=(None if self._edges is None
                            else self._edges - edge_down)))
            # train loss over clients whose update ARRIVED (live rows) —
            # identical to the historical mean when nothing timed out
            losses_h = np.asarray(losses)[:n]
            train_loss = (float(losses_h[live].mean()) if live.any()
                          else 0.0)
        finally:
            # released on error too — leaking the slot would deadlock the
            # NEXT run_round inside the staging ring instead of erroring
            staged.release()
        return (train_loss, diag, staged.host_seconds,
                staged.device_seconds, extra)

    def _run_round_serial(self, t: int):
        clients = self._sample_clients(t)
        tic = time.perf_counter()
        round_batches = self._round_batches(clients, t)
        ingest = time.perf_counter() - tic
        extra = self.algo.client_extra(self.server_state)
        deltas, losses = [], []
        for (batches, mask) in round_batches:
            delta, loss = self.local_update(self.params, batches, mask, extra)
            deltas.append(delta)
            losses.append(float(loss))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        ids = jnp.asarray(clients, jnp.int32)
        # chaos hardening (DESIGN.md §12) on the reference path: the SAME
        # transforms the fused round applies, run eagerly on the host-
        # stacked deltas — injection, deadline fold, guard — so the
        # chaos regimes stay cross-checkable against this path too
        out: Dict[str, Any] = {}
        cm = None
        n = len(clients)
        live = np.ones(n, bool)
        shipped_mask = np.ones(n, bool)
        if self._inject_deltas:
            codes = self.fault_plan.delta_codes(t, clients)
            stacked = round_mod.apply_fault_codes(
                stacked, jnp.asarray(codes),
                self.fault_plan.explode_magnitude)
        # codec stage (repro.codec, DESIGN.md §13), eager on the host-
        # stacked deltas — the SAME encode -> decode -> guard -> EF order
        # the fused round compiles, so codec regimes stay cross-checkable
        # against this path
        shipped = decoded = None
        if self._codec_lossy:
            shipped = stacked
            if self._codec_ef:
                shipped = jax.tree.map(
                    lambda d, e: (d.astype(jnp.float32)
                                  + e.astype(jnp.float32)[None]
                                  ).astype(d.dtype), stacked, self._ef)
            key = (jax.random.fold_in(self._codec_key, t)
                   if self._codec_stochastic else None)
            payload = self._codec.encode_cohort(shipped, key=key)
            decoded = self._codec.decode_cohort(payload)
            stacked = decoded
        if self._deadline_mask:
            lat, dropped = self._runtime_take(t)
            live = (~dropped) & (lat <= self.cfg.round_deadline)
            # see _run_round_vectorized: late clients shipped, dropouts
            # never did
            shipped_mask = ~dropped
            lv = jnp.asarray(live)
            ids = jnp.where(lv, ids, round_mod.ID_SENTINEL)
            cm = lv
            out["deadline_dropped"] = int((~live).sum())
            out["deadline_fired"] = int((~live).any())
        edge_down = 0
        if self._edge_faults:
            # same edge-loss fold as the fused path: the serial stack
            # holds exactly K rows (edges | K validated at construction
            # for the no-mesh serial path)
            edrop = self.fault_plan.edge_drops(t, self._edges)
            elive = np.repeat(~edrop, n // self._edges)
            live = live & elive
            lv = jnp.asarray(live)
            ids = jnp.where(lv, ids, round_mod.ID_SENTINEL)
            cm = lv
            edge_down = int(edrop.sum())
            out["edge_dropped"] = edge_down
        if self._guard is not None:
            stacked, ids, cm, gstats = round_mod.apply_guard(
                stacked, ids, cm, self._guard.threshold(),
                self._guard.config)
            q = np.asarray(gstats["quarantined"])
            c = np.asarray(gstats["clipped"])
            norms = np.asarray(gstats["norm"])
            out["quarantined"] = int((q & live).sum())
            out["clipped"] = int((c & live).sum())
            self._guard.observe(norms[live & ~q],
                                quarantined=out["quarantined"],
                                clipped=out["clipped"])
        if self._codec_ef:
            # pre-guard decode residual, nonfinite-sanitized, mean over
            # the surviving clients (mirrors the fused round)
            from repro.codec import base as codec_base
            from repro.core import projection as proj
            resid = codec_base.sanitized_residual(shipped, decoded)
            self._ef = proj.masked_client_mean(resid, cm)
        if self._server_opt is not None:
            (self.params, self.server_state, diag,
             self._opt_state) = self._server_step_opt(
                self.server_state, self.params, stacked, ids, cm,
                self._opt_state)
        else:
            self.params, self.server_state, diag = self._server_step(
                self.server_state, self.params, stacked, ids, cm)
        # bytes are counted when a delta is shipped, regardless of
        # whether the fold uses it (matches the fused path)
        out.update(self._comm_fields(
            int(shipped_mask.sum()),
            live_edges=(None if self._edges is None
                        else self._edges - edge_down)))
        losses_h = np.asarray(losses)
        train_loss = float(losses_h[live].mean()) if live.any() else 0.0
        return train_loss, diag, ingest, 0.0, out

    def _run_round_async(self, t: int):
        """One buffered-async server step: the engine collects the next
        buffer_size arrivals (dispatching waves as concurrency allows,
        stopping at the round deadline) and folds them with their
        staleness discounts."""
        self.params, self.server_state, m = self._engine.run_server_round(
            t, self.params, self.server_state)
        extra = {"staleness_mean": m["staleness_mean"],
                 "staleness_max": m["staleness_max"],
                 # uplink accounting: updates SHIPPED during this round's
                 # collection — bytes are paid at ship time whether or
                 # not this fold consumed the update (a straggler folds
                 # in a later round without paying again; a runtime
                 # dropout never shipped and never pays). edges is never
                 # set here (validated at construction), so the comm
                 # fields take the flat shape.
                 **self._comm_fields(int(m["n_shipped"]))}
        # ingest-restart attribution: charge the waves whose staging ran
        # during this round's collection (restarts key on the staged
        # wave index, final once the wave was handed out)
        restarts = sum(self._pipeline.restarts_for(w)
                       for w in range(m["wave_start"], m["wave_end"]))
        if restarts:
            extra["ingest_restarts"] = restarts
        if self.cfg.round_deadline is not None:
            extra["deadline_fired"] = int(m["deadline_fired"])
            extra["deadline_dropped"] = int(m["deadline_dropped"])
        if self._guard is not None and m["guard_stats"] is not None:
            nb = int(m["n_arrivals"])
            q = np.asarray(m["guard_stats"]["quarantined"])[:nb]
            c = np.asarray(m["guard_stats"]["clipped"])[:nb]
            norms = np.asarray(m["guard_stats"]["norm"])[:nb]
            extra["quarantined"] = int(q.sum())
            extra["clipped"] = int(c.sum())
            self._guard.observe(norms[~q], quarantined=extra["quarantined"],
                                clipped=extra["clipped"])
        return (m["train_loss"], m["diag"], m["host_seconds"],
                m["device_seconds"], extra)

    def _resolve_pending_eval(self):
        if self._pending_eval is not None:
            rec, fut = self._pending_eval
            self._pending_eval = None
            rec.test_accuracy = float(fut.result())

    # ---- public ----

    def run_round(self, t: int) -> RoundRecord:
        # fold a FINISHED async eval into its record without blocking, so
        # manual run_round loops see accuracies at most one round late
        # (the still-running case resolves at the next eval boundary /
        # finalize()/run() end)
        if self._pending_eval is not None and self._pending_eval[1].done():
            self._resolve_pending_eval()
        tic = time.perf_counter()
        run = (self._run_round_async if self._engine is not None
               else self._run_round_vectorized if self.cfg.vectorize
               else self._run_round_serial)
        train_loss, diag, ingest_host, ingest_dev, extra = run(t)
        # supervised-restart accounting (DESIGN.md §12), attributed to
        # the round whose STAGING crashed — the producer stages ahead,
        # so the old cumulative-counter diff charged a crash during
        # round t+1's staging to round t (whichever round observed it);
        # restarts_for keys on the staged round index instead. The async
        # engine attributes per wave inside _run_round_async.
        if self._engine is None:
            restarts = self._pipeline.restarts_for(t)
            if restarts:
                extra["ingest_restarts"] = restarts
        rec = RoundRecord(
            round=t, train_loss=train_loss,
            seconds=time.perf_counter() - tic,
            ingest_seconds=ingest_host + ingest_dev,
            ingest_host_seconds=ingest_host,
            ingest_device_seconds=ingest_dev,
            diagnostics={k: float(v) for k, v in diag.items()},
            **extra)
        if self.eval_fn and (t % self.cfg.eval_every == 0
                             or t == self.cfg.rounds - 1):
            # previous async eval must land before its boundary passes
            self._resolve_pending_eval()
            if self._async_eval:
                # snapshot: the next round DONATES self.params, so eval
                # runs on a private copy, overlapped with t+1's ingest;
                # the result folds into rec at the next boundary (or
                # finalize()/run() end). One short-lived daemon thread per
                # eval — sweeps build many trainers and a pooled worker
                # per trainer would accumulate idle threads.
                from concurrent.futures import Future
                snap = jax.tree.map(lambda x: jnp.array(x, copy=True),
                                    self.params)
                fut = Future()

                def _eval(fn=self.eval_fn, p=snap, fut=fut):
                    try:
                        fut.set_result(fn(p))
                    except BaseException as e:
                        fut.set_exception(e)

                threading.Thread(target=_eval, daemon=True,
                                 name="fl-eval").start()
                self._pending_eval = (rec, fut)
            else:
                rec.test_accuracy = float(self.eval_fn(self.params))
        self.history.append(rec)
        if self._health is not None:
            # run-health monitor (repro.health, DESIGN.md §14): consumes
            # the record in round order; read the verdict from
            # trainer.health_report (run() honors should_stop)
            self._health.observe(rec)
        return rec

    def finalize(self):
        """Land any in-flight async eval into its RoundRecord."""
        self._resolve_pending_eval()

    def close(self):
        """Release trainer-owned resources (the ingest pipeline's staging
        thread, pending eval future). The data source is CALLER-owned —
        sweeps share one source across trainers — and is never closed
        here."""
        self.finalize()
        self._pipeline.close()
        if self._health is not None:
            self._health.close_sink()

    def __enter__(self) -> "FederatedTrainer":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    def run(self, verbose: bool = False) -> List[RoundRecord]:
        for t in range(self._start_round, self.cfg.rounds):
            rec = self.run_round(t)
            if (self._health is not None
                    and self._health.last_report is not None
                    and self._health.last_report.should_stop):
                # health early stop (DESIGN.md §14): the detector asked
                # for it — land the pending eval and stop cleanly; the
                # report (trainer.health_report) says why
                if verbose:
                    print(f"[{self.algo.name}] round {t:4d} health stop: "
                          f"{self._health.last_report.alarms}")
                break
            if verbose:
                # a human is watching: land this round's async eval now so
                # the accuracy prints with its round (trades the overlap)
                self._resolve_pending_eval()
                acc = ("" if rec.test_accuracy is None
                       else f"  acc={rec.test_accuracy:.4f}")
                print(f"[{self.algo.name}] round {t:4d} "
                      f"loss={rec.train_loss:.4f}{acc}")
        self.finalize()
        return self.history

    @property
    def start_round(self) -> int:
        """First round ``run()`` will execute — 0 for a fresh trainer,
        the checkpointed next round after ``restore()``/``resume()``."""
        return self._start_round

    @property
    def health_report(self):
        """Latest HealthReport from the run-health monitor (repro.health,
        DESIGN.md §14) — None before the first round or when
        ExecConfig.health is off."""
        return None if self._health is None else self._health.last_report

    @property
    def best_accuracy(self):
        self._resolve_pending_eval()
        accs = [(r.test_accuracy, r.round) for r in self.history
                if r.test_accuracy is not None]
        return max(accs) if accs else (None, None)

    # ---- checkpointing (TrainerState <-> checkpoint/checkpoint.py) ----

    def state(self) -> TrainerState:
        """Snapshot the checkpoint unit. The prefetcher may have staged
        (and therefore sampled) rounds beyond the last consumed one; the
        snapshot rolls RNG/sampler/schedule back to the next UNCONSUMED
        round using the per-round captures taken at sampling time, so a
        resumed trainer re-draws the staged rounds identically."""
        self.finalize()
        with self._sample_lock:
            # history carries every consumed round (including restored
            # ones after a resume), so its length IS the next round —
            # provided rounds were consumed sequentially; reject anything
            # else loudly instead of writing a silently-wrong checkpoint
            next_round = len(self.history)
            if [r.round for r in self.history] != list(range(next_round)):
                raise ValueError(
                    "save() requires rounds to have been run sequentially "
                    "from 0 (run_round(0), run_round(1), ...); history "
                    f"holds rounds {[r.round for r in self.history]}")
            # synchronous path: the sampling frontier IS the next round;
            # buffered-async: the engine's wave frontier (waves run ahead
            # of server rounds), and the capture also carries the
            # runtime-model state as of that wave
            frontier = (next_round if self._engine is None
                        else self._engine.wave_frontier)
            cap = self._round_caps.get(frontier)
            if cap is None:     # nothing staged past the consumed rounds
                cap = {"rng": self.rng.get_state(),
                       "sampler": self.sampler.state_dict(),
                       "max_batches": self._max_batches,
                       **({"runtime": self._runtime.state_dict()}
                          if self._runtime is not None else {})}
            schedule = [np.asarray(c) for c in self.schedule[:frontier]]
        return TrainerState(
            params=self.params, server_state=self.server_state,
            round=next_round, max_batches=cap["max_batches"],
            rng_state=cap["rng"], sampler_state=cap["sampler"],
            schedule=schedule, history=list(self.history),
            runtime_state=cap.get("runtime"),
            opt_state=self._opt_state)

    def _codec_echo(self) -> Optional[dict]:
        """JSON echo of the LOSSY codec configuration (identity is
        bitwise the no-codec path, so it normalizes to None): the codec
        decides what the aggregated values ARE, and with EF it decides
        the accumulator trajectory — a resume mismatch silently
        diverges, so restore() compares this and fails loudly."""
        if not self._codec_lossy:
            return None
        return {"config": self._codec.config_dict(), "ef": self._codec_ef}

    def _algo_echo(self) -> dict:
        """JSON echo of everything that parameterizes the compiled round
        — a resume with ANY of these changed cannot continue the run."""
        return {
            "eta_l": self.algo_cfg.eta_l,
            "eta_g": self.algo_cfg.eta_g,
            "local_optimizer": self.algo_cfg.local_optimizer,
            "hyper": {"class": type(self.algo.hyper).__name__,
                      **asdict(self.algo.hyper)},
        }

    def save(self, ckpt_dir: str, keep: int = 3) -> str:
        """Write the full TrainerState; ``resume(ckpt_dir, ...)`` then
        reproduces the uninterrupted run exactly."""
        from repro.checkpoint import checkpoint as ckpt
        st = self.state()
        rng = st.rng_state
        k = self.cfg.clients_per_round
        aux_arrays = {
            "rng_keys": np.asarray(rng[1], np.uint32),
            "rng_pos": np.int64(rng[2]),
            "rng_has_gauss": np.int64(rng[3]),
            "rng_cached": np.float64(rng[4]),
            "round": np.int64(st.round),
            "max_batches": np.int64(-1 if st.max_batches is None
                                    else st.max_batches),
            "schedule": (np.stack(st.schedule).astype(np.int64)
                         if st.schedule else np.zeros((0, k), np.int64)),
        }
        if self._codec_ef:
            # error-feedback accumulator (repro.codec, DESIGN.md §13):
            # params-shaped f32, saved leaf-exact so resume is bitwise
            for i, leaf in enumerate(jax.tree_util.tree_leaves(self._ef)):
                aux_arrays[f"codec_ef_{i}"] = np.asarray(leaf, np.float32)
        if st.opt_state is not None:
            # server-optimizer moments (repro.optim.server, DESIGN.md
            # §14): {"m","v"} params mirrors in f32, leaf-exact — the
            # optimizer only advances at folds, so a mid-buffer async
            # save carries exactly the round-boundary state
            for i, leaf in enumerate(
                    jax.tree_util.tree_leaves(st.opt_state)):
                aux_arrays[f"server_opt_{i}"] = np.asarray(leaf, np.float32)
        if self._engine is not None:
            # buffered-async streaming state (DESIGN.md §11): virtual
            # clock + the in-flight entries (dispatched, not yet folded)
            # in heap order, their delta pytrees stacked per leaf with
            # exact dtypes — load_inflight rebuilds the heap bitwise.
            # The arrival buffer itself is always empty between rounds.
            eng = self._engine
            entries = eng.inflight()
            aux_arrays.update({
                "async_clock": np.float64(eng.clock),
                "async_seq": np.int64(eng.seq),
                "async_wave_frontier": np.int64(eng.wave_frontier),
                "async_n_inflight": np.int64(len(entries)),
                "async_entry_client": np.asarray(
                    [e.client for e in entries], np.int64),
                "async_entry_wave": np.asarray(
                    [e.wave for e in entries], np.int64),
                "async_entry_version": np.asarray(
                    [e.version for e in entries], np.int64),
                "async_entry_seq": np.asarray(
                    [e.seq for e in entries], np.int64),
                "async_entry_finish": np.asarray(
                    [e.finish for e in entries], np.float64),
                "async_entry_loss": np.asarray(
                    [e.loss for e in entries], np.float32),
            })
            n_entry_leaves = len(jax.tree_util.tree_leaves(
                self._entry_delta_template()))
            for i in range(n_entry_leaves):
                if entries:
                    aux_arrays[f"async_delta_{i}"] = np.stack(
                        [np.asarray(jax.tree_util.tree_leaves(e.delta)[i])
                         for e in entries])
        aux_json = {
            "format": 1,
            "algorithm": self.algo.name,
            "algo_config": self._algo_echo(),
            # informational (NOT compared on restore: the saved arrays are
            # full host copies, so any mesh shape can pick them up)
            "exec_mesh": {"shard_clients": self.cfg.shard_clients,
                          "shard_model": self.cfg.shard_model,
                          "devices": (0 if self.mesh is None
                                      else int(self.mesh.devices.size))},
            "num_clients": self.num_clients,
            "clients_per_round": k,
            "sampler": {"class": type(self.sampler).__name__,
                        "config": self.sampler.config_dict(),
                        "state": st.sampler_state},
            "codec": self._codec_echo(),
            # server optimizer echo (None for the stateless sgd anchor):
            # the moments' trajectory is part of the run, so restore()
            # compares this and fails loudly on a mismatch
            "server_opt": (None if self._server_opt is None
                           else self._server_opt.config_dict()),
            "history": [asdict(r) for r in st.history],
        }
        if self._health is not None:
            # run-health detector state (repro.health, DESIGN.md §14):
            # observe() runs at consumption, never ahead of it, so the
            # window checkpoints verbatim — a resumed detector picks up
            # mid-window instead of re-warming blind
            aux_json["health"] = {
                "config": self._health.config.config_dict(),
                "state": self._health.state_dict()}
        if self._engine is not None:
            aux_json["async"] = {
                "buffer_size": self._engine.buffer_size,
                "alpha": self._engine.alpha,
                "concurrency": self._engine.concurrency,
                "runtime": {"config": self._runtime.config_dict(),
                            "state": st.runtime_state or {}},
            }
        elif self._runtime is not None:
            # sync round-deadline regime: the runtime model rides the
            # sampling lock exactly like the async one, and its pre-draw
            # state is captured in the same per-round caps
            aux_json["sync_runtime"] = {
                "config": self._runtime.config_dict(),
                "state": st.runtime_state or {}}
        if (self._guard is not None or self.fault_plan is not None
                or self.cfg.round_deadline is not None):
            # chaos-hardening echo (DESIGN.md §12): the guard window is
            # consumed-round state (observe() runs at consumption, never
            # ahead of it), so it checkpoints verbatim — resume bitwise
            aux_json["chaos"] = {
                "round_deadline": self.cfg.round_deadline,
                "fault_plan": (None if self.fault_plan is None
                               else self.fault_plan.config_dict()),
                "guard": (None if self._guard is None else
                          {"config": self._guard.config.config_dict(),
                           "state": self._guard.state_dict()}),
            }
        if self._mp:
            # only process 0 writes (DESIGN.md §15): every process holds
            # the identical replicated state, so N concurrent writers
            # would race on the same files for no information gain. The
            # KV barrier keeps non-writers from resuming/deleting past a
            # save that is still in flight; the step path is composed
            # deterministically so every process returns the same string.
            from repro.launch import distributed as dist_mod
            if dist_mod.is_coordinator():
                path = ckpt.save(ckpt_dir, st.round,
                                 {"params": st.params,
                                  "server_state": st.server_state},
                                 keep=keep, aux_arrays=aux_arrays,
                                 aux_json=aux_json)
            else:
                path = os.path.join(ckpt_dir, f"step_{st.round:08d}")
            self._save_seq += 1
            dist_mod.barrier(f"t{self._mp_seq}/save/{self._save_seq}")
            return path
        return ckpt.save(ckpt_dir, st.round,
                         {"params": st.params,
                          "server_state": st.server_state},
                         keep=keep, aux_arrays=aux_arrays, aux_json=aux_json)

    def restore(self, ckpt_dir: str, step: Optional[int] = None
                ) -> "FederatedTrainer":
        """Load a TrainerState saved by ``save`` into this (freshly
        constructed) trainer; ``run()`` then continues from the saved
        round. Configs/loss_fn/source are NOT checkpointed — construct
        the trainer exactly as the original run did.  Exception: the
        EXECUTION levers (vectorize / shard_clients / shard_model /
        prefetch) may differ — checkpoints hold full host arrays, so a
        run saved on one mesh shape resumes on another (or on none)
        numerically equivalent (allclose; bitwise identity holds only
        for a same-mesh resume — a mesh shape change reorders the f32
        reductions); a shard_model that cannot tile the new device
        count fails loudly at construction."""
        if self._prefetcher is not None or self.history or self.schedule:
            # a used trainer has a live prefetch thread drawing this RNG
            # and staged rounds past the restore point — rewinding it in
            # place would race and/or desync; restore only into a fresh
            # construction (what resume() does)
            raise RuntimeError(
                "restore() requires a freshly constructed trainer that "
                "has not run any rounds — use FederatedTrainer.resume()")
        from repro.checkpoint import checkpoint as ckpt
        # self-healing resume (DESIGN.md §12): verify content digests and
        # fall back to the newest INTACT step when the latest is corrupt
        # (truncated / bit-flipped / missing manifest); an explicitly
        # requested step never falls back silently — it fails loudly
        step = ckpt.resolve_step(ckpt_dir, step)
        like = {"params": self.params, "server_state": self.server_state}
        state = ckpt.restore(ckpt_dir, like, step=step)
        arrays, meta = ckpt.load_aux(ckpt_dir, step)
        if meta is None or "rng_keys" not in arrays:
            raise ValueError(f"{ckpt_dir} has no TrainerState sidecars — "
                             "was it written by FederatedTrainer.save()?")
        if meta["algorithm"] != self.algo.name:
            raise ValueError(f"checkpoint is for {meta['algorithm']!r}, "
                             f"trainer runs {self.algo.name!r}")
        for field_name, mine in (("num_clients", self.num_clients),
                                 ("clients_per_round",
                                  self.cfg.clients_per_round),
                                 ("algo_config", self._algo_echo())):
            saved = meta.get(field_name)
            if saved is not None and saved != mine:
                # a different population / cohort size / algorithm
                # parameterization cannot continue the run — fail here,
                # not rounds later (or silently diverge)
                raise ValueError(
                    f"checkpoint has {field_name}={saved}, trainer was "
                    f"built with {mine} — resume with the original "
                    "configuration")
        saved_sampler = meta["sampler"].get("class")
        if saved_sampler != type(self.sampler).__name__:
            # a mismatched sampler would silently discard checkpointed
            # sampler state (e.g. the Markov availability chain) and
            # break the bitwise-resume guarantee
            raise ValueError(
                f"checkpoint was sampled by {saved_sampler}, trainer uses "
                f"{type(self.sampler).__name__} — resume with the same "
                "sampler the original run used")
        saved_cfg = meta["sampler"].get("config")
        if saved_cfg is not None:
            # resume-compat shim: pre-digest sidecars embedded the full
            # probability vector under "p"; normalize maps them onto the
            # (p_digest, p_len) form current config_dicts carry
            from repro.core.samplers import normalize_sampler_config
            saved_cfg = normalize_sampler_config(saved_cfg)
        if saved_cfg is not None and saved_cfg != self.sampler.config_dict():
            # same class, different construction (Markov transition
            # probabilities, weight vector, ...) — also diverges silently
            raise ValueError(
                f"checkpoint sampler was built as {saved_cfg}, trainer's "
                f"is {self.sampler.config_dict()} — resume with the "
                "original sampler parameters")
        meta_async = meta.get("async")
        if (meta_async is not None) != (self._engine is not None):
            raise ValueError(
                "checkpoint and trainer disagree on the buffered-async "
                f"regime (checkpoint async={meta_async is not None}, "
                f"trainer async={self._engine is not None}) — the wave/"
                "arrival trajectory is part of the run and cannot switch "
                "mid-stream")
        if self._engine is not None:
            eng = self._engine
            for knob in ("buffer_size", "alpha", "concurrency"):
                if meta_async[knob] != getattr(eng, knob):
                    raise ValueError(
                        f"checkpoint has async {knob}={meta_async[knob]}, "
                        f"trainer was built with {getattr(eng, knob)} — "
                        "resume with the original async configuration")
            rt = meta_async["runtime"]
            if rt["config"] != self._runtime.config_dict():
                raise ValueError(
                    f"checkpoint runtime model was built as "
                    f"{rt['config']}, trainer's is "
                    f"{self._runtime.config_dict()} — resume with the "
                    "original runtime model")
        meta_sync_rt = meta.get("sync_runtime")
        if meta_sync_rt is not None:
            if self._engine is not None or self._runtime is None:
                raise ValueError(
                    "checkpoint carries a sync-deadline runtime model but "
                    "the trainer was not built with one — resume with the "
                    "original ExecConfig(round_deadline=...) and runtime")
            if meta_sync_rt["config"] != self._runtime.config_dict():
                raise ValueError(
                    f"checkpoint sync runtime model was built as "
                    f"{meta_sync_rt['config']}, trainer's is "
                    f"{self._runtime.config_dict()} — resume with the "
                    "original runtime model")
        meta_chaos = meta.get("chaos")
        if meta_chaos is not None:
            # the chaos configuration is part of the trajectory: the
            # fault plan decides the injected faults, the guard window
            # decides the thresholds, the deadline decides the folds —
            # any mismatch silently diverges, so fail at restore
            mine_chaos = {
                "round_deadline": self.cfg.round_deadline,
                "fault_plan": (None if self.fault_plan is None
                               else self.fault_plan.config_dict()),
                "guard_config": (None if self._guard is None
                                 else self._guard.config.config_dict()),
            }
            saved_chaos = {
                "round_deadline": meta_chaos.get("round_deadline"),
                "fault_plan": meta_chaos.get("fault_plan"),
                "guard_config": (meta_chaos["guard"] or {}).get("config")
                                if meta_chaos.get("guard") is not None
                                else None,
            }
            # JSON round-trips tuples as lists: normalize through the
            # same encoder before comparing
            import json as _json
            if (_json.loads(_json.dumps(mine_chaos, default=float))
                    != saved_chaos):
                raise ValueError(
                    f"checkpoint chaos configuration {saved_chaos} does "
                    f"not match the trainer's {mine_chaos} — resume with "
                    "the original guard/fault-plan/deadline configuration")
        elif (self._guard is not None or self.fault_plan is not None
              or self.cfg.round_deadline is not None):
            raise ValueError(
                "trainer was built with chaos hardening (guard/fault "
                "plan/deadline) but the checkpoint has none — resume "
                "with the original configuration")
        if meta.get("codec") != self._codec_echo():
            # the codec decides what the aggregated values ARE (and the
            # EF accumulator trajectory): a mismatch cannot continue the
            # run — fail here, not rounds later
            raise ValueError(
                f"checkpoint codec configuration {meta.get('codec')} "
                f"does not match the trainer's {self._codec_echo()} — "
                "resume with the original codec/codec_ef configuration")
        mine_sopt = (None if self._server_opt is None
                     else self._server_opt.config_dict())
        if meta.get("server_opt") != mine_sopt:
            # the moment trajectory is part of the run: switching the
            # server optimizer (or its parameterization) mid-stream
            # silently diverges — fail at restore
            raise ValueError(
                f"checkpoint server optimizer {meta.get('server_opt')} "
                f"does not match the trainer's {mine_sopt} — resume "
                "with the original server_opt configuration")
        meta_health = meta.get("health")
        if (meta_health is not None) != (self._health is not None):
            raise ValueError(
                "checkpoint and trainer disagree on the run-health "
                f"monitor (checkpoint health={meta_health is not None}, "
                f"trainer health={self._health is not None}) — resume "
                "with the original ExecConfig.health configuration")
        if (meta_health is not None
                and meta_health["config"] != self._health.config
                .config_dict()):
            raise ValueError(
                f"checkpoint health configuration {meta_health['config']} "
                f"does not match the trainer's "
                f"{self._health.config.config_dict()} — resume with the "
                "original health detector parameters")
        self.params = state["params"]
        self.server_state = state["server_state"]
        if self._codec_ef:
            if "codec_ef_0" not in arrays:
                raise ValueError(
                    "trainer expects an error-feedback accumulator but "
                    "the checkpoint carries none — resume with the "
                    "original codec configuration")
            leaves, treedef = jax.tree_util.tree_flatten(self._ef)
            self._ef = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(arrays[f"codec_ef_{i}"], jnp.float32)
                          for i in range(len(leaves))])
        if self._opt_state is not None:
            if "server_opt_0" not in arrays:
                raise ValueError(
                    "trainer expects server-optimizer moment state but "
                    "the checkpoint carries none — resume with the "
                    "original server_opt configuration")
            leaves, treedef = jax.tree_util.tree_flatten(self._opt_state)
            self._opt_state = jax.tree_util.tree_unflatten(
                treedef,
                [jnp.asarray(arrays[f"server_opt_{i}"], jnp.float32)
                 for i in range(len(leaves))])
        if self.mesh is not None:
            # checkpoints hold full (host) arrays, so restoring onto a
            # DIFFERENT mesh shape than the one that saved them works:
            # the state is simply re-placed with this trainer's layout
            # (an impossible shard_model — not dividing the device count
            # — already failed loudly in _build_mesh). That includes
            # crossing a process-count boundary: a 2-process run resumes
            # single-process and vice versa (multi-process placement
            # assembles per-host shards via put_global; every process
            # must read the same checkpoint directory).
            p_sh, s_sh = self._placements()
            if self._mp:
                self.params = self._put_replicated(self.params, p_sh)
                self.server_state = self._put_replicated(
                    self.server_state, s_sh)
                if self._ef is not None:
                    self._ef = self._put_replicated(self._ef, p_sh)
                if self._opt_state is not None:
                    self._opt_state = self._put_replicated(
                        self._opt_state, p_sh)
            else:
                self.params = jax.device_put(self.params, p_sh)
                self.server_state = jax.device_put(self.server_state, s_sh)
                if self._ef is not None:
                    self._ef = jax.device_put(self._ef, p_sh)
                if self._opt_state is not None:
                    self._opt_state = jax.device_put(self._opt_state,
                                                     self._opt_shardings)
        self.rng.set_state(("MT19937",
                            np.asarray(arrays["rng_keys"], np.uint32),
                            int(arrays["rng_pos"]),
                            int(arrays["rng_has_gauss"]),
                            float(arrays["rng_cached"])))
        mb = int(arrays["max_batches"])
        self._max_batches = None if mb < 0 else mb
        self._start_round = int(arrays["round"])
        self.schedule = [row for row in np.asarray(arrays["schedule"])]
        self.history = [RoundRecord(**r) for r in meta["history"]]
        if meta["sampler"].get("state"):
            self.sampler.load_state_dict(meta["sampler"]["state"])
        if meta.get("sync_runtime", {}).get("state"):
            self._runtime.load_state_dict(meta["sync_runtime"]["state"])
        if (meta.get("chaos") or {}).get("guard") is not None \
                and self._guard is not None:
            gst = meta["chaos"]["guard"].get("state")
            if gst:
                self._guard.load_state_dict(gst)
        if meta_health is not None and self._health is not None:
            self._health.load_state_dict(meta_health["state"])
        if self._engine is not None:
            from repro.core.async_engine import BufferEntry
            eng = self._engine
            rt_state = meta["async"]["runtime"].get("state")
            if rt_state:
                self._runtime.load_state_dict(rt_state)
            eng.clock = float(arrays["async_clock"])
            eng.seq = int(arrays["async_seq"])
            eng.wave_frontier = int(arrays["async_wave_frontier"])
            # folds performed == server rounds consumed
            eng.version = self._start_round
            n = int(arrays["async_n_inflight"])
            entries = []
            if n:
                # entry deltas are raw params trees, or the codec's
                # single-client wire payloads (exact dtypes round-trip
                # through the npz sidecar, so int8 codes reload as int8)
                _, treedef = jax.tree_util.tree_flatten(
                    self._entry_delta_template())
                stacked = [jnp.asarray(arrays[f"async_delta_{i}"])
                           for i in range(treedef.num_leaves)]
                for j in range(n):
                    entries.append(BufferEntry(
                        client=int(arrays["async_entry_client"][j]),
                        wave=int(arrays["async_entry_wave"][j]),
                        version=int(arrays["async_entry_version"][j]),
                        seq=int(arrays["async_entry_seq"][j]),
                        finish=float(arrays["async_entry_finish"][j]),
                        loss=float(arrays["async_entry_loss"][j]),
                        delta=jax.tree_util.tree_unflatten(
                            treedef, [s[j] for s in stacked])))
            eng.load_inflight(entries)
        self._round_caps.clear()
        return self

    @classmethod
    def resume(cls, ckpt_dir: str, loss_fn: Callable, params: PyTree,
               num_clients: int, data, cfg=None, eval_fn=None, *,
               algo: Optional[AlgoConfig] = None,
               sampler: Optional[ClientSampler] = None,
               runtime=None, fault_plan=None,
               step: Optional[int] = None) -> "FederatedTrainer":
        """Fresh-process resume: construct the trainer exactly as the
        original run did, then restore the saved TrainerState. ``run()``
        continues from the checkpointed round and reproduces the
        uninterrupted run bit for bit — including mid-buffer async
        state (pass the same ``runtime`` model — and, chaos-hardened,
        the same ``fault_plan`` — the original run used)."""
        algo, cfg = _coerce_cfg(cfg, algo)
        tr = cls(loss_fn, params, num_clients, data, cfg, eval_fn,
                 algo=algo, sampler=sampler, runtime=runtime,
                 fault_plan=fault_plan)
        return tr.restore(ckpt_dir, step=step)
