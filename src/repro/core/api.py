"""FederatedTrainer — simulation-mode FL driver (reproduces the paper).

Orchestrates: client sampling (uniform, partial participation) -> local
training -> server aggregation (FedDPC or any baseline) -> periodic
global-model evaluation.

The default round is **cohort-vectorized** (cfg.vectorize=True): all
clients_per_round clients' padded minibatch stacks are stacked into one
(K, M, ...) batch pytree and the whole round — local training vmapped
over the client axis, fused with the server step — runs as ONE jit'd
program per round (core/round.py ``make_cohort_round``), donating the
params/server-state buffers. cfg.vectorize=False keeps the historical
serial path (one jit dispatch per client + a host-side stack), retained
as the reference for the equivalence tests.

Shape bucketing: M is padded to the cohort max and ``_max_batches`` only
grows (grow-once), so the jit cache holds one program per (K, M) bucket
and later rounds with fewer batches re-use the compiled round.

Works for any (loss_fn, params, data source): the paper's vision models
and the framework's LM architectures both plug in through the same API.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client as client_mod
from repro.core import round as round_mod
from repro.core.baselines import ServerAlgo, get_algorithm

PyTree = Any


@dataclass
class FLConfig:
    algorithm: str = "feddpc"
    rounds: int = 50
    clients_per_round: int = 10
    eta_l: float = 0.1
    eta_g: float = 1.0
    lam: float = 1.0                 # FedDPC adaptive-scaling hyper-param
    mu: float = 0.01                 # FedProx
    cm_alpha: float = 0.1            # FedCM
    ga_beta: float = 0.1             # FedGA
    batch_size: int = 256
    local_epochs: int = 1
    local_optimizer: str = "sgd"
    seed: int = 0
    eval_every: int = 5
    use_kernel: bool = False         # route FedDPC epilogue through Pallas
    vectorize: bool = True           # one fused program per round (default)


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_accuracy: Optional[float] = None
    seconds: float = 0.0
    diagnostics: Dict[str, float] = field(default_factory=dict)


class FederatedTrainer:
    """loss_fn(params, batch) -> scalar; batches come from
    ``batch_fn(client, round)`` -> list of batch pytrees (numpy).
    eval_fn(params) -> float accuracy (optional)."""

    def __init__(self, loss_fn: Callable, params: PyTree, num_clients: int,
                 batch_fn: Callable[[int, int], List[dict]],
                 cfg: FLConfig,
                 eval_fn: Optional[Callable[[PyTree], float]] = None):
        self.cfg = cfg
        # private copy: the fused round donates the params buffers, and the
        # caller's tree must stay valid (sweeps reuse one init across runs)
        self.params = jax.tree.map(lambda x: jnp.array(x, copy=True), params)
        self.num_clients = num_clients
        self.batch_fn = batch_fn
        self.eval_fn = eval_fn
        self.algo: ServerAlgo = get_algorithm(
            cfg.algorithm, lam=cfg.lam, use_kernel=cfg.use_kernel)
        self.server_state = self.algo.init(self.params, num_clients)
        # fused path: local training + server step, one program per round
        self._cohort_round = round_mod.make_cohort_round(
            loss_fn, self.algo, cfg.eta_l, cfg.eta_g,
            optimizer=cfg.local_optimizer, mu=cfg.mu,
            cm_alpha=cfg.cm_alpha, ga_beta=cfg.ga_beta)
        # serial reference path (cfg.vectorize=False): per-client dispatch
        self.local_update = client_mod.make_local_update(
            loss_fn, cfg.eta_l, variant=self.algo.client_variant,
            optimizer=cfg.local_optimizer, mu=cfg.mu,
            cm_alpha=cfg.cm_alpha, ga_beta=cfg.ga_beta)
        self._server_step = jax.jit(
            lambda st, p, d, ids: self.algo.step(
                st, p, d, ids, cfg.eta_g, 0))
        self.rng = np.random.RandomState(cfg.seed)
        self.history: List[RoundRecord] = []
        self._max_batches: Optional[int] = None

    # ---- internals ----

    def _sample_clients(self) -> np.ndarray:
        return self.rng.choice(self.num_clients,
                               size=self.cfg.clients_per_round, replace=False)

    def _cohort_lists(self, clients: Sequence[int], t: int):
        per_client = [self.batch_fn(int(c), t) for c in clients]
        mx = max(len(b) for b in per_client)
        if self._max_batches is None or mx > self._max_batches:
            self._max_batches = mx          # grow-once; keeps jit cache small
        return per_client

    def _round_batches(self, clients: Sequence[int], t: int):
        return [client_mod.stack_batches(b, self._max_batches)
                for b in self._cohort_lists(clients, t)]

    def _run_round_vectorized(self, clients: np.ndarray, t: int):
        batches, masks = client_mod.stack_cohort(
            self._cohort_lists(clients, t), self._max_batches)
        ids = jnp.asarray(clients, jnp.int32)
        self.params, self.server_state, losses, diag = self._cohort_round(
            self.server_state, self.params, batches, masks, ids)
        return float(jnp.mean(losses)), diag

    def _run_round_serial(self, clients: np.ndarray, t: int):
        extra = self.algo.client_extra(self.server_state)
        deltas, losses = [], []
        for (batches, mask) in self._round_batches(clients, t):
            delta, loss = self.local_update(self.params, batches, mask, extra)
            deltas.append(delta)
            losses.append(float(loss))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        ids = jnp.asarray(clients, jnp.int32)
        self.params, self.server_state, diag = self._server_step(
            self.server_state, self.params, stacked, ids)
        return float(np.mean(losses)), diag

    # ---- public ----

    def run_round(self, t: int) -> RoundRecord:
        tic = time.perf_counter()
        clients = self._sample_clients()
        run = (self._run_round_vectorized if self.cfg.vectorize
               else self._run_round_serial)
        train_loss, diag = run(clients, t)
        rec = RoundRecord(
            round=t, train_loss=train_loss,
            seconds=time.perf_counter() - tic,
            diagnostics={k: float(v) for k, v in diag.items()})
        if self.eval_fn and (t % self.cfg.eval_every == 0
                             or t == self.cfg.rounds - 1):
            rec.test_accuracy = float(self.eval_fn(self.params))
        self.history.append(rec)
        return rec

    def run(self, verbose: bool = False) -> List[RoundRecord]:
        for t in range(self.cfg.rounds):
            rec = self.run_round(t)
            if verbose:
                acc = ("" if rec.test_accuracy is None
                       else f"  acc={rec.test_accuracy:.4f}")
                print(f"[{self.cfg.algorithm}] round {t:4d} "
                      f"loss={rec.train_loss:.4f}{acc}")
        return self.history

    @property
    def best_accuracy(self):
        accs = [(r.test_accuracy, r.round) for r in self.history
                if r.test_accuracy is not None]
        return max(accs) if accs else (None, None)
