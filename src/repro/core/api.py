"""FederatedTrainer — simulation-mode FL driver (reproduces the paper).

Orchestrates: client sampling (uniform, partial participation) -> local
training -> server aggregation (FedDPC or any baseline) -> periodic
global-model evaluation.

The default round is **cohort-vectorized** (cfg.vectorize=True): all
clients_per_round clients' padded minibatch stacks are stacked into one
(K, M, ...) batch pytree and the whole round — local training vmapped
over the client axis, fused with the server step — runs as ONE jit'd
program per round (core/round.py ``make_cohort_round``), donating the
params/server-state buffers. cfg.vectorize=False keeps the historical
serial path (one jit dispatch per client + a host-side stack), retained
as the reference for the equivalence tests.

Shape bucketing: M is padded to the cohort max and ``_max_batches`` only
grows (grow-once), so the jit cache holds one program per (K, M) bucket
and later rounds with fewer batches re-use the compiled round.

Scaling levers (DESIGN.md §2), all on by construction or by one flag:
  cfg.shard_clients  client-axis NamedSharding over the local devices —
      the (K, M, ...) cohort stack runs data-parallel across the mesh,
      params/server state replicated (launch/mesh.make_cohort_mesh +
      sharding/rules.cohort_round_shardings).
  cfg.prefetch       double-buffered host ingest: a daemon thread stages
      round t+1's cohort (sampling + batch_fn + stacking into
      preallocated buffers) while round t runs on device, so run_round
      blocks only on device completion (core/client.CohortPrefetcher).
  cfg.async_eval     eval_fn runs on a params snapshot in a worker
      thread, overlapped with the next round; the accuracy folds into
      its RoundRecord at the next eval boundary / finalize() / run() end.

Works for any (loss_fn, params, data source): the paper's vision models
and the framework's LM architectures both plug in through the same API.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client as client_mod
from repro.core import round as round_mod
from repro.core.baselines import ServerAlgo, get_algorithm

PyTree = Any


@dataclass
class FLConfig:
    algorithm: str = "feddpc"
    rounds: int = 50
    clients_per_round: int = 10
    eta_l: float = 0.1
    eta_g: float = 1.0
    lam: float = 1.0                 # FedDPC adaptive-scaling hyper-param
    mu: float = 0.01                 # FedProx
    cm_alpha: float = 0.1            # FedCM
    ga_beta: float = 0.1             # FedGA
    batch_size: int = 256
    local_epochs: int = 1
    local_optimizer: str = "sgd"
    seed: int = 0
    eval_every: int = 5
    use_kernel: bool = False         # route FedDPC epilogue through Pallas
    vectorize: bool = True           # one fused program per round (default)
    shard_clients: bool = False      # client-axis NamedSharding over devices
    prefetch: bool = True            # double-buffered host ingest (vectorized)
    # overlap eval_fn with the next round: accuracy folds into its
    # RoundRecord when ready (at latest at the next eval boundary /
    # finalize()/run() end) — read it from history, not from the record
    # run_round just returned; set False for strictly inline eval
    async_eval: bool = True


@dataclass
class RoundRecord:
    round: int
    train_loss: float
    test_accuracy: Optional[float] = None
    seconds: float = 0.0
    # host time this round spent blocked on cohort ingest (sampling +
    # batch_fn + stacking); with prefetch on it is just the staging wait
    ingest_seconds: float = 0.0
    diagnostics: Dict[str, float] = field(default_factory=dict)


class FederatedTrainer:
    """loss_fn(params, batch) -> scalar; batches come from
    ``batch_fn(client, round)`` -> list of batch pytrees (numpy).
    eval_fn(params) -> float accuracy (optional)."""

    def __init__(self, loss_fn: Callable, params: PyTree, num_clients: int,
                 batch_fn: Callable[[int, int], List[dict]],
                 cfg: FLConfig,
                 eval_fn: Optional[Callable[[PyTree], float]] = None):
        self.cfg = cfg
        # private copy: the fused round donates the params buffers, and the
        # caller's tree must stay valid (sweeps reuse one init across runs)
        self.params = jax.tree.map(lambda x: jnp.array(x, copy=True), params)
        self.num_clients = num_clients
        self.batch_fn = batch_fn
        self.eval_fn = eval_fn
        self.algo: ServerAlgo = get_algorithm(
            cfg.algorithm, lam=cfg.lam, use_kernel=cfg.use_kernel)
        self.server_state = self.algo.init(self.params, num_clients)
        self.mesh = self._build_mesh() if cfg.shard_clients else None
        # fused path: local training + server step, one program per round
        self._cohort_round = round_mod.make_cohort_round(
            loss_fn, self.algo, cfg.eta_l, cfg.eta_g,
            optimizer=cfg.local_optimizer, mu=cfg.mu,
            cm_alpha=cfg.cm_alpha, ga_beta=cfg.ga_beta, mesh=self.mesh)
        if self.mesh is not None:
            # pre-place replicated so the first round's donation matches
            from jax.sharding import NamedSharding, PartitionSpec as P
            rep = NamedSharding(self.mesh, P())
            self.params = jax.device_put(self.params, rep)
            self.server_state = jax.device_put(self.server_state, rep)
        # serial reference path (cfg.vectorize=False): per-client dispatch
        self.local_update = client_mod.make_local_update(
            loss_fn, cfg.eta_l, variant=self.algo.client_variant,
            optimizer=cfg.local_optimizer, mu=cfg.mu,
            cm_alpha=cfg.cm_alpha, ga_beta=cfg.ga_beta)
        self._server_step = jax.jit(
            lambda st, p, d, ids: self.algo.step(
                st, p, d, ids, cfg.eta_g, 0))
        self.rng = np.random.RandomState(cfg.seed)
        self.history: List[RoundRecord] = []
        self.schedule: List[np.ndarray] = []     # sampled cohort per round
        self._max_batches: Optional[int] = None
        self._prefetcher = None                  # built on first round
        self._pending_eval = None                # (RoundRecord, Future)
        self._async_eval = eval_fn is not None and cfg.async_eval

    # ---- internals ----

    def _build_mesh(self):
        from repro.launch import mesh as mesh_mod
        mesh = mesh_mod.make_cohort_mesh()
        from repro.sharding.rules import clients_divisible
        if not clients_divisible(mesh, self.cfg.clients_per_round):
            import warnings
            warnings.warn(
                f"clients_per_round={self.cfg.clients_per_round} is not a "
                f"multiple of the {int(mesh.devices.size)}-device client "
                "axis; falling back to the single-device cohort round")
            return None
        return mesh

    def _sample_clients(self) -> np.ndarray:
        clients = self.rng.choice(self.num_clients,
                                  size=self.cfg.clients_per_round,
                                  replace=False)
        self.schedule.append(clients)
        return clients

    def _cohort_lists(self, clients: Sequence[int], t: int):
        per_client = [self.batch_fn(int(c), t) for c in clients]
        mx = max(len(b) for b in per_client)
        if self._max_batches is None or mx > self._max_batches:
            self._max_batches = mx          # grow-once; keeps jit cache small
        return per_client

    def _round_batches(self, clients: Sequence[int], t: int):
        return [client_mod.stack_batches(b, self._max_batches)
                for b in self._cohort_lists(clients, t)]

    def _produce_cohort(self, t: int, slot: dict):
        """Prefetch-thread body: sample + fetch + stack round t's cohort
        into the slot's preallocated buffers (round order preserves the
        RNG-driven schedule exactly)."""
        clients = self._sample_clients()
        lists = self._cohort_lists(clients, t)
        batches, masks = client_mod.stack_cohort_into(
            lists, self._max_batches, slot)
        return clients, batches, masks

    def _run_round_vectorized(self, t: int):
        tic = time.perf_counter()
        if self.cfg.prefetch:
            if self._prefetcher is None:
                self._prefetcher = client_mod.CohortPrefetcher(
                    self._produce_cohort, t, self.cfg.rounds)
            (clients, batches, masks), slot = self._prefetcher.get(t)
        else:
            slot = None
            clients = self._sample_clients()
            batches, masks = client_mod.stack_cohort(
                self._cohort_lists(clients, t), self._max_batches)
        ingest = time.perf_counter() - tic
        try:
            ids = jnp.asarray(clients, jnp.int32)
            self.params, self.server_state, losses, diag = self._cohort_round(
                self.server_state, self.params, batches, masks, ids)
            # syncs on the round's result: after this the device is done
            # with the inputs and the slot is reusable for t+2
            train_loss = float(jnp.mean(losses))
        finally:
            # released on error too — leaking the slot would deadlock the
            # NEXT run_round inside the prefetcher instead of erroring
            if slot is not None:
                self._prefetcher.release(slot)
        return train_loss, diag, ingest

    def _run_round_serial(self, t: int):
        clients = self._sample_clients()
        tic = time.perf_counter()
        round_batches = self._round_batches(clients, t)
        ingest = time.perf_counter() - tic
        extra = self.algo.client_extra(self.server_state)
        deltas, losses = [], []
        for (batches, mask) in round_batches:
            delta, loss = self.local_update(self.params, batches, mask, extra)
            deltas.append(delta)
            losses.append(float(loss))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        ids = jnp.asarray(clients, jnp.int32)
        self.params, self.server_state, diag = self._server_step(
            self.server_state, self.params, stacked, ids)
        return float(np.mean(losses)), diag, ingest

    def _resolve_pending_eval(self):
        if self._pending_eval is not None:
            rec, fut = self._pending_eval
            self._pending_eval = None
            rec.test_accuracy = float(fut.result())

    # ---- public ----

    def run_round(self, t: int) -> RoundRecord:
        # fold a FINISHED async eval into its record without blocking, so
        # manual run_round loops see accuracies at most one round late
        # (the still-running case resolves at the next eval boundary /
        # finalize()/run() end)
        if self._pending_eval is not None and self._pending_eval[1].done():
            self._resolve_pending_eval()
        tic = time.perf_counter()
        run = (self._run_round_vectorized if self.cfg.vectorize
               else self._run_round_serial)
        train_loss, diag, ingest = run(t)
        rec = RoundRecord(
            round=t, train_loss=train_loss,
            seconds=time.perf_counter() - tic, ingest_seconds=ingest,
            diagnostics={k: float(v) for k, v in diag.items()})
        if self.eval_fn and (t % self.cfg.eval_every == 0
                             or t == self.cfg.rounds - 1):
            # previous async eval must land before its boundary passes
            self._resolve_pending_eval()
            if self._async_eval:
                # snapshot: the next round DONATES self.params, so eval
                # runs on a private copy, overlapped with t+1's ingest;
                # the result folds into rec at the next boundary (or
                # finalize()/run() end). One short-lived daemon thread per
                # eval — sweeps build many trainers and a pooled worker
                # per trainer would accumulate idle threads.
                import threading
                from concurrent.futures import Future
                snap = jax.tree.map(lambda x: jnp.array(x, copy=True),
                                    self.params)
                fut = Future()

                def _eval(fn=self.eval_fn, p=snap, fut=fut):
                    try:
                        fut.set_result(fn(p))
                    except BaseException as e:
                        fut.set_exception(e)

                threading.Thread(target=_eval, daemon=True,
                                 name="fl-eval").start()
                self._pending_eval = (rec, fut)
            else:
                rec.test_accuracy = float(self.eval_fn(self.params))
        self.history.append(rec)
        return rec

    def finalize(self):
        """Land any in-flight async eval into its RoundRecord."""
        self._resolve_pending_eval()

    def close(self):
        self.finalize()
        if self._prefetcher is not None:
            self._prefetcher.stop()

    def run(self, verbose: bool = False) -> List[RoundRecord]:
        for t in range(self.cfg.rounds):
            rec = self.run_round(t)
            if verbose:
                # a human is watching: land this round's async eval now so
                # the accuracy prints with its round (trades the overlap)
                self._resolve_pending_eval()
                acc = ("" if rec.test_accuracy is None
                       else f"  acc={rec.test_accuracy:.4f}")
                print(f"[{self.cfg.algorithm}] round {t:4d} "
                      f"loss={rec.train_loss:.4f}{acc}")
        self.finalize()
        return self.history

    @property
    def best_accuracy(self):
        self._resolve_pending_eval()
        accs = [(r.test_accuracy, r.round) for r in self.history
                if r.test_accuracy is not None]
        return max(accs) if accs else (None, None)
