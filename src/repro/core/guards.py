"""Update quarantine for arriving client deltas (DESIGN.md §12).

One NaN client poisons every mean-style server rule, and FedDPC is worse:
a non-finite or exploded delta enters the projection geometry (the
<Δ,Δ_prev> reduction) and corrupts Δ_prev for EVERY later round. The
``UpdateGuard`` validates deltas before aggregation:

  quarantine   non-finite entries, or ||Δ|| above ``quarantine_mult`` x
               the rolling robust threshold → the client's delta is
               ZEROED inside the jit'd round (0 x NaN = NaN, so masking
               alone is NOT enough), its id is replaced by an
               out-of-range sentinel (FedVARP's masked scatter only
               drops out-of-range ids), and its row folds into the
               existing ``client_mask`` — every server rule stays exact
               with zero rule changes (the mask already renormalizes
               FedDPC's reduction-pass scalars, the client mean, and
               FedExP's extrapolation count).
  clip         finite deltas with ||Δ|| above ``clip_mult`` x threshold
               are scaled down to the clip limit (norm outliers damp
               instead of dominating the mean).

The threshold is the MEDIAN of a rolling window of accepted norms —
robust: a burst of exploded updates cannot drag it up, because rejected
norms never enter the window. While fewer than ``min_history`` norms
have been accepted the threshold is +inf: nothing quarantines by norm
(non-finite always quarantines), nothing clips, and every multiplier is
exactly 1.0 — a guarded run with no faults is the unguarded run.

The in-round reduction (per-client ||Δ||² + non-finite count) shares the
pass that computes FedDPC's reduction scalars; the fused Pallas form is
``kernels/feddpc_project.guard_dots`` (4th column = non-finite count),
validated bitwise against its reference path in interpret mode.

The window itself lives HOST-SIDE on the trainer: rounds consume the
threshold as a scalar jit input and return accepted norms, the guard
observes them in round order (sequential even under prefetch/async, so
checkpointing the window verbatim keeps resume bitwise).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class GuardConfig:
    quarantine_mult: float = 1e3     # ||Δ|| > mult x thresh → quarantine
    clip_mult: float = 1e2           # ||Δ|| > mult x thresh → clip to limit
    window: int = 64                 # rolling accepted-norm window size
    min_history: int = 8             # threshold is +inf below this count

    def config_dict(self) -> dict:
        return {"quarantine_mult": self.quarantine_mult,
                "clip_mult": self.clip_mult, "window": self.window,
                "min_history": self.min_history}


class UpdateGuard:
    """Host-side rolling robust threshold over accepted update norms."""

    def __init__(self, config: GuardConfig = GuardConfig()):
        self.config = config
        self._norms: deque = deque(maxlen=int(config.window))
        self.total_quarantined = 0
        self.total_clipped = 0

    def threshold(self) -> float:
        """Median of the accepted-norm window; +inf until min_history
        norms have been observed (cold-start: quarantine only on
        non-finite, never on norm)."""
        if len(self._norms) < self.config.min_history:
            return float("inf")
        return float(np.median(np.asarray(self._norms)))

    def observe(self, accepted_norms: Sequence[float],
                quarantined: int = 0, clipped: int = 0) -> None:
        """Fold one consumed round's ACCEPTED (non-quarantined, real-row)
        norms into the window, in round order."""
        for n in np.asarray(accepted_norms, np.float64).ravel():
            if np.isfinite(n):
                self._norms.append(float(n))
        self.total_quarantined += int(quarantined)
        self.total_clipped += int(clipped)

    # ---- checkpoint round-trip (resume must be bitwise) ----

    def state_dict(self) -> dict:
        return {"norms": [float(n) for n in self._norms],
                "total_quarantined": int(self.total_quarantined),
                "total_clipped": int(self.total_clipped)}

    def load_state_dict(self, state: dict) -> None:
        self._norms = deque(state["norms"], maxlen=int(self.config.window))
        self.total_quarantined = int(state.get("total_quarantined", 0))
        self.total_clipped = int(state.get("total_clipped", 0))
