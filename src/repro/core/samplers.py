"""Pluggable client-participation samplers (DESIGN.md §3).

The paper's core claim is about *partial client participation*; which
clients show up each round is a modeling axis of its own (FedVARP's
uniform-without-replacement, size-weighted availability, cyclic block
schedules, Markov on/off availability — arXiv:2207.14130, 2506.02887).
``ClientSampler`` is the protocol the ``FederatedTrainer`` drives:

    sampler.sample(rng, round) -> (cohort_size,) int ndarray of client ids

Contract:
  * ``rng`` is the trainer's ``np.random.RandomState``.  Samplers draw
    from it IN ROUND ORDER (the cohort prefetcher stages rounds
    sequentially) and must consume the same draws for the same round
    regardless of prefetching — determinism is what makes a prefetched
    run reproduce a blocking one, and what makes checkpoint/resume
    reproduce an uninterrupted run.
  * The returned cohort has EXACTLY ``cohort_size`` distinct ids: the
    fused cohort round is one jit'd program per (K, M) shape bucket, so
    K must not vary across rounds.
  * Samplers with internal evolution (``MarkovSampler``'s availability
    vector) expose it via ``state_dict()``/``load_state_dict()`` so
    ``FederatedTrainer.save()`` can checkpoint mid-chain.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Optional, Sequence

import numpy as np


def _digest_p(p) -> str:
    """Canonical digest of a probability vector: sha256 over the
    float64 little-endian bytes."""
    arr = np.ascontiguousarray(np.asarray(p, np.float64))
    if arr.dtype.byteorder == ">":        # canonicalize on BE hosts
        arr = arr.astype("<f8")
    return hashlib.sha256(arr.tobytes()).hexdigest()


def normalize_sampler_config(cfg: Dict) -> Dict:
    """Resume-compat shim for sampler config echoes: sidecars written
    before WeightedSampler switched to digest+length carry the full
    ``"p"`` vector — rewrite them to the digest form so old checkpoints
    still compare equal against a live ``config_dict()``. Non-legacy
    configs pass through unchanged."""
    if "p" in cfg:
        cfg = dict(cfg)
        p = cfg.pop("p")
        cfg["p_digest"] = _digest_p(p)
        cfg["p_len"] = int(len(p))
    return cfg


class ClientSampler:
    """Protocol + base class: subclass and implement ``sample``."""

    num_clients: int
    cohort_size: int

    def sample(self, rng: np.random.RandomState, round: int) -> np.ndarray:
        raise NotImplementedError

    # ---- checkpointing (stateless samplers need nothing) ----

    def state_dict(self) -> Dict:
        return {}

    def load_state_dict(self, state: Dict) -> None:
        pass

    def config_dict(self) -> Dict:
        """JSON echo of the CONSTRUCTOR parameterization (as opposed to
        the evolving ``state_dict``): ``FederatedTrainer.restore``
        compares it against the checkpointed echo so a resume with a
        differently-built sampler fails loudly instead of silently
        diverging. Subclasses with extra knobs must extend it."""
        return {"class": type(self).__name__,
                "num_clients": self.num_clients,
                "cohort_size": self.cohort_size}


class UniformSampler(ClientSampler):
    """Uniform without replacement — the paper's (and the seed repo's)
    participation model.  Draw-for-draw identical to the historical
    inlined ``FederatedTrainer._sample_clients``, which the old-vs-new
    surface equivalence test relies on."""

    def __init__(self, num_clients: int, cohort_size: int):
        if not 1 <= cohort_size <= num_clients:
            raise ValueError((cohort_size, num_clients))
        self.num_clients = num_clients
        self.cohort_size = cohort_size

    def sample(self, rng, round):
        return rng.choice(self.num_clients, size=self.cohort_size,
                          replace=False)


class WeightedSampler(ClientSampler):
    """Weighted-by-data-size (or any importance weight) participation
    without replacement: clients with more data are proportionally more
    likely to be available.  Zero-weight clients never participate."""

    def __init__(self, weights: Sequence[float], cohort_size: int):
        w = np.asarray(weights, np.float64)
        if w.ndim != 1 or (w < 0).any() or w.sum() <= 0:
            raise ValueError("weights must be a non-negative 1-D vector "
                             "with positive sum")
        if int((w > 0).sum()) < cohort_size:
            raise ValueError(f"only {int((w > 0).sum())} clients have "
                             f"positive weight; cannot draw {cohort_size}")
        self.num_clients = len(w)
        self.cohort_size = cohort_size
        self.p = w / w.sum()

    def sample(self, rng, round):
        return rng.choice(self.num_clients, size=self.cohort_size,
                          replace=False, p=self.p)

    def config_dict(self):
        # digest + length, NOT the raw vector: the echo lives in the
        # JSON checkpoint sidecar and is string-compared on every
        # resume — an O(num_clients) float list bloats both at scale.
        # sha256 over the canonical float64 bytes is exact (the vector
        # is already float64; JSON float round-trips are value-exact so
        # legacy sidecars normalize to the same digest).
        return {**super().config_dict(),
                "p_digest": _digest_p(self.p), "p_len": int(len(self.p))}


class CyclicSampler(ClientSampler):
    """Deterministic cyclic / block participation: round t takes the
    contiguous block starting at ``t * cohort_size (mod num_clients)``
    (arXiv:2506.02887's "cyclic" regime — every client participates at a
    fixed cadence; no RNG draws are consumed)."""

    def __init__(self, num_clients: int, cohort_size: int):
        if not 1 <= cohort_size <= num_clients:
            raise ValueError((cohort_size, num_clients))
        self.num_clients = num_clients
        self.cohort_size = cohort_size

    def sample(self, rng, round):
        start = (round * self.cohort_size) % self.num_clients
        return (start + np.arange(self.cohort_size)) % self.num_clients


class MarkovSampler(ClientSampler):
    """Two-state Markov availability per client (the intermittent-client
    regime): an available client drops out with prob ``p_off``, an
    unavailable one returns with prob ``p_on``; the cohort is drawn
    uniformly from the available set.  If fewer than ``cohort_size``
    clients are up, the shortfall is drafted uniformly from the
    unavailable ones so K stays constant (the jit shape bucket).

    The availability vector is sampler STATE and is checkpointed through
    ``state_dict`` — resuming mid-chain continues the exact trajectory.
    """

    def __init__(self, num_clients: int, cohort_size: int,
                 p_on: float = 0.5, p_off: float = 0.5):
        if not 1 <= cohort_size <= num_clients:
            raise ValueError((cohort_size, num_clients))
        if not (0.0 < p_on <= 1.0 and 0.0 <= p_off <= 1.0):
            raise ValueError((p_on, p_off))
        self.num_clients = num_clients
        self.cohort_size = cohort_size
        self.p_on = p_on
        self.p_off = p_off
        self._avail: Optional[np.ndarray] = None    # (n,) bool

    def sample(self, rng, round):
        if self._avail is None:
            # stationary distribution of the two-state chain
            pi = self.p_on / max(self.p_on + self.p_off, 1e-12)
            self._avail = rng.rand(self.num_clients) < pi
        else:
            u = rng.rand(self.num_clients)
            up = self._avail
            self._avail = np.where(up, u >= self.p_off, u < self.p_on)
        up_ids = np.flatnonzero(self._avail)
        down_ids = np.flatnonzero(~self._avail)
        k = self.cohort_size
        if len(up_ids) >= k:
            return rng.choice(up_ids, size=k, replace=False)
        drafted = rng.choice(down_ids, size=k - len(up_ids), replace=False)
        cohort = np.concatenate([up_ids, drafted])
        # RNG contract: the shortfall branch consumes exactly TWO draws
        # (choice + permutation) vs the normal branch's one. The shuffle
        # matters: returning sorted up_ids first leaked availability
        # through cohort position and gave the two branches different
        # padded-cohort layouts (position-sensitive downstream: pad
        # masks, per-slot diagnostics).
        return cohort[rng.permutation(k)]

    def state_dict(self):
        return {} if self._avail is None else {
            "avail": self._avail.astype(np.uint8).tolist()}

    def load_state_dict(self, state):
        self._avail = (np.asarray(state["avail"], np.uint8).astype(bool)
                       if state.get("avail") is not None else None)

    def config_dict(self):
        return {**super().config_dict(),
                "p_on": self.p_on, "p_off": self.p_off}


def sampler_matrix(num_clients: int, cohort_size: int
                   ) -> Dict[str, ClientSampler]:
    """One representatively-configured instance of every sampler — the
    participation axis of the cross-regime equivalence matrix
    (tests/test_regime_matrix.py).  A NEW sampler class added to this
    module registers an instance here and the matrix auto-enrolls it.
    Weighted uses size-proportional weights (1..n) so the draw is
    genuinely non-uniform; Markov uses asymmetric on/off rates so the
    availability chain actually evolves."""
    return {
        "uniform": UniformSampler(num_clients, cohort_size),
        "weighted": WeightedSampler(
            np.arange(1, num_clients + 1, dtype=np.float64), cohort_size),
        "cyclic": CyclicSampler(num_clients, cohort_size),
        "markov": MarkovSampler(num_clients, cohort_size,
                                p_on=0.7, p_off=0.4),
    }
