"""DEPRECATED shim — the DataSource protocol moved to ``repro.ingest``
(ingest/sources.py; DESIGN.md §10) as the read stage of the staged
ingest subsystem.

Importing from this module still works for one release but warns
(attributed to the caller — the CI gate errors on DeprecationWarnings
raised FROM repro.*, so library code must import ``repro.ingest``
directly). The forwarded objects are IDENTICAL to the new ones —
``isinstance`` checks and subclasses keep working across the move.
"""
from __future__ import annotations

import warnings

_MOVED = ("DataSource", "ListDataSource", "IteratorDataSource",
          "as_data_source")


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.core.datasources.{name} moved to repro.ingest.{name} "
            "(DESIGN.md §10); this alias will be removed next release",
            DeprecationWarning, stacklevel=2)
        import repro.ingest
        return getattr(repro.ingest, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
