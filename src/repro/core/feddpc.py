"""FedDPC server optimizer (paper Algorithm 1, lines 15-19).

Pure, jit-able server step over a *stacked* batch of client updates
(leading axis = participating clients).  The projection/scaling math per
client lives in core/projection.py; here we vmap it over the client axis
and aggregate.

Server state is exactly one pytree: ``delta_prev`` (the previous global
update Delta_{t-1}) — the method is stateless on clients, which is what
makes it robust to low-rate partial participation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import projection as proj

PyTree = Any


def init_state(params: PyTree) -> Dict[str, PyTree]:
    """Delta_0 -> 0 (paper input line): projection onto a zero vector is 0,
    so round 1 degenerates to two-sided-LR FedAvg with the lam+1 scaling
    factor exactly as the paper's ablation baseline."""
    return {"delta_prev": proj.tree_zeros_like(params)}


def server_step(state: Dict[str, PyTree], params: PyTree, deltas: PyTree,
                eta_g: float, lam: float = 1.0, use_kernel: bool = False,
                client_mask=None, model_sharded: bool = False,
                staleness_weights=None, encoded=None, edges=None
                ) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jnp.ndarray]]:
    """One FedDPC aggregation.

    deltas: client-stacked pytree — every leaf has leading axis k'
    (participating clients), leaf[j] = Delta_{jt} = (w_{t-1} - w_{jt})/eta_l.

    client_mask (k',) bool marks the REAL rows when the sharded path pads
    the cohort (DESIGN.md §2).  The per-client transform
    scale_j * (d_j - coef_j * prev) is linear in (scale_j, coef_j), so
    masking folds EXACTLY into the reduction-pass scalars: dummy rows get
    scale=coef=0 and the survivors renormalize by k'/n_valid — the
    unchanged mean-over-k' epilogue (jnp or Pallas) then computes the
    mean over real clients only, with no kernel changes.

    model_sharded=True declares that the delta/param leaves are
    PARTITIONED over a mesh model axis (the two-axis cohort round,
    DESIGN.md §2). The reduction-pass scalars need no change: they go
    through dim-preserving per-leaf sums (projection.tree_vdot), so
    GSPMD psums the ||Δ||² / <Δ, Δ_prev> partials across the model axis
    before ``scale``/``coef`` are formed. The Pallas epilogue, however,
    flattens every leaf (reshaping a partitioned leaf forces an
    all-gather) and pallas_call does not partition under GSPMD, so
    ``use_kernel`` falls back to the reference jnp epilogue — which is
    elementwise on the local shards and exact.

    staleness_weights (k',) f32 are the buffered-async discount factors
    (core/async_engine.py, DESIGN.md §11): each buffered delta's weight
    (1+s)^(-alpha) multiplies its adaptive SCALE, so the projection
    geometry (coef, the diagnostics) is computed on the raw delta and
    only the applied magnitude is discounted — the staleness-discounted
    projection coefficient folds into the same reduction-pass scalars
    the masked path uses, leaving the epilogue unchanged. At staleness
    0 every weight is exactly 1.0 and the step is the synchronous one.

    ``edges=E`` runs the aggregation as a two-level hierarchical fold
    (DESIGN.md §15): the k' rows split into E equal contiguous edge
    groups, each edge folds its slice — the SAME fused epilogue
    (including the Pallas and codec-dequant grids) over k'/E rows — to a
    partial reduction-pass sum, and the server combines the E partials.
    The per-client transform scale_j*(d_j - coef_j*prev) derives from
    dim-preserving scalars, so the scalars (and the mask/staleness
    folding above) are UNCHANGED; only the final mean decomposes, into
    mean-of-means over equal groups — exact up to float summation order.
    E must divide k'.

    ``encoded`` is the codec wire payload ({"q", "scale", "zero"} trees,
    repro/codec) whose dequant — ``q * scale + zero`` — reproduces
    ``deltas`` exactly. The reduction-pass scalars are still computed on
    the decoded ``deltas`` (4 dots per client, cheap); with ``use_kernel``
    the epilogue routes to the fused dequant→residual→scale→mean grid
    (kernels/feddpc_project.dequant_*), which reads the int8/bf16 stack
    straight from HBM — the full-precision decoded stack never makes a
    second full-memory pass. Ignored on the model-sharded fallback.

    Returns (new_params, new_state, diagnostics).
    """
    if model_sharded:
        use_kernel = False
    delta_prev = state["delta_prev"]

    # reduction pass: per-client scalars (4 dots each, vmapped over K)
    coefs, scales, diag = jax.vmap(
        lambda d: proj.projection_scalars(d, delta_prev, lam))(deltas)
    if client_mask is None:
        diag_mean = jnp.mean
    else:
        mf = client_mask.astype(jnp.float32)
        nvalid = jnp.maximum(mf.sum(), 1.0)
        coefs = coefs * mf
        scales = scales * mf * (mf.shape[0] / nvalid)
        diag_mean = lambda x: jnp.sum(x * mf) / nvalid
    wgt = (None if staleness_weights is None
           else jnp.asarray(staleness_weights, jnp.float32))
    E = int(edges) if edges is not None and int(edges) > 1 else None
    if E is not None:
        k_rows = int(coefs.shape[0])
        if k_rows % E:
            raise ValueError(f"edges={E} must divide the cohort rows "
                             f"({k_rows})")
    if use_kernel:
        # epilogue pass: residual+scale, client-mean (Eq. 4) AND the param
        # update fused into ONE grid over the stacked deltas
        # (kernels/feddpc_project.batched_epilogue) — one HBM pass instead
        # of K per-client kernel calls + two more full passes. The
        # buffered-async fold routes to the scatter-accumulate variant,
        # which applies the staleness discount inside the grid.
        from repro.kernels.feddpc_project import ops as k_ops

        def kernel_fold(d_slice, enc_slice, c_slice, s_slice, w_slice):
            if enc_slice is not None and w_slice is None:
                return k_ops.dequant_batched_server_epilogue(
                    enc_slice, delta_prev, params, c_slice, s_slice, eta_g)
            if enc_slice is not None:
                return k_ops.dequant_buffered_server_fold(
                    enc_slice, delta_prev, params, c_slice, s_slice,
                    w_slice, eta_g)
            if w_slice is None:
                return k_ops.batched_server_epilogue(
                    d_slice, delta_prev, params, c_slice, s_slice, eta_g)
            return k_ops.buffered_server_fold(
                d_slice, delta_prev, params, c_slice, s_slice, w_slice,
                eta_g)

        if E is None:
            new_params, delta_t = kernel_fold(deltas, encoded, coefs,
                                              scales, wgt)
        else:
            # two-level fold: each edge runs the SAME fused grid (Pallas
            # epilogue / codec dequant included) over its k'/E-row slice
            # — its partial mean is the edge→server summary — and the
            # server averages the E partials (equal groups: exact)
            rows = k_rows // E
            def rsl(tree, lo, hi):
                return jax.tree.map(lambda x: x[lo:hi], tree)
            parts = []
            for e in range(E):
                lo, hi = e * rows, (e + 1) * rows
                _, part = kernel_fold(
                    rsl(deltas, lo, hi),
                    None if encoded is None else rsl(encoded, lo, hi),
                    coefs[lo:hi], scales[lo:hi],
                    None if wgt is None else wgt[lo:hi])
                parts.append(part)
            delta_t = jax.tree.map(
                lambda *xs: jnp.mean(jnp.stack(xs), axis=0), *parts)
            new_params = jax.tree.map(
                lambda w, d: (w.astype(jnp.float32)
                              - eta_g * d).astype(w.dtype),
                params, delta_t)
    else:
        if wgt is not None:
            scales = scales * wgt
        def bc(s, x):
            return s.reshape((-1,) + (1,) * (x.ndim - 1))

        def mean_rows(x):
            if E is None:
                return jnp.mean(x, axis=0)
            # two-level: per-edge partial means, then the mean of the E
            # edge summaries — the mask/staleness weights are already
            # folded into the scales, so the decomposition is exact
            return jnp.mean(jnp.mean(
                x.reshape((E, x.shape[0] // E) + x.shape[1:]), axis=1),
                axis=0)

        # scaled residual + mean over the client axis (Eq. 4)
        delta_t = jax.tree.map(
            lambda d, p: mean_rows(
                bc(scales, d) * (d.astype(jnp.float32)
                                 - bc(coefs, d) * p.astype(jnp.float32)[None])),
            deltas, delta_prev)
        new_params = jax.tree.map(
            lambda w, d: (w.astype(jnp.float32) - eta_g * d).astype(w.dtype),
            params, delta_t)
    new_state = {"delta_prev": delta_t}
    diagnostics = {
        "mean_coef": diag_mean(diag["coef"]),
        "mean_cos_angle": diag_mean(diag["cos_angle"]),
        "mean_scale": diag_mean(diag["scale"]),
        "mean_norm_delta": diag_mean(diag["norm_delta"]),
        "norm_global_update": proj.tree_norm(delta_t),
        # orthogonality invariant: <Delta_t, Delta_{t-1}> ~ 0 after round 1
        "global_dot_prev": proj.tree_vdot(delta_t, delta_prev),
    }
    return new_params, new_state, diagnostics


def server_step_projection_only(state, params, deltas, eta_g,
                                client_mask=None, edges=None
                                ) -> Tuple[PyTree, Dict, Dict]:
    """Ablation: orthogonal projection WITHOUT adaptive scaling (paper Fig 6,
    blue line). Equivalent to lam-scaling with scale == 1."""
    delta_prev = state["delta_prev"]

    def one(d):
        coef = proj.project_coefficient(d, delta_prev)
        return jax.tree.map(
            lambda di, pi: (di.astype(jnp.float32)
                            - coef * pi.astype(jnp.float32)).astype(di.dtype),
            d, delta_prev)

    resid = jax.vmap(one)(deltas)
    delta_t = proj.masked_client_mean(resid, client_mask, edges=edges)
    new_params = jax.tree.map(
        lambda w, d: (w.astype(jnp.float32) - eta_g * d).astype(w.dtype),
        params, delta_t)
    return new_params, {"delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t)}
