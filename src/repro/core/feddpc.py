"""FedDPC server optimizer (paper Algorithm 1, lines 15-19).

Pure, jit-able server step over a *stacked* batch of client updates
(leading axis = participating clients).  The projection/scaling math per
client lives in core/projection.py; here we vmap it over the client axis
and aggregate.

Server state is exactly one pytree: ``delta_prev`` (the previous global
update Delta_{t-1}) — the method is stateless on clients, which is what
makes it robust to low-rate partial participation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import projection as proj

PyTree = Any


def init_state(params: PyTree) -> Dict[str, PyTree]:
    """Delta_0 -> 0 (paper input line): projection onto a zero vector is 0,
    so round 1 degenerates to two-sided-LR FedAvg with the lam+1 scaling
    factor exactly as the paper's ablation baseline."""
    return {"delta_prev": proj.tree_zeros_like(params)}


def server_step(state: Dict[str, PyTree], params: PyTree, deltas: PyTree,
                eta_g: float, lam: float = 1.0, use_kernel: bool = False
                ) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jnp.ndarray]]:
    """One FedDPC aggregation.

    deltas: client-stacked pytree — every leaf has leading axis k'
    (participating clients), leaf[j] = Delta_{jt} = (w_{t-1} - w_{jt})/eta_l.

    Returns (new_params, new_state, diagnostics).
    """
    delta_prev = state["delta_prev"]

    scaled, diag = jax.vmap(
        lambda d: proj.project_and_scale(d, delta_prev, lam,
                                         use_kernel=use_kernel))(deltas)
    # aggregate: mean over the client axis (Eq. 4)
    delta_t = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0),
                           scaled)
    new_params = jax.tree.map(
        lambda w, d: (w.astype(jnp.float32) - eta_g * d).astype(w.dtype),
        params, delta_t)
    new_state = {"delta_prev": delta_t}
    diagnostics = {
        "mean_coef": diag["coef"].mean(),
        "mean_cos_angle": diag["cos_angle"].mean(),
        "mean_scale": diag["scale"].mean(),
        "mean_norm_delta": diag["norm_delta"].mean(),
        "norm_global_update": proj.tree_norm(delta_t),
        # orthogonality invariant: <Delta_t, Delta_{t-1}> ~ 0 after round 1
        "global_dot_prev": proj.tree_vdot(delta_t, delta_prev),
    }
    return new_params, new_state, diagnostics


def server_step_projection_only(state, params, deltas, eta_g
                                ) -> Tuple[PyTree, Dict, Dict]:
    """Ablation: orthogonal projection WITHOUT adaptive scaling (paper Fig 6,
    blue line). Equivalent to lam-scaling with scale == 1."""
    delta_prev = state["delta_prev"]

    def one(d):
        coef = proj.project_coefficient(d, delta_prev)
        return jax.tree.map(
            lambda di, pi: (di.astype(jnp.float32)
                            - coef * pi.astype(jnp.float32)).astype(di.dtype),
            d, delta_prev)

    resid = jax.vmap(one)(deltas)
    delta_t = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0),
                           resid)
    new_params = jax.tree.map(
        lambda w, d: (w.astype(jnp.float32) - eta_g * d).astype(w.dtype),
        params, delta_t)
    return new_params, {"delta_prev": delta_t}, {
        "norm_global_update": proj.tree_norm(delta_t)}
