"""Orthogonal projection + adaptive scaling on parameter pytrees —
the mathematical core of FedDPC (paper §4.1–4.2, Fig. 2).

All reductions are computed leaf-wise in float32 and summed, which is
exactly the flat-vector semantics of the paper (the model update is one
vector in R^d). Under pjit these per-leaf partial dots reduce over every
model-sharding axis automatically (DESIGN.md §2): FedDPC's server step
costs 4 scalar all-reduces + elementwise work.

The fused single-HBM-pass version of ``residual_and_scale_apply`` is the
Pallas kernel in kernels/feddpc_project; ``use_kernel=True`` routes
through it (interpret mode on CPU).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
EPS = 1e-12


def tree_vdot(a: PyTree, b: PyTree) -> jnp.ndarray:
    """<a, b> over the flattened parameter vector, f32.

    NOTE: summed with jnp.sum(x*y) per leaf, NOT jnp.vdot — vdot RAVELS
    its operands, and reshaping a model-sharded leaf to 1-D makes GSPMD
    all-gather it (13.5x the FedAvg round's collective volume before this
    fix — EXPERIMENTS.md §Perf hillclimb 3). A dim-preserving reduction
    lowers to local partial sums + one scalar psum per leaf."""
    parts = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32)
                                          * y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(parts)) if parts else jnp.zeros((), jnp.float32)


def tree_sqnorm(a: PyTree) -> jnp.ndarray:
    return tree_vdot(a, a)


def tree_norm(a: PyTree) -> jnp.ndarray:
    return jnp.sqrt(tree_sqnorm(a))


def tree_nonfinite_count(a: PyTree) -> jnp.ndarray:
    """Number of non-finite (NaN/Inf) entries over the flattened vector,
    f32 scalar — the update guard's validity reduction (DESIGN.md §12).
    Dim-preserving per-leaf sums like tree_vdot, so it shares the HBM
    pass with the reduction scalars and GSPMD psums the partials; the
    fused Pallas form is kernels/feddpc_project.guard_dots."""
    parts = jax.tree.leaves(
        jax.tree.map(lambda x: jnp.sum((~jnp.isfinite(x)).astype(jnp.float32)),
                     a))
    return jnp.sum(jnp.stack(parts)) if parts else jnp.zeros((), jnp.float32)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y (alpha scalar)."""
    return jax.tree.map(
        lambda xi, yi: (alpha * xi.astype(jnp.float32)
                        + yi.astype(jnp.float32)).astype(yi.dtype), x, y)


def tree_scale(alpha, x: PyTree) -> PyTree:
    return jax.tree.map(
        lambda xi: (alpha * xi.astype(jnp.float32)).astype(xi.dtype), x)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x, y: (x.astype(jnp.float32) - y.astype(jnp.float32)
                      ).astype(x.dtype), a, b)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x, y: (x.astype(jnp.float32) + y.astype(jnp.float32)
                      ).astype(x.dtype), a, b)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def masked_client_mean(tree: PyTree, client_mask=None, *,
                       edges=None) -> PyTree:
    """f32 mean over the leading (client) axis of every leaf; with
    ``client_mask`` (K,) bool the mean runs over the True rows only —
    padded dummy clients (DESIGN.md §2) contribute zero to the numerator
    AND the denominator. The single implementation every server rule's
    aggregation goes through.

    ``edges=E`` expresses the SAME mean as a two-level hierarchical fold
    (DESIGN.md §15): the K rows split into E equal contiguous groups —
    one per edge aggregator — each edge reduces its slice to a partial
    sum (and a partial live count), and the server combines the E
    partials. Because the mask folds into per-row weights, the two-level
    value equals the flat mean exactly up to float summation order; the
    explicit (E, K/E) reshape is the single-process expression of the
    fold that, on a process-spanning clients mesh, keeps cross-host
    traffic to E partial summaries instead of K raw rows."""
    if edges is not None and int(edges) > 1:
        E = int(edges)

        def two_level(x):
            x = x.astype(jnp.float32)
            k = x.shape[0]
            if k % E:
                raise ValueError(
                    f"edges={E} must divide the client axis ({k})")
            xs = x.reshape((E, k // E) + x.shape[1:])
            if client_mask is None:
                # equal group sizes: mean-of-means is exact
                return jnp.mean(jnp.mean(xs, axis=1), axis=0)
            w = client_mask.astype(jnp.float32).reshape(
                (E, k // E) + (1,) * (x.ndim - 1))
            part = jnp.sum(xs * w, axis=1)          # (E, ...) edge sums
            live = jnp.sum(client_mask.astype(jnp.float32)
                           .reshape(E, k // E), axis=1)   # (E,) counts
            return jnp.sum(part, axis=0) / jnp.maximum(jnp.sum(live), 1.0)

        return jax.tree.map(two_level, tree)
    if client_mask is None:
        return jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0), tree)
    mf = client_mask.astype(jnp.float32)
    nvalid = jnp.maximum(mf.sum(), 1.0)

    def one(x):
        w = mf.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0) / nvalid

    return jax.tree.map(one, tree)


def project_coefficient(delta: PyTree, delta_prev: PyTree) -> jnp.ndarray:
    """coef such that Proj_{prev}(delta) = coef * prev. Zero-safe: when
    ||prev|| == 0 (round 1, Delta_0 -> 0) the projection is 0."""
    num = tree_vdot(delta, delta_prev)
    den = tree_sqnorm(delta_prev)
    return jnp.where(den > EPS, num / jnp.maximum(den, EPS), 0.0)


def projection_scalars(delta: PyTree, delta_prev: PyTree, lam: float
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """The reduction half of FedDPC's per-client modification: the three
    dots <d,prev>, ||d||², ||prev||² and everything derived from them.

    Returns (coef, scale, diagnostics) — the epilogue (residual + scaling,
    applied either in jnp or by the Pallas kernel) only needs the two
    scalars. ||delta||² is reduced ONCE and reused for both ||delta|| and
    the Pythagoras residual norm; ||resid||² = ||d||² - coef²||prev||²
    avoids a full extra pass over the parameters.
    """
    num = tree_vdot(delta, delta_prev)
    sq_prev = tree_sqnorm(delta_prev)
    sq_d = tree_sqnorm(delta)
    coef = jnp.where(sq_prev > EPS, num / jnp.maximum(sq_prev, EPS), 0.0)
    norm_d = jnp.sqrt(sq_d)
    sq_resid = jnp.maximum(sq_d - coef * coef * sq_prev, 0.0)
    norm_r = jnp.sqrt(sq_resid)
    scale = lam + norm_d / jnp.maximum(norm_r, EPS)
    diag = {"coef": coef, "norm_delta": norm_d, "norm_resid": norm_r,
            "scale": scale,
            "cos_angle": jnp.where(norm_d > EPS,
                                   coef * jnp.sqrt(sq_prev)
                                   / jnp.maximum(norm_d, EPS),
                                   0.0)}
    return coef, scale, diag


def project_and_scale(delta: PyTree, delta_prev: PyTree, lam: float,
                      use_kernel: bool = False) -> Tuple[PyTree, dict]:
    """Paper Algorithm 1 lines 17–17b for ONE client update:

        resid  = delta - Proj_{delta_prev}(delta)
        scaled = (lam + ||delta|| / ||resid||) * resid

    Returns (scaled_residual, diagnostics).
    """
    coef, scale, diag = projection_scalars(delta, delta_prev, lam)

    if use_kernel:
        from repro.kernels.feddpc_project import ops as k_ops
        scaled = k_ops.residual_scale_tree(delta, delta_prev, coef, scale)
    else:
        scaled = jax.tree.map(
            lambda d, p: (scale * (d.astype(jnp.float32)
                                   - coef * p.astype(jnp.float32))).astype(d.dtype),
            delta, delta_prev)
    return scaled, diag
