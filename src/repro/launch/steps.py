"""Step builders + ShapeDtypeStruct input specs for every (arch x shape).

Shapes map to step kinds (configs/shapes.py):
  train_4k    -> train_step    fwd + bwd + SGD apply on the full batch
  prefill_32k -> prefill_step  full-sequence forward writing caches/states
  decode_32k  -> decode_step   ONE token against a seq_len cache
  long_500k   -> decode_step   sub-quadratic: SSM/hybrid decode natively;
                               dense archs use the sliding-window variant
                               (ring cache of WINDOW tokens — DESIGN.md §7)

Everything here is ShapeDtypeStruct-only until jit/lower time: no real
allocation ever happens for the full-size configs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf

PyTree = Any
WINDOW = 4096                 # sliding window for dense long-context decode


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def effective_window(cfg: ArchConfig, shape: InputShape) -> int:
    if shape.long_context and cfg.arch_type not in ("ssm", "hybrid"):
        return WINDOW
    return cfg.sliding_window


def cache_capacity(cfg: ArchConfig, shape: InputShape) -> int:
    w = effective_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


# ---------------- input specs ----------------

def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the *data* arguments of the step.
    (params/state specs come from params_spec / states_spec below.)"""
    b, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    tok = jnp.int32
    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            # audio: stubbed frame embeddings + text targets
            dec_len = min(s, cfg.max_seq_len)
            return {"batch": {
                "frames": _sds((b, cfg.encoder_seq_len, cfg.d_model), dtype),
                "tokens": _sds((b, dec_len), tok),
                "labels": _sds((b, dec_len), tok)}}
        batch = {"tokens": _sds((b, s), tok), "labels": _sds((b, s), tok)}
        if cfg.modality == "vision":
            p = cfg.num_patches
            batch = {"tokens": _sds((b, s - p), tok),
                     "labels": _sds((b, s - p), tok),
                     "patch_embeds": _sds((b, p, cfg.d_model), dtype)}
        return {"batch": batch}
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            dec_len = min(s, cfg.max_seq_len)
            return {"frames": _sds((b, cfg.encoder_seq_len, cfg.d_model), dtype),
                    "tokens": _sds((b, dec_len), tok)}
        specs = {"tokens": _sds((b, s), tok)}
        if cfg.modality == "vision":
            p = cfg.num_patches
            specs = {"tokens": _sds((b, s - p), tok),
                     "patch_embeds": _sds((b, p, cfg.d_model), dtype)}
        return specs
    # decode: ONE new token; cache already holds shape.seq_len history
    return {"tokens": _sds((b, 1), tok),
            "positions": _sds((b, 1), tok)}


def params_spec(cfg: ArchConfig) -> PyTree:
    key = jax.random.PRNGKey(0)
    if cfg.is_encoder_decoder:
        return jax.eval_shape(lambda k: encdec_mod.init_encdec(cfg, k), key)
    return jax.eval_shape(lambda k: tf.init_lm(cfg, k), key)


def states_spec(cfg: ArchConfig, shape: InputShape) -> PyTree:
    cap = cache_capacity(cfg, shape)
    b = shape.global_batch
    dtype = jnp.dtype(cfg.dtype)
    if cfg.is_encoder_decoder:
        dec = jax.eval_shape(
            lambda: encdec_mod.init_decoder_states(cfg, b, cap, dtype))
        return {"decoder": dec,
                "enc_out": _sds((b, cfg.encoder_seq_len, cfg.d_model), dtype)}
    return jax.eval_shape(lambda: tf.init_states(cfg, b, cap, dtype))


# ---------------- steps ----------------

def make_train_step(cfg: ArchConfig, lr: float = 1e-3, remat: str = "full",
                    attn_impl: str = "auto", moe_groups: int = 1,
                    shard_fn=None, scan_unroll: int = 1,
                    moe_impl: str = "gshard", moe_mesh=None,
                    microbatches: int = 1):
    """(params, batch) -> (new_params, loss). Plain SGD so the lowered
    artifact is fwd+bwd+apply (the paper's local client step).

    microbatches > 1 splits the global batch into M sequential grad-
    accumulation chunks (lax.scan): live activation memory scales 1/M at
    ~zero collective cost (the gradient all-reduce happens once on the
    accumulated f32 grads) — §Perf hillclimb 1 iteration 3."""
    if cfg.is_encoder_decoder:
        def loss(params, batch):
            return encdec_mod.encdec_loss_fn(cfg, params, batch,
                                             attn_impl=attn_impl,
                                             scan_unroll=scan_unroll)
    else:
        def loss(params, batch):
            return tf.loss_fn(cfg, params, batch, remat=remat,
                              attn_impl=attn_impl, moe_groups=moe_groups,
                              shard_fn=shard_fn, scan_unroll=scan_unroll,
                              moe_impl=moe_impl, moe_mesh=moe_mesh)

    def train_step(params, batch):
        if microbatches <= 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, chunk):
                l_sum, g_acc = carry
                l, g = jax.value_and_grad(loss)(params, chunk)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (l_sum + l, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (l, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), g0), mb)
            l = l / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads)
        return new_params, l

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: InputShape,
                      attn_impl: str = "auto", moe_groups: int = 1,
                      shard_fn=None, scan_unroll: int = 1,
                      moe_impl: str = "gshard", moe_mesh=None):
    """(params, states, **inputs) -> (new_states, last_token_logits)."""
    window = effective_window(cfg, shape)

    if cfg.is_encoder_decoder:
        def prefill(params, states, frames, tokens):
            enc_out = encdec_mod.encode(cfg, params, frames, attn_impl,
                                        scan_unroll)
            logits, dec_states = encdec_mod.decode(
                cfg, params, tokens, enc_out, states=states["decoder"],
                window=window, attn_impl=attn_impl, scan_unroll=scan_unroll)
            return ({"decoder": dec_states, "enc_out": enc_out},
                    logits[:, -1:, :])
        return prefill

    def prefill(params, states, tokens, patch_embeds=None):
        logits, new_states, _ = tf.lm_forward(
            cfg, params, tokens, embeds=patch_embeds, states=states,
            window=window, attn_impl=attn_impl, moe_groups=moe_groups,
            shard_fn=shard_fn, logits_slice_last=True,
            scan_unroll=scan_unroll, moe_impl=moe_impl, moe_mesh=moe_mesh)
        return new_states, logits

    return prefill


def make_decode_step(cfg: ArchConfig, shape: InputShape,
                     attn_impl: str = "auto", moe_groups: int = 1,
                     shard_fn=None, scan_unroll: int = 1,
                     moe_impl: str = "gshard", moe_mesh=None):
    """(params, states, tokens(B,1), positions(B,1)) -> (states, logits)."""
    window = effective_window(cfg, shape)

    if cfg.is_encoder_decoder:
        def decode(params, states, tokens, positions):
            logits, dec_states = encdec_mod.decode(
                cfg, params, tokens, states["enc_out"], positions=positions,
                states=states["decoder"], window=window, attn_impl=attn_impl,
                scan_unroll=scan_unroll)
            return ({"decoder": dec_states, "enc_out": states["enc_out"]},
                    logits)
        return decode

    def decode(params, states, tokens, positions):
        logits, new_states, _ = tf.lm_forward(
            cfg, params, tokens, positions=positions, states=states,
            window=window, attn_impl=attn_impl, moe_groups=moe_groups,
            shard_fn=shard_fn, logits_slice_last=True,
            scan_unroll=scan_unroll, moe_impl=moe_impl, moe_mesh=moe_mesh)
        return new_states, logits

    return decode


def make_step(cfg: ArchConfig, shape: InputShape, **kw):
    """Uniform entry: returns (step_fn, arg_specs_dict, has_states)."""
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        return make_train_step(cfg, **kw), specs, False
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, **kw), specs, True
    return make_decode_step(cfg, shape, **kw), specs, True
