# Launch environment hygiene for benchmarks and training runs.
#
# Source this before any python entry point (benchmarks, launch/train.py,
# CI bench lanes):
#
#     source src/repro/launch/env.sh            # defaults
#     REPRO_HOST_DEVICES=8 source src/repro/launch/env.sh
#
# Every setting is additive and overridable: values already exported by
# the caller win, so CI lanes can pin their own device counts and local
# users their own allocator.

# --- allocator: tcmalloc beats glibc malloc for the host-side staging
# ring's large short-lived cohort buffers, but only preload it where it
# actually exists (CI runners and dev boxes differ)
if [ -z "${LD_PRELOAD:-}" ]; then
  for _tcm in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
              /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
    if [ -e "${_tcm}" ]; then
      export LD_PRELOAD="${_tcm}"
      break
    fi
  done
  unset _tcm
fi
# numpy's big staging allocations trip tcmalloc's large-alloc report;
# silence it (60 GB threshold) instead of spamming every bench log
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# --- log hygiene: drop TF/XLA C++ chatter below error level
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# --- dtype discipline: the repo's numerics are f32-by-default with
# explicit f64 host state (RNG caches); never let x64 flip globally
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# --- forced host devices: the CPU-backed sharded lanes tile a virtual
# device mesh; REPRO_HOST_DEVICES > 0 appends the XLA flag (callers that
# already set XLA_FLAGS keep whatever they exported — the flags compose)
if [ "${REPRO_HOST_DEVICES:-0}" -gt 0 ] 2>/dev/null; then
  export XLA_FLAGS="${XLA_FLAGS:+${XLA_FLAGS} }--xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
fi
