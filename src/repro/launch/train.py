"""End-to-end federated training driver (simulation mode — reproduces the
paper's experiments on synthetic heterogeneous data).

  PYTHONPATH=src python -m repro.launch.train \
      --model lenet5 --algorithm feddpc --rounds 50 --alpha 0.2 \
      --clients 100 --participation 0.1 --eta-l 0.01 --eta-g 0.01

Also supports federated *LM* training with any assigned architecture's
smoke config (--model starcoder2-3b etc.) — the beyond-paper scenario
(cross-silo federated pretraining).
"""
from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ARCH_IDS, get_config
from repro.core.api import FLConfig, FederatedTrainer
from repro.data.pipeline import build_federated_image_data, client_batches
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_lm_dataset
from repro.models import transformer as tf
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)


def build_vision_task(args):
    family = "lenet5" if args.model == "lenet5" else "resnet18"
    nclass = {"lenet5": 10, "resnet18-gn": args.num_classes}.get(
        args.model, args.num_classes)
    vc = VisionConfig(name=args.model, family=family, num_classes=nclass)
    data = build_federated_image_data(
        num_classes=nclass, num_clients=args.clients, alpha=args.alpha,
        samples_per_class=args.samples_per_class, seed=args.seed)
    params = init_vision(vc, jax.random.PRNGKey(args.seed))
    loss_fn = functools.partial(vision_loss_fn, vc)

    def batch_fn(c, t):
        return list(client_batches(data, c, args.batch_size, t,
                                   args.local_epochs))

    te_x = jnp.asarray(data.test_images)
    te_y = jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    return params, loss_fn, batch_fn, eval_fn, data.num_clients


def build_lm_task(args):
    cfg = get_config(args.model, smoke=True)
    tokens, topics = make_lm_dataset(args.clients * 16, args.seq_len,
                                     cfg.vocab_size, seed=args.seed)
    parts = dirichlet_partition(topics, args.clients, args.alpha,
                                seed=args.seed, min_size=1)
    params = tf.init_lm(cfg, jax.random.PRNGKey(args.seed), jnp.float32)

    def loss_fn(p, batch):
        return tf.loss_fn(cfg, p, batch)

    def batch_fn(c, t):
        idx = parts[c]
        rng = np.random.RandomState(hash((c, t)) % (2 ** 31))
        sel = idx[rng.permutation(len(idx))][:args.batch_size]
        if len(sel) < args.batch_size:
            sel = np.concatenate([sel] * ((args.batch_size // max(len(sel), 1))
                                          + 1))[:args.batch_size]
        tk = tokens[sel]
        return [{"tokens": tk[:, :-1], "labels": tk[:, 1:]}]

    ho = tokens[:64]
    ho_batch = {"tokens": jnp.asarray(ho[:, :-1]),
                "labels": jnp.asarray(ho[:, 1:])}

    @jax.jit
    def eval_fn(p):    # negative perplexity proxy -> "accuracy" slot
        return -loss_fn(p, ho_batch)

    return params, loss_fn, batch_fn, eval_fn, args.clients


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet5",
                    choices=["lenet5", "resnet18-gn", *ARCH_IDS])
    ap.add_argument("--algorithm", default="feddpc")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--participation", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--eta-l", type=float, default=0.01)
    ap.add_argument("--eta-g", type=float, default=0.01)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--samples-per-class", type=int, default=100)
    ap.add_argument("--num-classes", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--serial", action="store_true",
                    help="per-client dispatch instead of the fused "
                         "cohort-vectorized round (debug/reference path)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    if args.model in ("lenet5", "resnet18-gn"):
        params, loss_fn, batch_fn, eval_fn, k = build_vision_task(args)
    else:
        params, loss_fn, batch_fn, eval_fn, k = build_lm_task(args)

    cfg = FLConfig(
        algorithm=args.algorithm, rounds=args.rounds,
        clients_per_round=max(1, int(round(k * args.participation))),
        eta_l=args.eta_l, eta_g=args.eta_g, lam=args.lam,
        batch_size=args.batch_size, local_epochs=args.local_epochs,
        seed=args.seed, eval_every=args.eval_every,
        vectorize=not args.serial)
    trainer = FederatedTrainer(loss_fn, params, k, batch_fn, cfg, eval_fn)
    hist = trainer.run(verbose=True)

    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.rounds,
                  {"params": trainer.params,
                   "server_state": trainer.server_state})
        print("checkpoint written to", args.ckpt_dir)
    best, at = trainer.best_accuracy
    print(f"best eval {best} @ round {at}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.__dict__ for r in hist], f, indent=1, default=float)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
