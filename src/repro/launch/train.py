"""End-to-end federated training driver (simulation mode — reproduces the
paper's experiments on synthetic heterogeneous data).

  PYTHONPATH=src python -m repro.launch.train \
      --model lenet5 --algorithm feddpc --rounds 50 --alpha 0.2 \
      --clients 100 --participation 0.1 --eta-l 0.01 --eta-g 0.01

Built on the composable engine (DESIGN.md §3): the participation model is
selectable (--sampler uniform|weighted|cyclic|markov), vision data
streams through the staged ingest pipeline (DESIGN.md §10) — synthetic
by default, or the REAL disk-backed datasets via --dataset
cifar10|cifar100|tiny-imagenet --data-root <standard download dir>,
with --prefetch-depth/--host-staged steering the staging ring —
--shard-clients/--model-shards turn on the sharded cohort round
(--model-shards M > 1 builds the two-axis (clients, model) mesh of
DESIGN.md §2 — per-leaf model-sharded params for >HBM configs), and
--ckpt-dir/--ckpt-every/--resume checkpoint the full TrainerState so an
interrupted run continues exactly where it stopped (mesh-shape changes
across save/resume included). The chaos-hardening levers of DESIGN.md
§12 ride along: --guard validates every client delta, --round-deadline
bounds each round in virtual time, --fault-plan replays a seeded
injector schedule, and --ingest-max-restarts supervises the staging
producer. --edges folds the cohort through hierarchical edge
aggregators (DESIGN.md §15) — under launch/distributed.spawn_local the
same driver runs one process per "host" — and --health-log streams the
run-health monitor's verdicts to a JSONL tracker file.

Also supports federated *LM* training with any assigned architecture's
smoke config (--model starcoder2-3b etc.) — the beyond-paper scenario
(cross-silo federated pretraining).
"""
from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.baselines import default_hyper
from repro.core.samplers import (CyclicSampler, MarkovSampler,
                                 UniformSampler, WeightedSampler)
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_lm_dataset
from repro.ingest import (CIFAR10Source, CIFAR100Source, ListDataSource,
                          StreamingImageSource, TinyImageNetSource,
                          build_federated_image_data)
from repro.models import transformer as tf
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)


def build_vision_task(args):
    family = "lenet5" if args.model == "lenet5" else "resnet18"
    if args.dataset == "synthetic":
        nclass = {"lenet5": 10, "resnet18-gn": args.num_classes}.get(
            args.model, args.num_classes)
        data = build_federated_image_data(
            num_classes=nclass, num_clients=args.clients, alpha=args.alpha,
            samples_per_class=args.samples_per_class, seed=args.seed)
        # streaming: per-round batches materialize on the ingest path
        source = StreamingImageSource(data, args.batch_size,
                                      args.local_epochs)
        te_x, te_y = data.test_images, data.test_labels
        image_size = 32
    else:
        # disk-backed reader (ingest/datasets.py): Dirichlet-partitioned
        # on load, records decode/augment lazily on the staging thread
        if not args.data_root:
            raise SystemExit(f"--dataset {args.dataset} needs --data-root "
                             "(the standard download directory)")
        src_cls = {"cifar10": CIFAR10Source, "cifar100": CIFAR100Source,
                   "tiny-imagenet": TinyImageNetSource}[args.dataset]
        src_kw = {}
        if args.dataset == "tiny-imagenet":
            src_kw["decode_workers"] = args.decode_workers
        source = src_cls(args.data_root, num_clients=args.clients,
                         alpha=args.alpha, batch_size=args.batch_size,
                         local_epochs=args.local_epochs,
                         augment=args.augment, seed=args.seed, **src_kw)
        nclass = source.num_classes
        te_x, te_y = source.test_arrays()
        image_size = 64 if args.dataset == "tiny-imagenet" else 32
    vc = VisionConfig(name=args.model, family=family, num_classes=nclass,
                      image_size=image_size)
    params = init_vision(vc, jax.random.PRNGKey(args.seed))
    loss_fn = functools.partial(vision_loss_fn, vc)
    te_x, te_y = jnp.asarray(te_x), jnp.asarray(te_y)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    return params, loss_fn, source, eval_fn, args.clients


def build_lm_task(args):
    cfg = get_config(args.model, smoke=True)
    tokens, topics = make_lm_dataset(args.clients * 16, args.seq_len,
                                     cfg.vocab_size, seed=args.seed)
    parts = dirichlet_partition(topics, args.clients, args.alpha,
                                seed=args.seed, min_size=1)
    params = tf.init_lm(cfg, jax.random.PRNGKey(args.seed), jnp.float32)

    def loss_fn(p, batch):
        return tf.loss_fn(cfg, p, batch)

    def batch_fn(c, t):
        idx = parts[c]
        rng = np.random.RandomState(hash((c, t)) % (2 ** 31))
        sel = idx[rng.permutation(len(idx))][:args.batch_size]
        if len(sel) < args.batch_size:
            sel = np.concatenate([sel] * ((args.batch_size // max(len(sel), 1))
                                          + 1))[:args.batch_size]
        tk = tokens[sel]
        return [{"tokens": tk[:, :-1], "labels": tk[:, 1:]}]

    ho = tokens[:64]
    ho_batch = {"tokens": jnp.asarray(ho[:, :-1]),
                "labels": jnp.asarray(ho[:, 1:])}

    @jax.jit
    def eval_fn(p):    # negative perplexity proxy -> "accuracy" slot
        return -loss_fn(p, ho_batch)

    return params, loss_fn, ListDataSource(batch_fn), eval_fn, args.clients


def build_sampler(args, source, num_clients: int, cohort: int):
    if args.sampler == "uniform":
        return UniformSampler(num_clients, cohort)
    if args.sampler == "weighted":
        if hasattr(source, "client_weights"):   # image sources, disk-backed
            weights = source.client_weights()
        else:   # LM task: uniform shard sizes, degenerate but valid
            weights = np.ones(num_clients)
        return WeightedSampler(weights, cohort)
    if args.sampler == "cyclic":
        return CyclicSampler(num_clients, cohort)
    if args.sampler == "markov":
        return MarkovSampler(num_clients, cohort,
                             p_on=args.markov_p_on, p_off=args.markov_p_off)
    raise ValueError(args.sampler)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lenet5",
                    choices=["lenet5", "resnet18-gn", *ARCH_IDS])
    ap.add_argument("--algorithm", default="feddpc")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--participation", type=float, default=0.1)
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "weighted", "cyclic", "markov"])
    ap.add_argument("--markov-p-on", type=float, default=0.5)
    ap.add_argument("--markov-p-off", type=float, default=0.5)
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "cifar10", "cifar100",
                             "tiny-imagenet"],
                    help="vision data: offline synthetic (default) or a "
                         "disk-backed reader over the standard download "
                         "layout under --data-root (DESIGN.md §10)")
    ap.add_argument("--data-root", default=None,
                    help="directory holding cifar-10-batches-py / "
                         "cifar-100-python / tiny-imagenet-200")
    ap.add_argument("--augment", action="store_true",
                    help="random crop + flip on the ingest path "
                         "(disk-backed datasets)")
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--eta-l", type=float, default=0.01)
    ap.add_argument("--eta-g", type=float, default=0.01)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--samples-per-class", type=int, default=100)
    ap.add_argument("--num-classes", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--serial", action="store_true",
                    help="per-client dispatch instead of the fused "
                         "cohort-vectorized round (debug/reference path)")
    ap.add_argument("--shard-clients", action="store_true",
                    help="shard the cohort's client axis over the local "
                         "devices (DESIGN.md §2)")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="model-axis shards per client slice: >1 builds "
                         "the two-axis (clients, model) mesh so params/"
                         "server state shard per leaf over `model` (the "
                         ">HBM layout); must divide the device count")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="staging-ring depth: cohort buffers the ingest "
                         "pipeline cycles through (DESIGN.md §10)")
    ap.add_argument("--host-staged", action="store_true",
                    help="keep the device-place stage on the consumer "
                         "thread (H2D at dispatch) instead of the "
                         "staging thread — the ingest bench's baseline")
    ap.add_argument("--async-buffer", action="store_true",
                    help="buffered-async rounds (DESIGN.md §11): waves "
                         "train against possibly-stale snapshots and the "
                         "server steps every --buffer-size arrivals with "
                         "staleness-discounted aggregation")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="arrivals per async server step (default: the "
                         "cohort size — the sync-equivalent anchor)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="staleness discount exponent: w(s)=(1+s)^-alpha")
    ap.add_argument("--async-concurrency", type=int, default=1,
                    help="max waves in flight at once (>1 lets fresh "
                         "waves overlap stale stragglers)")
    ap.add_argument("--runtime", default="deterministic",
                    choices=["deterministic", "exponential", "heavytail",
                             "markov"],
                    help="client runtime model: arrival latencies + "
                         "dropout of the async waves (core/runtime.py)")
    ap.add_argument("--runtime-dropout", type=float, default=0.0,
                    help="per-wave client dropout probability of the "
                         "exponential/heavytail/markov runtime models")
    ap.add_argument("--ingest-stall-s", type=float, default=None,
                    help="staging-ring stall deadline in seconds (a hung "
                         "producer raises instead of spinning forever)")
    ap.add_argument("--guard", action="store_true",
                    help="update-guard validation (DESIGN.md §12): "
                         "quarantine non-finite / exploded-norm client "
                         "deltas, clip outliers against the rolling "
                         "robust norm threshold")
    ap.add_argument("--round-deadline", type=float, default=None,
                    help="virtual-seconds round deadline (DESIGN.md "
                         "§12): sync rounds drop-and-mask clients whose "
                         "runtime draw misses it; async rounds fold the "
                         "partial buffer (needs a --runtime model)")
    ap.add_argument("--fault-plan", default=None,
                    help="chaos harness: JSON FaultPlan config (inline "
                         "string or @/path/to/plan.json) — the seeded "
                         "injector schedule of core/faults.py")
    ap.add_argument("--codec", default=None,
                    help="delta codec for the client->server uplink "
                         "(DESIGN.md §13): identity | bf16 | int8 | "
                         "int8_sym | int8_sr — quantized wire payloads "
                         "with per-leaf scales; identity is bitwise "
                         "equal to no codec")
    ap.add_argument("--server-opt", default="sgd",
                    choices=["sgd", "fedadam", "fedyogi"],
                    help="server-side optimizer (DESIGN.md §14): "
                         "precondition every rule's post-projection "
                         "aggregate with fedadam/fedyogi moments; sgd "
                         "(default) is today's step, bitwise")
    ap.add_argument("--health", action="store_true",
                    help="run-health monitor (DESIGN.md §14): rolling-"
                         "median loss spike / NaN detection, staleness "
                         "and quarantine-rate trend alarms over the "
                         "RoundRecord stream")
    ap.add_argument("--health-patience", type=int, default=None,
                    help="early-stop after N consecutive alarmed rounds "
                         "(needs --health; default: alarms only)")
    ap.add_argument("--health-log", default=None,
                    help="stream every health verdict to this JSONL "
                         "tracker file as the run goes (needs --health; "
                         "one JSON object per round, flushed per "
                         "verdict — in multi-process jobs process 0 "
                         "writes)")
    ap.add_argument("--edges", type=int, default=None,
                    help="hierarchical edge aggregation (DESIGN.md §15): "
                         "fold the cohort through E edge aggregators "
                         "(must divide the padded cohort) so the server "
                         "consumes E partial summaries instead of K raw "
                         "deltas; in multi-process jobs each process is "
                         "one edge over its local client shard")
    ap.add_argument("--codec-ef", action="store_true",
                    help="server-side error feedback for a lossy "
                         "--codec: clients ship delta + the running "
                         "mean quantization residual, so compression "
                         "error cancels across rounds instead of "
                         "accumulating (needs a lossy codec)")
    ap.add_argument("--decode-workers", type=int, default=0,
                    help="bounded thread pool for the per-image file "
                         "decode of path-indexed datasets "
                         "(tiny-imagenet); 0 = serial decode, output "
                         "order is identical either way")
    ap.add_argument("--ingest-max-restarts", type=int, default=0,
                    help="supervised staging-producer restarts: retry a "
                         "crashed produce up to N times (bounded "
                         "exponential backoff) before failing the run")
    ap.add_argument("--out", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the TrainerState every N rounds (0 = only "
                         "at the end, and only when --ckpt-dir is set)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest TrainerState from --ckpt-dir "
                         "and continue the run exactly where it stopped")
    args = ap.parse_args(argv)

    # multi-process jobs (DESIGN.md §15): wire jax.distributed from the
    # REPRO_DIST_* environment BEFORE the first device query; a no-op in
    # single-process runs
    from repro.launch.distributed import maybe_initialize
    maybe_initialize()

    if args.model in ("lenet5", "resnet18-gn"):
        params, loss_fn, source, eval_fn, k = build_vision_task(args)
    else:
        params, loss_fn, source, eval_fn, k = build_lm_task(args)

    cohort = max(1, int(round(k * args.participation)))
    algo = AlgoConfig(name=args.algorithm, eta_l=args.eta_l,
                      eta_g=args.eta_g,
                      hyper=default_hyper(args.algorithm, lam=args.lam),
                      server_opt=args.server_opt)
    cfg = ExecConfig(
        rounds=args.rounds, clients_per_round=cohort, seed=args.seed,
        eval_every=args.eval_every, vectorize=not args.serial,
        shard_clients=args.shard_clients, shard_model=args.model_shards,
        prefetch_depth=args.prefetch_depth,
        device_stage=not args.host_staged,
        async_buffer=args.async_buffer, buffer_size=args.buffer_size,
        staleness_alpha=args.staleness_alpha,
        async_concurrency=args.async_concurrency,
        ingest_stall_s=args.ingest_stall_s,
        guard=args.guard, round_deadline=args.round_deadline,
        ingest_max_restarts=args.ingest_max_restarts,
        codec=args.codec, codec_ef=(True if args.codec_ef else None),
        health=args.health, health_patience=args.health_patience,
        health_log=args.health_log, edges=args.edges,
        decode_workers=args.decode_workers,
        batch_size=args.batch_size, local_epochs=args.local_epochs)
    sampler = build_sampler(args, source, k, cohort)
    runtime = None
    if args.async_buffer or args.round_deadline is not None:
        from repro.core.runtime import make_runtime
        rt_kw = ({} if args.runtime == "deterministic"
                 else {"dropout": args.runtime_dropout})
        runtime = make_runtime(args.runtime, k, **rt_kw)
    fault_plan = None
    if args.fault_plan:
        from repro.core.faults import FaultPlan
        raw = args.fault_plan
        if raw.startswith("@"):
            with open(raw[1:]) as fh:
                raw = fh.read()
        fault_plan = FaultPlan.from_config(json.loads(raw))

    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume needs --ckpt-dir")
        trainer = FederatedTrainer.resume(
            args.ckpt_dir, loss_fn, params, k, source, cfg, eval_fn,
            algo=algo, sampler=sampler, runtime=runtime,
            fault_plan=fault_plan)
        print(f"resumed from {args.ckpt_dir} at round {trainer.start_round}")
    else:
        trainer = FederatedTrainer(loss_fn, params, k, source, cfg, eval_fn,
                                   algo=algo, sampler=sampler,
                                   runtime=runtime, fault_plan=fault_plan)
    with trainer:
        if args.ckpt_dir and args.ckpt_every > 0:
            for t in range(trainer.start_round, args.rounds):
                rec = trainer.run_round(t)
                print(f"[{args.algorithm}] round {t:4d} "
                      f"loss={rec.train_loss:.4f}")
                if (t + 1) % args.ckpt_every == 0:
                    trainer.save(args.ckpt_dir)
            trainer.finalize()
            hist = trainer.history
        else:
            hist = trainer.run(verbose=True)
        if args.ckpt_dir:
            path = trainer.save(args.ckpt_dir)
            print("checkpoint written to", path)
        best, at = trainer.best_accuracy
        if args.health and trainer.health_report is not None:
            hr = trainer.health_report
            print(f"health: {hr.alarmed_rounds} alarmed rounds "
                  f"(spikes {hr.spike_rounds}, "
                  f"nonfinite {hr.nonfinite_rounds})"
                  + (" — EARLY STOP" if hr.should_stop else ""))
    print(f"best eval {best} @ round {at}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.__dict__ for r in hist], f, indent=1, default=float)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
