import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run (deliverable e): prove that every
(architecture x input-shape x mesh) combination lowers, SPMD-partitions
and compiles on the production meshes, and extract roofline terms.

MUST be imported before any other module that imports jax — the device
count locks at first jax init (hence the XLA_FLAGS lines above, before
every other import).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single   # 40 baselines
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi    # 512-chip pass
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import all_arch_ids, get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import (analyze_compiled, model_flops,
                                     roofline_report)
from repro.sharding.rules import (ShardingPolicy, batch_specs, param_specs,
                                  state_specs)


def _policy_for(mesh, cfg) -> ShardingPolicy:
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    # experts span EVERY non-model axis (pod x data on the multi-pod mesh:
    # 2 TB of kimi expert weights over 512 devices instead of 256)
    expert_axis = None
    if cfg.moe:
        expert_axis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return ShardingPolicy(batch_axes=batch_axes, expert_axis=expert_axis)


def _moe_shard_fn(mesh, pol):
    """Sharding constraints for MoE internals (see models/moe.py)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fn(x, role):
        if role == "dispatched" and x.ndim == 4:
            g, e, c, d = x.shape
            ea = pol.expert_axis
            spec = [None, None, None, None]
            if ea and e % axis_sizes[ea] == 0:
                spec[1] = ea
            if c % axis_sizes[pol.model_axis] == 0:
                spec[2] = pol.model_axis
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        return x

    return fn


def lower_and_compile(arch: str, shape_name: str, *, multi_pod: bool = False,
                      compile_: bool = True, verbose: bool = True,
                      policy: ShardingPolicy = None,
                      step_kwargs: dict = None, unroll: bool = False,
                      cfg_override=None, moe_impl: str = "gshard"):
    """unroll=True fully unrolls the layer-stack scans so cost_analysis
    counts every layer (XLA counts while-loop bodies ONCE — with the scan
    in place the FLOP term would be ~L x too small). Compile-proving runs
    keep the scan (small HLO, fast 512-way partitioning)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(mesh.devices.size)
    pol = policy or _policy_for(mesh, cfg)
    kw = dict(step_kwargs or {})
    if unroll:
        kw.setdefault("scan_unroll", True)
    if cfg.moe and moe_impl == "ep":
        # explicit expert-parallel all-to-all via shard_map (§Perf 1)
        kw["moe_impl"] = "ep"
        kw["moe_mesh"] = mesh
    elif cfg.moe and "shard_fn" not in kw:
        kw["shard_fn"] = _moe_shard_fn(mesh, pol)
        # token groups = number of batch shards so expert dispatch sorts
        # stay device-local
        kw.setdefault("moe_groups", 1)

    step, arg_specs, has_states = steps_mod.make_step(cfg, shape, **kw)

    p_spec = steps_mod.params_spec(cfg)
    p_shard = param_specs(p_spec, mesh, pol)

    def _ns(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    with mesh:
        if shape.kind == "train":
            batch = arg_specs["batch"]
            b_shard = batch_specs(batch, mesh, pol)
            jitted = jax.jit(step,
                             in_shardings=(_ns(p_shard), _ns(b_shard)),
                             out_shardings=(_ns(p_shard), None))
            lowered = jitted.lower(p_spec, batch)
        elif shape.kind == "prefill":
            st_spec = steps_mod.states_spec(cfg, shape)
            st_shard = state_specs(st_spec, mesh, pol)
            data_args = arg_specs
            d_shard = batch_specs(data_args, mesh, pol)
            order = list(data_args.keys())
            jitted = jax.jit(
                lambda p, s, *a: step(p, s, *a),
                in_shardings=(_ns(p_shard), _ns(st_shard),
                              *[_ns(d_shard[k]) for k in order]),
                out_shardings=(_ns(st_shard), None))
            lowered = jitted.lower(p_spec, st_spec,
                                   *[data_args[k] for k in order])
        else:   # decode
            st_spec = steps_mod.states_spec(cfg, shape)
            st_shard = state_specs(st_spec, mesh, pol)
            d_shard = batch_specs(arg_specs, mesh, pol)
            jitted = jax.jit(
                step,
                in_shardings=(_ns(p_shard), _ns(st_shard),
                              _ns(d_shard["tokens"]),
                              _ns(d_shard["positions"])),
                out_shardings=(_ns(st_shard), None),
                donate_argnums=(1,))     # in-place KV-cache/state update
            lowered = jitted.lower(p_spec, st_spec, arg_specs["tokens"],
                                   arg_specs["positions"])

        if not compile_:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "lowered": True}
        compiled = lowered.compile()

    rl = analyze_compiled(
        compiled, arch=arch, shape_name=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops_total=model_flops(cfg, shape))
    if verbose:
        print(roofline_report(rl))
        try:
            print("memory_analysis:", compiled.memory_analysis())
        except Exception as e:                       # pragma: no cover
            print("memory_analysis unavailable:", e)
    return rl


def fl_round_dryrun(arch: str = "starcoder2-3b", *, algorithm: str = "feddpc",
                    multi_pod: bool = False, clients: int = None,
                    local_steps: int = 2, seq_len: int = 4096,
                    verbose: bool = True, cfg_override=None,
                    unroll: bool = False):
    """Lower + compile ONE cross-silo FL round (core/round.py) on the
    production mesh: local training on every client slice + the FedDPC
    collective epilogue. This is the paper-representative artifact for
    §Perf hillclimb 3 (compare algorithm='fedavg' for collective volume).
    """
    from repro.core.round import fl_round_input_specs, make_fl_round_step
    from repro.models import transformer as tfm

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    pol = _policy_for(mesh, cfg)
    n_clients = clients or int(
        np.prod([mesh.shape[a] for a in pol.batch_axes]))
    local_batch = max(1, 256 // (n_clients * local_steps))

    def loss_fn(p, b):
        return tfm.loss_fn(cfg, p, b, remat="full", attn_impl="auto",
                           scan_unroll=(True if unroll else 1))

    step = make_fl_round_step(loss_fn, eta_l=1e-2, eta_g=1e-2,
                              algorithm=algorithm)
    p_spec = steps_mod.params_spec(cfg)
    p_shard = param_specs(p_spec, mesh, pol)
    d_shard = p_shard                        # delta_prev mirrors params
    batch = fl_round_input_specs(cfg, clients=n_clients,
                                 local_steps=local_steps,
                                 local_batch=local_batch, seq_len=seq_len)
    # client axis = leading dim sharded over the batch axes
    b_axis = (tuple(pol.batch_axes) if len(pol.batch_axes) > 1
              else pol.batch_axes[0])
    b_shard = jax.tree.map(
        lambda x: P(b_axis, *([None] * (len(x.shape) - 1))), batch)

    def _ns(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))

    import numpy as _np
    delta_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), p_spec)
    with mesh:
        jitted = jax.jit(step,
                         in_shardings=(_ns(p_shard), _ns(d_shard),
                                       _ns(b_shard)),
                         out_shardings=(_ns(p_shard), _ns(d_shard), None))
        lowered = jitted.lower(p_spec, delta_spec, batch)
        compiled = lowered.compile()

    tokens = n_clients * local_steps * local_batch * seq_len
    mf = 6.0 * cfg.param_counts()["active"] * tokens
    rl = analyze_compiled(
        compiled, arch=f"fl-round[{algorithm}]-{arch}",
        shape_name=f"K{n_clients}xM{local_steps}xB{local_batch}x{seq_len}",
        mesh_name="pod2x16x16" if multi_pod else "pod16x16",
        chips=chips, model_flops_total=mf)
    if verbose:
        print(roofline_report(rl))
        print("memory_analysis:", compiled.memory_analysis())
    return rl


def _depth_variant(cfg, groups: int):
    """cfg with the periodic stack reduced to `groups` groups (prefix kept)."""
    from repro.models.transformer import stack_plan
    if cfg.is_encoder_decoder:
        return cfg.with_(num_layers=groups, encoder_layers=groups)
    prefix, period, _ = stack_plan(cfg)
    return cfg.with_(num_layers=prefix + period * groups)


def roofline_table_entry(arch: str, shape_name: str, *, multi_pod: bool = False,
                         verbose: bool = True, policy=None,
                         step_kwargs: dict = None, moe_impl: str = "gshard"):
    """Accurate roofline via DEPTH DIFFERENCING (EXPERIMENTS.md §Roofline).

    XLA's cost_analysis counts while-loop bodies ONCE, so the layer-stack
    scan hides (G-1)/G of the FLOPs. Full unrolling is exact but takes
    ~minutes/combo. Instead we compile depth-1 and depth-2 UNROLLED
    variants (prefix + 1·period and prefix + 2·period layers) and
    extrapolate linearly:

        X_total = X(d1) + (G-1) · (X(d2) - X(d1))

    for FLOPs, HBM bytes and collective bytes (all layer-linear).
    memory_analysis and the compile PROOF come from the full-depth
    scan-mode artifact. Cross-checked against a fully-unrolled compile for
    starcoder2-3b/train_4k: FLOP term within 13% (the depth-diff run also
    unrolls the inner attention KV scan, which the full-unroll comparison
    run did not, so the depth-diff numbers are the more complete count).
    """
    from repro.models.transformer import stack_plan

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    prefix, period, groups = (0, 0, cfg.num_layers)
    if cfg.is_encoder_decoder:
        groups = cfg.num_layers
    else:
        prefix, period, groups = stack_plan(cfg)

    # 1) full-depth scan-mode compile: proof + memory analysis
    full = lower_and_compile(arch, shape_name, multi_pod=multi_pod,
                             verbose=False, policy=policy,
                             step_kwargs=step_kwargs, moe_impl=moe_impl)

    if groups <= 2:
        if verbose:
            print(roofline_report(full))
        return full

    # 2) depth-1 / depth-2 unrolled cost compiles
    sub = {}
    for g in (1, 2):
        sub[g] = lower_and_compile(arch, shape_name, multi_pod=multi_pod,
                                   verbose=False, policy=policy,
                                   step_kwargs=step_kwargs, unroll=True,
                                   cfg_override=_depth_variant(cfg, g),
                                   moe_impl=moe_impl)

    f1, f2 = sub[1], sub[2]
    scale = groups - 1
    # clamp at the depth-1 value: fusion differences at tiny depth can make
    # f2 < f1 for near-zero terms, which would extrapolate negative
    full.flops = max(f1.flops, f1.flops + scale * (f2.flops - f1.flops))
    full.hbm_bytes = max(f1.hbm_bytes,
                         f1.hbm_bytes + scale * (f2.hbm_bytes - f1.hbm_bytes))
    full.coll_bytes = {
        k: max(f1.coll_bytes.get(k, 0),
               int(f1.coll_bytes.get(k, 0)
                   + scale * (f2.coll_bytes.get(k, 0)
                              - f1.coll_bytes.get(k, 0))))
        for k in set(f1.coll_bytes) | set(f2.coll_bytes)}
    if verbose:
        print(roofline_report(full))
    return full


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON results here")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for accurate cost_analysis")
    ap.add_argument("--table", action="store_true",
                    help="depth-differencing roofline (accurate + fast)")
    ap.add_argument("--fl-round", action="store_true",
                    help="lower the cross-silo FL ROUND step instead")
    ap.add_argument("--algorithm", default="feddpc")
    ap.add_argument("--moe-impl", default="gshard", choices=["gshard", "ep"])
    args = ap.parse_args(argv)

    if args.fl_round:
        rl = fl_round_dryrun(args.arch or "starcoder2-3b",
                             algorithm=args.algorithm,
                             multi_pod=args.mesh == "multi")
        if args.out:
            with open(args.out, "w") as f:
                json.dump([rl.as_dict()], f, indent=1)
        return 0

    archs = all_arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for multi in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch} x {shape_name} x " + \
                    ("pod2x16x16" if multi else "pod16x16")
                t0 = time.time()
                try:
                    if args.table:
                        rl = roofline_table_entry(arch, shape_name,
                                                  multi_pod=multi,
                                                  moe_impl=args.moe_impl)
                    else:
                        rl = lower_and_compile(
                            arch, shape_name, multi_pod=multi,
                            compile_=not args.lower_only, unroll=args.unroll,
                            moe_impl=args.moe_impl)
                    dt = time.time() - t0
                    print(f"[OK]   {tag}  ({dt:.1f}s)")
                    if hasattr(rl, "as_dict"):
                        results.append(rl.as_dict())
                except Exception as e:
                    dt = time.time() - t0
                    print(f"[FAIL] {tag}  ({dt:.1f}s): "
                          f"{type(e).__name__}: {e}")
                    traceback.print_exc()
                    failures.append(tag)
    print(f"\n{len(results)} OK, {len(failures)} failed")
    if failures:
        for f in failures:
            print("  FAILED:", f)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
