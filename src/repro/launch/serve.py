"""Batched serving driver: prefill a batch of prompts, then decode tokens
step-by-step with the explicit KV-cache/SSM-state pytrees.

Runs the REDUCED (smoke) config of any assigned architecture for real on
CPU — the full-size configs are exercised via the dry-run only.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf


def serve_lm(cfg, batch, prompt_len, gen, seed=0, greedy=True):
    key = jax.random.PRNGKey(seed)
    params = tf.init_lm(cfg, key, jnp.float32)
    capacity = prompt_len + gen
    states = tf.init_states(cfg, batch, capacity, jnp.float32)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    embeds = None
    if cfg.modality == "vision":
        embeds = jnp.zeros((batch, cfg.num_patches, cfg.d_model), jnp.float32)

    @jax.jit
    def prefill(params, states, tokens):
        logits, st, _ = tf.lm_forward(cfg, params, tokens, embeds=embeds,
                                      states=states, logits_slice_last=True)
        return st, logits

    @jax.jit
    def decode(params, states, tokens, positions):
        logits, st, _ = tf.lm_forward(cfg, params, tokens,
                                      positions=positions, states=states,
                                      logits_slice_last=True)
        return st, logits

    t0 = time.perf_counter()
    states, logits = prefill(params, states, prompts)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    # VLM positions are offset by the patch prefix length
    base = prompt_len + (cfg.num_patches if cfg.modality == "vision" else 0)
    t0 = time.perf_counter()
    for i in range(gen - 1):
        pos = jnp.full((batch, 1), base + i, jnp.int32)
        states, logits = decode(params, states, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    t_decode = time.perf_counter() - t0
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {"prefill_s": t_prefill, "decode_s": t_decode,
                    "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def serve_encdec(cfg, batch, gen, seed=0):
    key = jax.random.PRNGKey(seed)
    params = encdec_mod.init_encdec(cfg, key, jnp.float32)
    frames = jax.random.normal(key, (batch, cfg.encoder_seq_len, cfg.d_model),
                               jnp.float32)
    dec_states = encdec_mod.init_decoder_states(cfg, batch, gen + 1,
                                                jnp.float32)

    @jax.jit
    def encode(params, frames):
        return encdec_mod.encode(cfg, params, frames)

    @jax.jit
    def decode_step(params, states, enc_out, tokens, positions):
        logits, st = encdec_mod.decode(cfg, params, tokens, enc_out,
                                       positions=positions, states=states)
        return st, logits

    t0 = time.perf_counter()
    enc_out = encode(params, frames)
    tok = jnp.zeros((batch, 1), jnp.int32)        # BOS
    out = []
    for i in range(gen):
        pos = jnp.full((batch, 1), i, jnp.int32)
        dec_states, logits = decode_step(params, dec_states, enc_out, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    dt = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), {"total_s": dt}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    if cfg.is_encoder_decoder:
        tokens, stats = serve_encdec(cfg, args.batch, args.gen, args.seed)
    else:
        tokens, stats = serve_lm(cfg, args.batch, args.prompt_len, args.gen,
                                 args.seed)
    print(f"[{args.arch}] generated {tokens.shape} tokens; stats={stats}")
    print("sample:", tokens[0].tolist())
    assert not jnp.isnan(tokens).any()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
