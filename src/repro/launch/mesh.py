"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state, so tests/benches see the default 1-device CPU while the
dry-run (which sets XLA_FLAGS *before* importing jax) sees 512 placeholder
host devices.

Target hardware: TPU v5e. 256 chips/pod in a (16, 16) twisted torus;
multi-pod = 2 pods over DCN. Axis meaning (DESIGN.md §2):
  pod    across-datacenter replica/client axis (DCN-linked)
  data   within-pod batch / FL-client / expert axis
  model  tensor-parallel axis (Megatron sharding)
"""
from __future__ import annotations

import jax

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~ per-device collective bw)
DCN_BW = 6.25e9                   # B/s per host across pods (50 Gb/s)
HBM_BYTES = 16e9                  # v5e HBM capacity


def _mesh_kwargs(n):
    # AxisType landed after jax 0.4.x; Auto is the default there anyway,
    # so on older jax we simply omit the kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    return {} if axis_type is None else {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_cohort_mesh(num_devices: int = None, axis: str = "clients",
                     model: int = 1):
    """Mesh over the local devices for the simulation trainer's cohort
    sharding (DESIGN.md §2).

    ``model == 1`` (default): the historical 1-D ``(axis,)`` mesh — the
    fused cohort round's (K, M, ...) batch stack is data-parallel over
    ``axis`` while params / server state replicate.

    ``model > 1``: a two-axis ``(devices // model, model)`` mesh over
    ``(axis, "model")`` — each client slice holds a model-parallel
    replica whose params / server state shard over ``model`` with the §8
    per-leaf rules (sharding/rules.cohort_param_specs), the layout for
    models larger than one device's HBM. ``model`` must divide the
    device count; anything else fails loudly here rather than producing
    a silently lopsided mesh.

    MULTI-PROCESS (DESIGN.md §15): ``jax.devices()`` is the GLOBAL
    device list once ``launch/distributed.maybe_initialize()`` has run,
    so the same call builds a process-spanning clients mesh with no
    changes — the axis enumerates every host's devices in process order
    (process 0's local devices first), which is what makes each host's
    contiguous client-row slice land on its own local devices
    (sharding/rules.local_row_range). ``num_devices`` must then be the
    GLOBAL count (or None).

    On CPU CI both shapes are exercised with
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    n = num_devices or len(jax.devices())
    if model <= 1:
        return jax.make_mesh((n,), (axis,), **_mesh_kwargs(1))
    if n % model:
        raise ValueError(
            f"model={model} does not divide the {n} available devices; "
            f"a ({axis}, model) mesh needs clients x model == devices")
    return jax.make_mesh((n // model, model), (axis, "model"),
                         **_mesh_kwargs(2))


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_mesh_kwargs(2))


def mesh_info(mesh) -> dict:
    return {"axis_names": tuple(mesh.axis_names),
            "shape": tuple(mesh.devices.shape),
            "num_devices": int(mesh.devices.size)}
