"""Multi-process (multi-host) runtime for the cohort trainer
(DESIGN.md §15).

Three pieces, all jax-lazy (importing this module never initializes a
backend, same contract as launch/mesh.py):

  ``maybe_initialize()``   reads the ``REPRO_DIST_*`` environment and, when
      present, wires ``jax.distributed`` up BEFORE any device query: on
      CPU the gloo cross-process collective backend must be selected
      before ``jax.distributed.initialize`` or every cross-process jit
      fails with "Multiprocess computations aren't implemented on the
      CPU backend". After it returns, ``jax.devices()`` spans every
      process and ``jax.local_devices()`` is this host's slice. A no-op
      (returning None) outside a spawned/distributed environment, so
      single-process entry points can call it unconditionally.

  ``spawn_local(argv, num_processes)``   the single-machine N-process
      spawner for offline CI: re-executes ``argv`` N times with the
      coordinator/process-id environment set (127.0.0.1 coordinator, a
      freshly bound port — no external network), collects the exit
      statuses, and raises on any failure. Each child calls
      ``maybe_initialize()`` and becomes one "host" of the cohort.

  process gating + a KV-store ``barrier``   ``is_coordinator()`` gates
      side effects (checkpoint writes, receipts) to process 0;
      ``barrier(name)`` synchronizes processes through the distributed
      KV store (each process publishes a key, then blocks on every
      peer's), which is how the checkpoint writer keeps non-writers from
      racing past a save/restore point.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Optional, Sequence

# environment contract between spawn_local and maybe_initialize
ENV_COORD = "REPRO_DIST_COORD"
ENV_NPROCS = "REPRO_DIST_NPROCS"
ENV_PID = "REPRO_DIST_PID"
ENV_LOCAL_DEVICES = "REPRO_DIST_LOCAL_DEVICES"

_initialized = False


@dataclass(frozen=True)
class DistContext:
    """What maybe_initialize() resolved: this process's place in the job."""
    coordinator: str
    num_processes: int
    process_id: int


def dist_env(environ=None) -> Optional[DistContext]:
    """Parse the REPRO_DIST_* contract; None outside a distributed job."""
    env = os.environ if environ is None else environ
    coord = env.get(ENV_COORD)
    if not coord:
        return None
    return DistContext(coordinator=coord,
                       num_processes=int(env.get(ENV_NPROCS, "1")),
                       process_id=int(env.get(ENV_PID, "0")))


def maybe_initialize() -> Optional[DistContext]:
    """Initialize jax.distributed from the environment, once.

    Must run before the first device query (anything that instantiates a
    backend). On the CPU backend the gloo collectives implementation is
    selected first — without it cross-process computations fail to
    compile — gated on the JAX_PLATFORMS *environment* rather than
    ``jax.default_backend()``, which would itself initialize a backend
    prematurely.
    """
    global _initialized
    ctx = dist_env()
    if ctx is None:
        return None
    import jax
    if not _initialized:
        if os.environ.get("JAX_PLATFORMS", "").startswith("cpu"):
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=ctx.coordinator,
                                   num_processes=ctx.num_processes,
                                   process_id=ctx.process_id)
        _initialized = True
    return ctx


def is_coordinator() -> bool:
    """True on process 0 (and in any single-process run) — the one
    process that writes checkpoints / receipts (DESIGN.md §15)."""
    import jax
    return jax.process_index() == 0


def process_count() -> int:
    import jax
    return jax.process_count()


def _kv_client():
    # the coordination-service KV store jax.distributed.initialize stands
    # up; jax exposes no public handle, so reach through _src — gated
    # behind initialize() having run (global_state.client is None
    # otherwise)
    from jax._src import distributed as jd
    client = jd.global_state.client
    if client is None:
        raise RuntimeError(
            "barrier() needs jax.distributed initialized "
            "(maybe_initialize() found no REPRO_DIST_* environment)")
    return client


def barrier(name: str, *, timeout_s: float = 120.0) -> None:
    """Synchronize every process at ``name`` through the distributed KV
    store: publish <name>/<pid>, then block until every peer's key is
    visible. ``name`` must be unique per synchronization point (keys are
    write-once per job). No-op in single-process runs."""
    import jax
    n = jax.process_count()
    if n <= 1:
        return
    client = _kv_client()
    pid = jax.process_index()
    client.key_value_set(f"repro/barrier/{name}/{pid}", "1")
    deadline_ms = int(timeout_s * 1000)
    for peer in range(n):
        client.blocking_key_value_get(f"repro/barrier/{name}/{peer}",
                                      deadline_ms)


def kv_allmax(name: str, value: int, *, timeout_s: float = 120.0) -> int:
    """All-reduce-max of a host-side int through the KV store: every
    process publishes its value under <name>/<pid> and reads every
    peer's, returning the max. Used where hosts must agree on a
    host-side capacity (e.g. the cohort stacker's max-batches high-water
    mark) without a device collective. Single-process: identity."""
    import jax
    n = jax.process_count()
    if n <= 1:
        return int(value)
    client = _kv_client()
    pid = jax.process_index()
    client.key_value_set(f"repro/allmax/{name}/{pid}", str(int(value)))
    deadline_ms = int(timeout_s * 1000)
    best = int(value)
    for peer in range(n):
        got = client.blocking_key_value_get(
            f"repro/allmax/{name}/{peer}", deadline_ms)
        best = max(best, int(got))
    return best


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_local(argv: Sequence[str], num_processes: int, *,
                devices_per_process: Optional[int] = None,
                env: Optional[dict] = None,
                timeout_s: float = 600.0,
                capture: bool = True):
    """Run ``argv`` as ``num_processes`` local processes forming one
    distributed jax job (the offline-CI stand-in for a real multi-host
    launch — coordinator on 127.0.0.1, no external network).

    Each child gets the REPRO_DIST_* contract plus ``JAX_PLATFORMS=cpu``
    and, when ``devices_per_process`` is set, an XLA_FLAGS host-device
    override so every "host" exposes that many CPU devices. The children
    must call :func:`maybe_initialize` before their first device query.

    Returns the list of ``subprocess.CompletedProcess``-like results
    (returncode/stdout/stderr per child); raises RuntimeError if any
    child fails, with the failing children's tails in the message.
    """
    coord = f"127.0.0.1:{free_port()}"
    procs = []
    for pid in range(num_processes):
        child_env = dict(os.environ)
        if env:
            child_env.update(env)
        child_env[ENV_COORD] = coord
        child_env[ENV_NPROCS] = str(num_processes)
        child_env[ENV_PID] = str(pid)
        child_env.setdefault("JAX_PLATFORMS", "cpu")
        if devices_per_process is not None:
            child_env[ENV_LOCAL_DEVICES] = str(devices_per_process)
            flags = child_env.get("XLA_FLAGS", "")
            # replace any inherited device-count force rather than append
            # (XLA honors the LAST occurrence, but a stale flag in a test
            # runner's env is a confusing thing to leave in place)
            flags = " ".join(
                f for f in flags.split()
                if not f.startswith("--xla_force_host_platform_device_count"))
            child_env["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{devices_per_process}").strip()
        procs.append(subprocess.Popen(
            list(argv), env=child_env,
            stdout=subprocess.PIPE if capture else None,
            stderr=subprocess.PIPE if capture else None,
            text=True))
    results = []
    deadline = time.monotonic() + timeout_s
    for p in procs:
        try:
            out, err = p.communicate(
                timeout=max(1.0, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            out, err = p.communicate()
            results.append((p.returncode if p.returncode is not None
                            else -9, out, err))
            raise RuntimeError(
                f"spawn_local: child timed out after {timeout_s}s\n"
                f"--- stdout tail ---\n{(out or '')[-2000:]}\n"
                f"--- stderr tail ---\n{(err or '')[-2000:]}")
        results.append((p.returncode, out, err))
    bad = [(i, rc, out, err) for i, (rc, out, err) in enumerate(results)
           if rc != 0]
    if bad:
        msgs = []
        for i, rc, out, err in bad:
            msgs.append(f"child {i} exited {rc}\n"
                        f"--- stdout tail ---\n{(out or '')[-2000:]}\n"
                        f"--- stderr tail ---\n{(err or '')[-2000:]}")
        raise RuntimeError("spawn_local: " + "\n".join(msgs))
    return results
