"""The cross-regime matrix's toy FL task, factored out so subprocess
harnesses can share it WITHOUT inheriting environment side effects:
this module never touches XLA_FLAGS / JAX_PLATFORMS and never queries
devices at import time — callers (tests/_regime_matrix_check.py forcing
8 host devices, tests/_multihost_worker.py joining a jax.distributed
job) own their environment setup and must finish it before calling
anything here.

The task is small on purpose — a 2-layer MLP regression with ragged
per-client minibatch counts — but exercises every moving part the
equivalence checks care about: cohort padding (K=3 pads to the device
axis), the grow-once M bucket ((c % 2) + 1 minibatches), 4-divisible
model dims for the 2-axis mesh, and multi-round schedules.
"""
import numpy as np
import jax.numpy as jnp

NUM_CLIENTS = 10
K = 3           # pads to 8 on the 1-D client axis, to 4 on the 2-axis mesh
ROUNDS = 3


def loss_fn(p, batch):
    h = jnp.tanh(batch["x"] @ p["w1"] + p["b1"])
    pred = h @ p["w2"] + p["b2"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w1": jnp.asarray(r.randn(8, 16) * 0.3, jnp.float32),
            "b1": jnp.zeros((16,), jnp.float32),
            "w2": jnp.asarray(r.randn(16, 4) * 0.3, jnp.float32),
            "b2": jnp.zeros((4,), jnp.float32)}


def batch_fn(c, t):
    """(c % 2) + 1 minibatches — cohorts are ragged by construction."""
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 8).astype(np.float32),
             "y": r.randn(8, 4).astype(np.float32)}
            for _ in range((c % 2) + 1)]
