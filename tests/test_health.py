"""Run-health monitor contracts (repro.health, DESIGN.md §14): the
windowed detectors (loss spike / non-finite / staleness trend /
quarantine rate), the patience-gated early stop, bitwise detector-state
round-trips through save/resume, and the trainer integration that turns
``should_stop`` into an actual early exit of ``run()``.
"""
import tempfile
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.faults import FaultPlan
from repro.health.monitor import HealthConfig, HealthMonitor

import jax.numpy as jnp


def rec(t, loss, stale=0.0, quar=0):
    return SimpleNamespace(round=t, train_loss=loss, staleness_mean=stale,
                           quarantined=quar)


def feed(mon, losses, **kw):
    return [mon.observe(rec(t, lo, **kw)) for t, lo in enumerate(losses)]


# ---------------- detectors ----------------

def test_spike_arms_after_min_history():
    mon = HealthMonitor(HealthConfig(min_history=4, spike_mult=3.0))
    # a 100x spike BEFORE min_history rounds must not alarm (unarmed)
    reports = feed(mon, [1.0, 1.0, 100.0])
    assert all(r.healthy for r in reports)
    mon = HealthMonitor(HealthConfig(min_history=4, spike_mult=3.0))
    reports = feed(mon, [1.0, 1.1, 0.9, 1.0, 100.0])
    assert all(r.healthy for r in reports[:4])
    assert reports[4].alarms == ["loss_spike"]
    assert reports[4].spike_rounds == 1
    assert not reports[4].should_stop          # no patience configured


def test_spike_uses_median_not_mean_and_plateau_recovers():
    """The rolling median ignores the spike itself (a mean would chase
    it), and a sustained plateau at the new level stops alarming once
    the median catches up — a spike is not a regime change."""
    mon = HealthMonitor(HealthConfig(window=4, min_history=4,
                                     spike_mult=3.0))
    losses = [1.0] * 4 + [10.0] * 6
    reports = feed(mon, losses)
    alarmed = [bool(r.alarms) for r in reports]
    assert alarmed[4]                  # the jump alarms
    assert not alarmed[-1]             # the plateau does not, forever
    assert reports[-1].loss_median == pytest.approx(10.0)


def test_nonfinite_always_alarms_and_never_enters_window():
    mon = HealthMonitor(HealthConfig(min_history=4, stop_on_nonfinite=False))
    reports = feed(mon, [1.0, float("nan"), 1.0, float("inf"), 1.0])
    assert reports[1].alarms == ["nonfinite_loss"]
    assert reports[3].alarms == ["nonfinite_loss"]
    assert reports[4].nonfinite_rounds == 2
    # the window holds only the finite losses: the median stays finite
    assert np.isfinite(reports[4].loss_median)
    assert not any(r.should_stop for r in reports)


def test_nonfinite_stops_immediately_when_configured():
    mon = HealthMonitor(HealthConfig(stop_on_nonfinite=True))
    reports = feed(mon, [1.0, float("nan")])
    assert not reports[0].should_stop
    assert reports[1].should_stop


def test_staleness_trend_alarm():
    mon = HealthMonitor(HealthConfig(min_history=4, staleness_mult=3.0))
    reports = [mon.observe(rec(t, 1.0, stale=1.0)) for t in range(6)]
    assert all(r.healthy for r in reports)
    assert mon.observe(rec(6, 1.0, stale=10.0)).alarms == [
        "staleness_trend"]


def test_quarantine_rate_alarm():
    mon = HealthMonitor(HealthConfig(min_history=4, quarantine_rate=0.25,
                                     clients_per_round=4))
    # sustained 2-of-4 quarantined: rate 0.5 > 0.25 once armed
    reports = [mon.observe(rec(t, 1.0, quar=2)) for t in range(5)]
    assert all(r.healthy for r in reports[:3])
    assert "quarantine_rate" in reports[4].alarms


def test_patience_counts_consecutive_alarms_only():
    mon = HealthMonitor(HealthConfig(min_history=2, spike_mult=2.0,
                                     patience=2, stop_on_nonfinite=False))
    # spike, recover, spike, spike -> the streak resets in between and
    # only the second consecutive pair trips the stop
    reports = feed(mon, [1.0, 1.0, 5.0, 1.0, 5.0, 5.0])
    stops = [r.should_stop for r in reports]
    assert stops == [False, False, False, False, False, True]
    assert reports[-1].consecutive_alarmed == 2
    assert reports[-1].alarmed_rounds == 3


def test_state_dict_roundtrip_resumes_mid_window():
    """Split a record stream at an arbitrary cut: a fresh monitor loaded
    from state_dict() must produce the exact same reports on the tail as
    the uninterrupted monitor (no blind re-warm-up)."""
    losses = [1.0, 1.1, 0.9, float("nan"), 1.0, 5.0, 1.0, 1.2, 6.0, 1.1]
    cfg = HealthConfig(window=4, min_history=3, spike_mult=3.0,
                       patience=3, stop_on_nonfinite=False)
    full = HealthMonitor(cfg)
    full_reports = feed(full, losses)
    for cut in (1, 4, 7):
        a = HealthMonitor(cfg)
        feed(a, losses[:cut])
        b = HealthMonitor(cfg)
        b.load_state_dict(a.state_dict())
        tail = [b.observe(rec(cut + i, lo))
                for i, lo in enumerate(losses[cut:])]
        for r_full, r_res in zip(full_reports[cut:], tail):
            assert r_full == r_res, cut
    assert full.state_dict() == b.state_dict()


# ---------------- trainer integration ----------------

NUM_CLIENTS, K = 8, 3


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 2) + 1)]


def make_trainer(plan=None, *, rounds=6, **exec_kw):
    kw = dict(clients_per_round=K, seed=7, eval_every=10 ** 9)
    kw.update(exec_kw)
    return FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                            ExecConfig(rounds=rounds, **kw),
                            algo=AlgoConfig(name="feddpc", eta_l=0.05,
                                            eta_g=0.1),
                            fault_plan=plan)


def test_trainer_stops_on_injected_nan():
    """An unguarded NaN plan + ExecConfig(health=True): the run stops
    the round the loss goes non-finite instead of training on poison."""
    plan = FaultPlan.seeded(7, nan_rate=1.0, nan_rounds=(2,))
    with make_trainer(plan, health=True) as tr:
        recs = tr.run()
    assert len(recs) < 6
    rep = tr.health_report
    assert rep is not None and rep.should_stop
    assert "nonfinite_loss" in rep.alarms
    assert not np.isfinite(recs[-1].train_loss)


def test_trainer_stops_on_loss_spike_with_patience():
    """A finite 50x delta explosion (guard off) must trip the spike
    detector — and with patience=1 the run early-stops on it."""
    plan = FaultPlan.seeded(3, explode_rate=1.0, explode_rounds=(4,),
                            explode_magnitude=200.0)
    with make_trainer(plan, rounds=8, health=True, health_min_history=3,
                      health_spike_mult=3.0, health_patience=1) as tr:
        recs = tr.run()
    rep = tr.health_report
    assert rep is not None and rep.should_stop, rep
    assert "loss_spike" in rep.alarms or "nonfinite_loss" in rep.alarms
    assert len(recs) < 8
    assert rep.round == recs[-1].round


def test_healthy_run_is_untouched_and_reports_healthy():
    with make_trainer(None) as tr:
        base = tr.run()
        assert tr.health_report is None         # monitor off by default
    with make_trainer(None, health=True) as tr:
        recs = tr.run()
        rep = tr.health_report
    assert len(recs) == len(base) == 6
    assert rep is not None and rep.healthy and not rep.should_stop
    assert rep.alarmed_rounds == 0
    # the monitor is a pure observer: losses are bitwise the plain run's
    np.testing.assert_array_equal([r.train_loss for r in base],
                                  [r.train_loss for r in recs])


def test_health_state_resumes_bitwise():
    """Detector state rides the checkpoint: the resumed run's monitor
    ends bitwise-identical to the uninterrupted one's (same windows,
    same counters), so a spike straddling the cut is still caught."""
    kw = dict(health=True, health_window=4, health_min_history=2,
              health_spike_mult=3.0, health_patience=2)
    with make_trainer(None, **kw) as tr:
        tr.run()
        full_state = tr._health.state_dict()
    with tempfile.TemporaryDirectory() as d:
        with make_trainer(None, **kw) as tr:
            for t in range(3):
                tr.run_round(t)
            tr.save(d)
        tr2 = FederatedTrainer.resume(
            d, loss_fn, make_params(), NUM_CLIENTS, batch_fn,
            ExecConfig(rounds=6, clients_per_round=K, seed=7,
                       eval_every=10 ** 9, **kw),
            algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1))
        assert tr2._health is not None
        # the restored windows hold the pre-save rounds, not a blank
        assert len(tr2._health.state_dict()["loss"]) > 0
        with tr2:
            tr2.run()
    assert tr2._health.state_dict() == full_state


def test_health_resume_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        with make_trainer(None, health=True) as tr:
            tr.run_round(0)
            tr.save(d)
        with pytest.raises(ValueError, match="health"):
            FederatedTrainer.resume(
                d, loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                ExecConfig(rounds=6, clients_per_round=K, seed=7,
                           eval_every=10 ** 9),
                algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1))


# ---------------- verdict streaming (pluggable sink) ----------------

import json                                               # noqa: E402
import os                                                 # noqa: E402

from repro.health.monitor import JsonlHealthSink          # noqa: E402


class FakeSink:
    """Minimal wandb-style tracker: log(data, step) + close()."""

    def __init__(self, fail_after=None):
        self.rows = []
        self.closed = False
        self.fail_after = fail_after

    def log(self, data, step=None):
        if self.fail_after is not None and len(self.rows) >= self.fail_after:
            raise IOError("tracker down")
        self.rows.append((step, dict(data)))

    def close(self):
        self.closed = True


def test_sink_streams_one_flat_row_per_observe():
    sink = FakeSink()
    mon = HealthMonitor(HealthConfig(min_history=2, spike_mult=3.0),
                        sink=sink)
    feed(mon, [1.0, 1.0, 100.0])
    assert len(sink.rows) == 3
    step, row = sink.rows[-1]
    assert step == 2 and row["round"] == 2
    assert row["alarms"] == "loss_spike" and row["healthy"] is False
    assert sink.rows[0][1]["healthy"] is True
    assert isinstance(row["train_loss"], float)
    mon.close_sink()
    assert sink.closed


def test_failing_sink_disables_with_a_warning_and_the_run_continues():
    sink = FakeSink(fail_after=1)
    mon = HealthMonitor(HealthConfig(), sink=sink)
    with pytest.warns(RuntimeWarning, match="disabled"):
        feed(mon, [1.0, 1.0, 1.0])
    assert len(sink.rows) == 1
    assert mon._sink is None                 # dropped, not retried
    assert mon.last_report.round == 2        # verdicts kept coming


def test_jsonl_sink_is_lazy_flushed_and_replayable(tmp_path):
    path = str(tmp_path / "health.jsonl")
    sink = JsonlHealthSink(path)
    assert not os.path.exists(path)          # lazy: no log, no file
    mon = HealthMonitor(HealthConfig(min_history=2, spike_mult=3.0),
                        sink=sink)
    feed(mon, [1.0, 1.0, 100.0])
    # flushed per verdict: readable BEFORE close (a killed run keeps
    # everything emitted so far)
    with open(path) as fh:
        rows = [json.loads(line) for line in fh]
    mon.close_sink()
    assert [r["step"] for r in rows] == [0, 1, 2]
    assert rows[0]["healthy"] and rows[1]["healthy"]
    assert rows[2]["alarms"] == "loss_spike" and not rows[2]["healthy"]


def test_trainer_health_log_streams_the_whole_run(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with make_trainer(None, health=True, health_log=path) as tr:
        recs = tr.run()
    with open(path) as fh:
        rows = [json.loads(line) for line in fh]
    assert [r["step"] for r in rows] == [rec.round for rec in recs]
    assert all(r["healthy"] for r in rows)


def test_trainer_takes_a_sink_object_and_closes_it():
    sink = FakeSink()
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                          ExecConfig(rounds=3, clients_per_round=K, seed=7,
                                     eval_every=10 ** 9, health=True),
                          algo=AlgoConfig(name="feddpc", eta_l=0.05,
                                          eta_g=0.1),
                          health_sink=sink) as tr:
        recs = tr.run()
    assert [s for s, _ in sink.rows] == [rec.round for rec in recs]
    assert sink.closed                       # context exit released it


def test_sink_config_errors():
    with pytest.raises(ValueError, match="health"):
        make_trainer(None, health_log="x.jsonl")     # needs health=True
    with pytest.raises(ValueError, match="not both"):
        FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                         ExecConfig(rounds=3, clients_per_round=K, seed=7,
                                    eval_every=10 ** 9, health=True,
                                    health_log="x.jsonl"),
                         algo=AlgoConfig(name="feddpc", eta_l=0.05,
                                         eta_g=0.1),
                         health_sink=FakeSink())
