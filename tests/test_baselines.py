"""Baseline server rules: semantics + one-round behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import ALGORITHM_NAMES, get_algorithm
from repro.core import projection as proj


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (5, 3))}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _flat(t):
    return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(t)])


@pytest.mark.parametrize("name", ALGORITHM_NAMES)
def test_every_algorithm_runs_two_rounds(name):
    algo = get_algorithm(name)
    params = _params()
    state = algo.init(params, 6)
    for t in range(2):
        deltas = _stack([_params(3 * t + i + 1) for i in range(3)])
        ids = jnp.asarray([0, 2, 4], jnp.int32)
        params, state, diag = algo.step(state, params, deltas, ids, 0.1, t)
    assert not jnp.isnan(_flat(params)).any()


def test_fedavg_is_plain_mean():
    algo = get_algorithm("fedavg")
    params = _params()
    state = algo.init(params, 4)
    deltas = _stack([_params(i + 1) for i in range(2)])
    new_p, _, _ = algo.step(state, params, deltas,
                            jnp.asarray([0, 1]), 1.0, 0)
    mean = jax.tree.map(lambda x: x.mean(0), deltas)
    want = jax.tree.map(lambda w, d: w - d, params, mean)
    np.testing.assert_allclose(_flat(new_p), _flat(want), rtol=1e-6)


def test_fedexp_extrapolation_at_least_one():
    algo = get_algorithm("fedexp")
    params = _params()
    state = algo.init(params, 4)
    # anti-correlated updates -> small mean -> large extrapolation
    d1 = _params(1)
    d2 = jax.tree.map(lambda x: -x + 0.01, d1)
    _, _, diag = algo.step(state, params, _stack([d1, d2]),
                           jnp.asarray([0, 1]), 1.0, 0)
    assert float(diag["extrap"]) >= 1.0


def test_fedvarp_uses_memory_for_absent_clients():
    algo = get_algorithm("fedvarp")
    params = _params()
    state = algo.init(params, 3)
    # round 1: clients {0,1} participate
    d0, d1 = _params(1), _params(2)
    params1, state, _ = algo.step(state, params, _stack([d0, d1]),
                                  jnp.asarray([0, 1]), 1.0, 0)
    # y table now holds d0, d1, 0
    np.testing.assert_allclose(_flat({"w": state["y"]["w"][0]}), _flat(d0),
                               rtol=1e-6)
    # round 2: only client 2 participates with delta d2;
    # Delta = mean(y) + (d2 - y[2]) = (d0+d1+0)/3 + d2
    d2 = _params(3)
    p_before = params1
    params2, state, _ = algo.step(state, p_before, _stack([d2]),
                                  jnp.asarray([2]), 1.0, 1)
    want_delta = (_flat(d0) + _flat(d1)) / 3.0 + _flat(d2)
    got_delta = _flat(p_before) - _flat(params2)
    np.testing.assert_allclose(got_delta, want_delta, rtol=1e-5, atol=1e-6)


def test_client_variants_assigned():
    assert get_algorithm("fedprox").client_variant == "prox"
    assert get_algorithm("fedcm").client_variant == "cm"
    assert get_algorithm("fedga").client_variant == "ga"
    assert get_algorithm("feddpc").client_variant == "plain"
    # fedcm/fedga broadcast the previous global update to clients
    algo = get_algorithm("fedcm")
    st = algo.init(_params(), 4)
    assert algo.client_extra(st) is not None
    assert get_algorithm("feddpc").client_extra({"delta_prev": 0}) is None
