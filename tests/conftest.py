import jax
import pytest

# Tests run on the default 1-device CPU (the 512-device override is ONLY in
# launch/dryrun.py). Force f32 for determinism of small-model checks.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
