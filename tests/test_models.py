"""Model-layer invariants: attention impl equivalence, cache-vs-full
equivalence, MoE dispatch properties, SSM decode==prefill, MLA absorbed
decode == materialized path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import get_config
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tf

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------- attention ----------------

def test_blocked_equals_reference(rng):
    ks = jax.random.split(rng, 3)
    b, s, h, kv, d = 2, 320, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    ref = attn.sdpa(q, k, v, pos, pos, impl="reference")
    blk = attn._sdpa_blocked(q, k, v, pos, pos, window=0, block=64)
    np.testing.assert_allclose(ref, blk, rtol=2e-5, atol=2e-5)


def test_cached_decode_equals_full_attention(rng):
    """Prefill S tokens into a cache then decode token S; must equal the
    last-position output of full attention over S+1 tokens."""
    cfg = get_config("starcoder2-3b", smoke=True)
    p = attn.init_gqa(rng, cfg, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.fold_in(rng, 1),
                          (b, s + 1, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s + 1, dtype=jnp.int32)[None],
                           (b, s + 1))
    full, _ = attn.gqa_forward(cfg, p, x, pos)
    cache = attn.init_kv_cache(cfg, b, s + 4, jnp.float32)
    _, cache = attn.gqa_forward(cfg, p, x[:, :s], pos[:, :s], cache=cache)
    dec, _ = attn.gqa_forward(cfg, p, x[:, s:], pos[:, s:], cache=cache)
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=2e-4, atol=2e-4)


def test_ring_buffer_wraparound(rng):
    """Cache capacity < stream length: ring buffer keeps the latest window."""
    cfg = get_config("starcoder2-3b", smoke=True)
    p = attn.init_gqa(rng, cfg, jnp.float32)
    b, cap, total, w = 1, 8, 14, 8
    x = jax.random.normal(jax.random.fold_in(rng, 2),
                          (b, total, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(total, dtype=jnp.int32)[None],
                           (b, total))
    cache = attn.init_kv_cache(cfg, b, cap, jnp.float32)
    outs = []
    for t in range(total):
        o, cache = attn.gqa_forward(cfg, p, x[:, t:t + 1], pos[:, t:t + 1],
                                    window=w, cache=cache)
        outs.append(o)
    # compare final step with windowed full attention
    full, _ = attn.gqa_forward(cfg, p, x, pos, window=w)
    np.testing.assert_allclose(outs[-1][:, 0], full[:, -1],
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_equals_materialized(rng):
    cfg = get_config("deepseek-v2-236b", smoke=True)
    p = attn.init_mla(rng, cfg, jnp.float32)
    b, s = 2, 10
    x = jax.random.normal(jax.random.fold_in(rng, 3),
                          (b, s + 1, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s + 1, dtype=jnp.int32)[None],
                           (b, s + 1))
    full, _ = attn.mla_forward(cfg, p, x, pos)            # materialized path
    cache = attn.init_mla_cache(cfg, b, s + 2, jnp.float32)
    _, cache = attn.mla_forward(cfg, p, x[:, :s], pos[:, :s], cache=cache)
    dec, _ = attn.mla_forward(cfg, p, x[:, s:], pos[:, s:], cache=cache)
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=3e-4, atol=3e-4)


# ---------------- MoE ----------------

def test_moe_identity_when_single_expert(rng):
    """1 expert, top-1, ample capacity: MoE == dense expert MLP on all
    tokens (gate weight == 1)."""
    cfg = get_config("kimi-k2-1t-a32b", smoke=True).with_(
        num_experts=1, top_k=1, num_shared_experts=0, capacity_factor=8.0)
    p = moe_mod.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 8, cfg.d_model))
    out, aux = moe_mod.moe_forward(cfg, p, x)
    h = jnp.einsum("bsd,df->bsf", x, p["gate"][0])
    h = jax.nn.silu(h) * jnp.einsum("bsd,df->bsf", x, p["up"][0])
    want = jnp.einsum("bsf,fd->bsd", h, p["down"][0])
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)
    assert np.isclose(float(aux), cfg.router_aux_coef, rtol=1e-3)  # E=1: aux=1


@given(st.integers(0, 1000))
def test_moe_slot_tables_invariants(seed):
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    k = jax.random.PRNGKey(seed)
    g, t = 1, 16
    logits = jax.random.normal(k, (g, t, cfg.num_experts))
    gates, idx, aux = moe_mod._route(cfg, logits)
    cap = 4
    slot_token, slot_gate = moe_mod._slot_tables(cfg, idx, gates, cap)
    st_np = np.asarray(slot_token)            # (G, E*C)
    assert st_np.shape == (g, cfg.num_experts * cap)
    # every slot is a valid token id or the dummy T
    assert ((st_np >= 0) & (st_np <= t)).all()
    # no token appears twice within one expert's slots
    for e in range(cfg.num_experts):
        s = st_np[0, e * cap:(e + 1) * cap]
        real = s[s < t]
        assert len(np.unique(real)) == len(real)
    # gates of dummy slots are zero
    assert (np.asarray(slot_gate)[st_np == t] == 0).all()


def test_moe_capacity_drops_tokens():
    """With capacity 0-ish (min floor), over-subscribed experts drop
    overflow tokens: output for dropped tokens comes only from other
    experts/shared — total output norm decreases vs ample capacity."""
    cfg = get_config("kimi-k2-1t-a32b", smoke=True).with_(
        num_shared_experts=0)
    k = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(k, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(k, 1), (1, 64, cfg.d_model))
    out_ample, _ = moe_mod.moe_forward(
        cfg.with_(capacity_factor=64.0), p, x)
    out_tight, _ = moe_mod.moe_forward(
        cfg.with_(capacity_factor=0.01), p, x)
    assert (float(jnp.linalg.norm(out_tight))
            < float(jnp.linalg.norm(out_ample)))


def test_moe_grouping_invariance(rng):
    """Same routing results regardless of dispatch group count."""
    cfg = get_config("deepseek-v2-236b", smoke=True).with_(
        capacity_factor=8.0)
    p = moe_mod.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (4, 8, cfg.d_model))
    o1, _ = moe_mod.moe_forward(cfg, p, x, groups=1)
    o2, _ = moe_mod.moe_forward(cfg, p, x, groups=4)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


# ---------------- SSM ----------------

def test_ssm_decode_matches_prefill(rng):
    cfg = get_config("falcon-mamba-7b", smoke=True)
    p = ssm_mod.init_mamba(rng, cfg, jnp.float32)
    b, s = 2, 9
    x = jax.random.normal(jax.random.fold_in(rng, 1),
                          (b, s + 1, cfg.d_model), jnp.float32)
    y_full, _ = ssm_mod.mamba_forward(cfg, p, x)
    _, state = ssm_mod.mamba_forward(cfg, p, x[:, :s])
    y_dec, _ = ssm_mod.mamba_forward(cfg, p, x[:, s:], state=state)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1],
                               rtol=3e-4, atol=3e-4)


def test_ssm_state_continuation(rng):
    """Splitting a sequence across two prefills == one prefill."""
    cfg = get_config("falcon-mamba-7b", smoke=True)
    p = ssm_mod.init_mamba(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 4),
                          (1, 16, cfg.d_model), jnp.float32)
    y_full, st_full = ssm_mod.mamba_forward(cfg, p, x)
    _, st1 = ssm_mod.mamba_forward(cfg, p, x[:, :7])
    y2, st2 = ssm_mod.mamba_forward(cfg, p, x[:, 7:], state=st1)
    np.testing.assert_allclose(y2, y_full[:, 7:], rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st2["h"], st_full["h"], rtol=3e-4, atol=3e-4)
