"""Local training semantics (paper Algorithm 1, client side)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import make_local_update
from repro.ingest import stack_batches


def quad_loss(params, batch):
    # ||w - target||^2 weighted by batch scale
    return jnp.sum((params["w"] - batch["target"]) ** 2) * batch["scale"]


def _batches(n, seed=0):
    k = jax.random.PRNGKey(seed)
    return [{"target": jax.random.normal(jax.random.fold_in(k, i), (3,)),
             "scale": jnp.float32(1.0)} for i in range(n)]


def test_delta_matches_manual_sgd():
    eta = 0.1
    fn = make_local_update(quad_loss, eta)
    w0 = {"w": jnp.array([1.0, -1.0, 0.5])}
    bl = _batches(3)
    batches, mask = stack_batches(bl, 3)
    delta, loss = fn(w0, batches, mask, None)
    # manual
    w = dict(w0)
    for b in bl:
        g = jax.grad(quad_loss)(w, b)
        w = {"w": w["w"] - eta * g["w"]}
    want = (w0["w"] - w["w"]) / eta
    np.testing.assert_allclose(delta["w"], want, rtol=1e-5, atol=1e-6)


def test_mask_padding_is_noop():
    fn = make_local_update(quad_loss, 0.1)
    w0 = {"w": jnp.zeros(3)}
    bl = _batches(2)
    b2, m2 = stack_batches(bl, 2)
    b4, m4 = stack_batches(bl, 4)          # padded with repeats + mask False
    d2, _ = fn(w0, b2, m2, None)
    d4, _ = fn(w0, b4, m4, None)
    np.testing.assert_allclose(d2["w"], d4["w"], rtol=1e-6)


def test_prox_term_pulls_toward_global():
    """Larger mu -> smaller distance from the global model. Uses one fixed
    target for every local step: with i.i.d. targets the mu-dependent
    forgetting rate confounds the drift (a larger mu also up-weights the
    most recent targets, which can dominate at few local steps)."""
    w0 = {"w": jnp.zeros(3)}
    bl = [_batches(1, seed=3)[0]] * 5
    batches, mask = stack_batches(bl, 5)
    dist = {}
    for mu in (0.0, 1.0):      # mu within the stable regime (eta*mu << 1)
        fn = make_local_update(quad_loss, 0.05, variant="prox", mu=mu)
        delta, _ = fn(w0, batches, mask, None)
        dist[mu] = float(jnp.linalg.norm(delta["w"]))
    assert dist[1.0] < dist[0.0]


def test_cm_momentum_mixes_previous_global():
    """With cm_alpha=0 the gradient IS the previous global update, so
    delta = local_iters * Delta_prev exactly."""
    fn = make_local_update(quad_loss, 0.1, variant="cm", cm_alpha=0.0)
    w0 = {"w": jnp.zeros(3)}
    prev = {"w": jnp.array([1.0, 2.0, 3.0])}
    batches, mask = stack_batches(_batches(4), 4)
    delta, _ = fn(w0, batches, mask, prev)
    np.testing.assert_allclose(delta["w"], 4.0 * prev["w"], rtol=1e-5)


def test_ga_displaced_initialization():
    """With 0 valid batches... not allowed; instead check ga shifts the
    result: delta includes the displacement beta*eta*Delta_prev/eta."""
    w0 = {"w": jnp.zeros(3)}
    prev = {"w": jnp.array([1.0, 1.0, 1.0])}
    batches, mask = stack_batches(_batches(1, seed=5), 1)
    f_plain = make_local_update(quad_loss, 0.1, variant="plain")
    f_ga = make_local_update(quad_loss, 0.1, variant="ga", ga_beta=0.5)
    d_plain, _ = f_plain(w0, batches, mask, None)
    d_ga, _ = f_ga(w0, batches, mask, prev)
    assert not np.allclose(d_plain["w"], d_ga["w"])
