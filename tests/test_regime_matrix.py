"""Cross-regime equivalence test matrix (DESIGN.md §2/§3).

The regime surface — algorithms x participation samplers x execution
regimes x prefetch — has outgrown hand-written equivalence tests. This
matrix is GENERATED from the live registries (``ALGORITHM_NAMES``,
``sampler_matrix``, ``EXEC_REGIMES``), so a newly registered algorithm,
sampler, or execution regime auto-enrolls; every cell must be
round-for-round allclose to the serial reference.

The device count locks at jax init, so the checks run on a forced
8-device subprocess (tests/_regime_matrix_check.py). Tier-1 keeps a fast
representative slice (every regime, the 2x4 acceptance mesh for
feddpc/fedavg/fedvarp, non-uniform samplers); the full
algorithm-diagonal sweep is marked slow.
"""
import os
import subprocess
import sys

import pytest

from repro.core.api import EXEC_REGIMES
from repro.core.baselines import ALGORITHM_NAMES
from repro.core.samplers import sampler_matrix

ALGOS = tuple(ALGORITHM_NAMES)
SAMPLERS = tuple(sampler_matrix(8, 2))
REGIMES = tuple(EXEC_REGIMES)


def full_matrix():
    """Every cell: (algorithm, sampler, regime, prefetch)."""
    return [(a, s, r, p) for a in ALGOS for s in SAMPLERS
            for r in REGIMES for p in (True, False)]


def _cell_str(cell):
    a, s, r, p = cell
    return f"{a}:{s}:{r}:{'P' if p else 'N'}"


def _run_check(args, timeout=900):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable,
         os.path.join(root, "tests", "_regime_matrix_check.py"), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL OK" in proc.stdout


# the tier-1 slice: every execution regime at least once, the (2 clients
# x 4 model) acceptance mesh for feddpc/fedavg/fedvarp, every non-uniform
# sampler against a non-serial regime, and the staged-ingest acceptance
# cells (prefetch_depth=4 + device staging on every mesh shape, plus the
# host-staged single-buffer degenerate point — DESIGN.md §10)
FAST_SLICE = [
    ("feddpc", "uniform", "serial", True),
    ("feddpc", "uniform", "vectorized", True),
    ("feddpc", "uniform", "sharded1d", True),
    ("feddpc", "uniform", "sharded2d", True),
    ("fedavg", "uniform", "sharded2d", True),
    ("fedvarp", "uniform", "sharded2d", True),
    ("feddpc", "markov", "sharded2d", True),
    ("fedexp", "cyclic", "sharded1d", False),
    ("fedvarp", "weighted", "vectorized", True),
    ("feddpc", "uniform", "staged", True),
    ("feddpc", "uniform", "staged1d", True),
    ("feddpc", "uniform", "staged2d", True),
    ("fedvarp", "markov", "staged2d", True),
    ("feddpc", "uniform", "hoststaged", True),
    # buffered-async anchor cells (DESIGN.md §11): DeterministicRuntime
    # + B=K + concurrency 1 must reproduce the synchronous round — for
    # the staleness-aware rule and a pre-scaling (FedBuff-mean) rule
    ("feddpc", "uniform", "async_buffer", True),
    ("feddpc", "uniform", "async_buffer", False),
    ("fedvarp", "markov", "async_buffer", True),
    # delta codecs (DESIGN.md §13): the identity anchor plus the lossy
    # acceptance cells — int8 under the (2x4) mesh and the async engine
    ("feddpc", "uniform", "codec_identity", True),
    ("feddpc", "uniform", "codec_bf16", True),
    ("feddpc", "uniform", "codec_int8", True),
    ("fedavg", "weighted", "codec_int8", False),
    ("feddpc", "uniform", "codec_int8_2d", True),
    ("feddpc", "uniform", "codec_int8_async", True),
    # adaptive server optimizers (DESIGN.md §14): every optimizer under
    # every execution shape — serial anchors live in the reference cache,
    # so each of these checks vectorized/2-axis/async == same-opt serial
    ("feddpc", "uniform", "server_fedadam", True),
    ("feddpc", "uniform", "server_fedadam_2d", True),
    ("feddpc", "uniform", "server_fedadam_async", True),
    ("fedavg", "uniform", "server_fedyogi", True),
    ("feddpc", "markov", "server_fedyogi_2d", True),
    ("feddpc", "uniform", "server_fedyogi_async", False),
    # hierarchical edge aggregation (DESIGN.md §15): the single-process
    # anchor cells — 8-device client mesh folded through 2 edge
    # aggregators must equal the flat serial fold; the genuinely
    # multi-PROCESS form of the same shape runs in
    # test_multihost_two_process below
    ("feddpc", "uniform", "multihost", True),
    ("fedavg", "uniform", "multihost", False),
    ("fedvarp", "markov", "multihost", True),
]


def test_matrix_axes_come_from_the_registries():
    """Auto-enroll guard: the axes are read from the live registries, so
    a new algorithm/sampler/regime lands in full_matrix() without
    touching the tests — and the slices stay valid sub-sets."""
    assert {"serial", "vectorized", "sharded1d", "sharded2d",
            "staged", "staged1d", "staged2d",
            "hoststaged", "async_buffer",
            "codec_identity", "codec_bf16", "codec_int8",
            "codec_int8_2d", "codec_int8_async"} <= set(REGIMES)
    assert {"uniform", "weighted", "cyclic", "markov"} <= set(SAMPLERS)
    assert {"feddpc", "fedavg", "fedvarp", "fedexp"} <= set(ALGOS)
    cells = set(full_matrix())
    assert len(cells) == len(ALGOS) * len(SAMPLERS) * len(REGIMES) * 2
    assert set(FAST_SLICE) <= cells
    # the 2-D path enrolled automatically (acceptance criterion)
    assert EXEC_REGIMES["sharded2d"]["shard_model"] > 1
    # staged ingest (DESIGN.md §10) enrolled at the acceptance depth on
    # every mesh shape, device-staged by default
    for reg in ("staged", "staged1d", "staged2d"):
        assert EXEC_REGIMES[reg]["prefetch_depth"] == 4
    assert EXEC_REGIMES["staged2d"]["shard_model"] > 1
    assert EXEC_REGIMES["hoststaged"]["device_stage"] is False
    # buffered-async streaming aggregation enrolled (DESIGN.md §11);
    # its registry defaults ARE the sync-equivalence anchor cell
    assert EXEC_REGIMES["async_buffer"]["async_buffer"] is True
    # delta codecs enrolled (DESIGN.md §13) at the acceptance shapes:
    # int8 on the 2-axis mesh and through the buffered-async engine
    assert EXEC_REGIMES["codec_identity"]["codec"] == "identity"
    assert EXEC_REGIMES["codec_bf16"]["codec"] == "bf16"
    assert EXEC_REGIMES["codec_int8"]["codec"] == "int8"
    assert EXEC_REGIMES["codec_int8_2d"]["shard_model"] > 1
    assert EXEC_REGIMES["codec_int8_async"]["async_buffer"] is True
    # adaptive server optimizers enrolled (DESIGN.md §14) at the
    # acceptance shapes: each optimizer serial, on the 2-axis mesh, and
    # through the buffered-async engine
    assert {"server_fedadam", "server_fedadam_2d", "server_fedadam_async",
            "server_fedyogi", "server_fedyogi_2d",
            "server_fedyogi_async"} <= set(REGIMES)
    assert EXEC_REGIMES["server_fedadam"]["server_opt"] == "fedadam"
    assert EXEC_REGIMES["server_fedyogi"]["server_opt"] == "fedyogi"
    assert EXEC_REGIMES["server_fedadam_2d"]["shard_model"] > 1
    assert EXEC_REGIMES["server_fedyogi_2d"]["shard_model"] > 1
    assert EXEC_REGIMES["server_fedadam_async"]["async_buffer"] is True
    assert EXEC_REGIMES["server_fedyogi_async"]["async_buffer"] is True
    from repro.optim.server import SERVER_OPTIMIZER_NAMES
    assert set(SERVER_OPTIMIZER_NAMES) == {"sgd", "fedadam", "fedyogi"}
    # hierarchical edge aggregation enrolled (DESIGN.md §15): clients
    # axis sharded, two edge aggregators between clients and server
    assert EXEC_REGIMES["multihost"]["shard_clients"] is True
    assert EXEC_REGIMES["multihost"]["edges"] == 2


def test_regime_matrix_fast_slice():
    _run_check(["--cells", ",".join(map(_cell_str, FAST_SLICE))])


def test_cross_mesh_resume():
    _run_check(["--cross-mesh-resume"])


def test_kernel_fallback_model_sharded():
    _run_check(["--kernel-fallback"])


def test_codec_identity_bitwise():
    """codec=identity is a pass-through: bitwise-identical params/state/
    losses to the no-codec run under every regime shape (serial,
    vectorized, 2-axis mesh, buffered-async)."""
    _run_check(["--codec-identity-bitwise"])


@pytest.fixture(scope="module")
def multihost_artifacts(tmp_path_factory):
    """Run the genuinely multi-PROCESS worker once (2 jax.distributed
    processes x 2 CPU devices each, 127.0.0.1 coordinator, no external
    network) and hand its artifacts — the cross-process checkpoint and
    process 0's final-state dump — to the dependent tests."""
    from repro.launch.distributed import spawn_local
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    d = tmp_path_factory.mktemp("multihost")
    ckpt, out = str(d / "ckpt"), str(d / "final.npz")
    results = spawn_local(
        [sys.executable, os.path.join(root, "tests", "_multihost_worker.py"),
         "--ckpt", ckpt, "--out", out],
        2, devices_per_process=2,
        env={"PYTHONPATH": os.path.join(root, "src")}, timeout_s=600)
    return ckpt, out, results


def test_multihost_two_process(multihost_artifacts):
    """Tentpole acceptance: a 2-process hierarchical round (each process
    one edge aggregator over its local client shard) reproduces the
    serial reference for feddpc/fedavg/fedvarp, is bitwise stable across
    prefetch on/off, and checkpoints bitwise within the job. The worker
    asserts all of that in-job on BOTH processes; here we check both
    children finished and reported every stage."""
    _, _, results = multihost_artifacts
    assert len(results) == 2
    for rc, out, _err in results:
        assert rc == 0
        for name in ("feddpc", "fedavg", "fedvarp"):
            assert f"{name}: 2-process hierarchical == serial OK" in out
        assert "save/resume bitwise OK" in out
        assert "MULTIHOST_WORKER_OK" in out


def test_multihost_resume_single_process(multihost_artifacts):
    """Cross-process -> single-process resume: the checkpoint written by
    process 0 of the 2-process job resumes on ONE plain process and
    lands allclose on the 2-process run's final state."""
    ckpt, out, _ = multihost_artifacts
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for extra in ([], ["--resume-sharded"]):
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.join(root, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        env.setdefault("JAX_PLATFORMS", "cpu")
        if extra:
            # the sharded resume keeps edges=2, which needs the padded
            # cohort even — force 2 host devices so K=3 pads to 4
            flags = " ".join(
                f for f in env.get("XLA_FLAGS", "").split()
                if not f.startswith(
                    "--xla_force_host_platform_device_count"))
            env["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=2").strip()
        proc = subprocess.run(
            [sys.executable,
             os.path.join(root, "tests", "_multihost_worker.py"),
             "--resume", "--ckpt", ckpt, "--expect", out, *extra],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
        assert "MULTIHOST_RESUME_OK" in proc.stdout


@pytest.mark.slow
def test_regime_matrix_diagonal():
    """Every algorithm under every regime, with sampler and prefetch
    rotating per algorithm so all matrix axis values keep appearing —
    one subprocess so the serial references are shared across regimes."""
    cells = [(a, SAMPLERS[i % len(SAMPLERS)], r, bool((i + j) % 2))
             for j, r in enumerate(REGIMES)
             for i, a in enumerate(ALGOS)]
    assert set(cells) <= set(full_matrix())
    _run_check(["--cells", ",".join(map(_cell_str, cells))])
