"""Beyond-paper server optimizers: FedAdam / FedYogi (Reddi et al.) and
FedDPC-M (projection+scaling composed with server momentum)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projection as proj
from repro.core.baselines import get_algorithm


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (5, 3))}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _flat(t):
    return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(t)])


@pytest.mark.parametrize("name", ["fedadam", "fedyogi", "feddpc_m"])
def test_runs_three_rounds(name):
    algo = get_algorithm(name)
    params = _params()
    state = algo.init(params, 4)
    for t in range(3):
        deltas = _stack([_params(3 * t + i + 1) for i in range(2)])
        params, state, diag = algo.step(state, params, deltas,
                                        jnp.asarray([0, 1]), 0.1, t)
    assert not jnp.isnan(_flat(params)).any()


def test_fedadam_normalizes_step():
    """Adam's step is ~unit-scale regardless of delta magnitude."""
    algo = get_algorithm("fedadam")
    params = _params()
    norms = {}
    for scale in (1.0, 100.0):
        state = algo.init(params, 2)
        deltas = _stack([jax.tree.map(lambda x: x * scale, _params(1))])
        new_p, _, _ = algo.step(state, params, deltas, jnp.asarray([0]),
                                1.0, 0)
        norms[scale] = float(jnp.linalg.norm(_flat(new_p) - _flat(params)))
    # 100x bigger deltas -> step grows FAR less than 100x (adaptive)
    assert norms[100.0] < norms[1.0] * 3.0


def test_feddpc_m_momentum_semantics():
    """Applied step == eta_g * (beta * m_{t-1} + Delta_t) exactly."""
    algo = get_algorithm("feddpc_m")
    params = _params()
    state = algo.init(params, 2)
    deltas = _stack([_params(1), _params(2)])
    p1, s1, _ = algo.step(state, params, deltas, jnp.asarray([0, 1]), 0.1, 0)
    deltas2 = _stack([_params(7), _params(8)])
    p2, s2, _ = algo.step(s1, p1, deltas2, jnp.asarray([0, 1]), 0.1, 1)
    want = _flat(p1) - 0.1 * (0.9 * _flat(s1["m"])
                              + _flat(s2["delta_prev"]))
    np.testing.assert_allclose(_flat(p2), want, rtol=1e-4, atol=1e-5)


def test_feddpc_m_projection_preserved():
    """FedDPC-M's aggregated Delta_t stays orthogonal to Delta_{t-1}
    (momentum smooths the APPLIED step, not the stored direction)."""
    algo = get_algorithm("feddpc_m")
    params = _params()
    state = algo.init(params, 2)
    state["delta_prev"] = _params(50)
    deltas = _stack([_params(1), _params(2)])
    _, new_s, _ = algo.step(state, params, deltas, jnp.asarray([0, 1]),
                            0.1, 0)
    dot = float(proj.tree_vdot(new_s["delta_prev"], state["delta_prev"]))
    denom = (float(proj.tree_norm(new_s["delta_prev"]))
             * float(proj.tree_norm(state["delta_prev"])))
    assert abs(dot) / denom < 1e-3
