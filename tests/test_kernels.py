"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracle (ref.py), as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.feddpc_project import ops as fp_ops, ref as fp_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.ssm_scan import ops as ss_ops, ref as ss_ref


# ---------------- feddpc_project ----------------

@pytest.mark.parametrize("n", [32, 128, 1000, 65536, 70001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_feddpc_project_sweep(n, dtype, rng):
    k1, k2 = jax.random.split(rng)
    d = jax.random.normal(k1, (n,), dtype)
    p = jax.random.normal(k2, (n,), dtype)
    got = fp_ops.project_and_scale_flat(d, p, lam=1.0)
    want = fp_ref.project_and_scale_flat_ref(d, p, 1.0)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("k,n", [(1, 128), (3, 1000), (8, 70001)])
def test_feddpc_batched_epilogue_sweep(k, n, rng):
    """kernel.batched_epilogue (one grid over the stacked cohort) vs the
    pure-jnp oracle, across block-boundary/pad shapes."""
    from repro.kernels.feddpc_project import kernel as fp_kernel
    ks = jax.random.split(rng, 5)
    m = -(-n // 128)
    rows = max(8, fp_kernel.DEFAULT_ROWS // k)
    m += (-m) % rows                              # full blocks, like ops.py
    d3 = jax.random.normal(ks[0], (k, m, 128))
    p2 = jax.random.normal(ks[1], (m, 128))
    w2 = jax.random.normal(ks[2], (m, 128))
    coefs = jax.random.normal(ks[3], (k,))
    scales = 1.0 + jnp.abs(jax.random.normal(ks[4], (k,)))
    got_w, got_dt = fp_kernel.batched_epilogue(d3, p2, w2, coefs, scales, 0.3)
    want_w, want_dt = fp_ref.batched_epilogue_ref(d3, p2, w2, coefs, scales,
                                                  0.3)
    np.testing.assert_allclose(got_w, want_w, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got_dt, want_dt, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("round1", [True, False])
def test_feddpc_batched_server_step_matches_jnp(round1, rng):
    """feddpc.server_step(use_kernel=True) — the single-HBM-pass batched
    epilogue — matches the jnp path, including the K>1 zero-delta_prev
    round-1 case (projection degenerates to 0, scale to lam+1)."""
    from repro.core import feddpc
    ks = jax.random.split(rng, 3)
    params = {"w": jax.random.normal(ks[0], (40, 37)),
              "b": jax.random.normal(ks[1], (37,))}
    deltas = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(ks[2], x.ndim),
                                    (5,) + x.shape), params)
    prev = (jax.tree.map(jnp.zeros_like, params) if round1
            else jax.tree.map(lambda x: x * 0.3, params))
    outs = {}
    for uk in (False, True):
        outs[uk] = feddpc.server_step({"delta_prev": prev}, params, deltas,
                                      0.1, 1.0, use_kernel=uk)
    for a, b in zip(jax.tree.leaves(outs[False][:2]),
                    jax.tree.leaves(outs[True][:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    for key, va in outs[False][2].items():
        np.testing.assert_allclose(np.asarray(va),
                                   np.asarray(outs[True][2][key]),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("b,n", [(1, 512 * 128), (3, 1000), (7, 70001)])
def test_feddpc_buffer_fold_sweep(b, n, rng):
    """kernel.buffer_fold (scatter-accumulate over the arrival buffer,
    DESIGN.md §11) vs the pure-jnp oracle: the row block stays at
    DEFAULT_ROWS for every buffer size B (unlike batched_epilogue's
    K-resident block), and the staleness weights fold into the scales."""
    from repro.kernels.feddpc_project import kernel as fp_kernel
    ks = jax.random.split(rng, 6)
    m = -(-n // 128)
    m += (-m) % fp_kernel.DEFAULT_ROWS            # full blocks, like ops.py
    d3 = jax.random.normal(ks[0], (b, m, 128))
    p2 = jax.random.normal(ks[1], (m, 128))
    w2 = jax.random.normal(ks[2], (m, 128))
    coefs = jax.random.normal(ks[3], (b,))
    scales = 1.0 + jnp.abs(jax.random.normal(ks[4], (b,)))
    wgts = jax.random.uniform(ks[5], (b,), minval=0.1, maxval=1.0)
    got_w, got_dt = fp_kernel.buffer_fold(d3, p2, w2, coefs, scales, wgts,
                                          0.3)
    want_w, want_dt = fp_ref.buffer_fold_ref(d3, p2, w2, coefs, scales,
                                             wgts, 0.3)
    np.testing.assert_allclose(got_w, want_w, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got_dt, want_dt, rtol=2e-5, atol=2e-5)


def test_feddpc_buffer_fold_unit_weights_match_batched_epilogue(rng):
    """At weights==1 (the zero-staleness anchor) the buffer fold is the
    plain batched epilogue on the same inputs — the property that makes
    the async anchor cell reproduce the sync round through the kernel
    path too."""
    from repro.kernels.feddpc_project import kernel as fp_kernel
    ks = jax.random.split(rng, 5)
    b, m = 4, 512
    d3 = jax.random.normal(ks[0], (b, m, 128))
    p2 = jax.random.normal(ks[1], (m, 128))
    w2 = jax.random.normal(ks[2], (m, 128))
    coefs = jax.random.normal(ks[3], (b,))
    scales = 1.0 + jnp.abs(jax.random.normal(ks[4], (b,)))
    ones = jnp.ones((b,))
    got_w, got_dt = fp_kernel.buffer_fold(d3, p2, w2, coefs, scales, ones,
                                          0.3)
    rows = max(8, fp_kernel.DEFAULT_ROWS // b)
    want_w, want_dt = fp_kernel.batched_epilogue(d3, p2, w2, coefs, scales,
                                                 0.3, rows=rows)
    np.testing.assert_allclose(got_w, want_w, rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(got_dt, want_dt, rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("round1", [True, False])
def test_feddpc_buffered_server_step_matches_jnp(round1, rng):
    """feddpc.server_step(use_kernel=True, staleness_weights=...) routes
    to ops.buffered_server_fold and matches the jnp path (which folds
    the weights into the reduction-pass scales)."""
    from repro.core import feddpc
    ks = jax.random.split(rng, 4)
    params = {"w": jax.random.normal(ks[0], (40, 37)),
              "b": jax.random.normal(ks[1], (37,))}
    deltas = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(ks[2], x.ndim),
                                    (5,) + x.shape), params)
    prev = (jax.tree.map(jnp.zeros_like, params) if round1
            else jax.tree.map(lambda x: x * 0.3, params))
    wgts = jax.random.uniform(ks[3], (5,), minval=0.2, maxval=1.0)
    outs = {}
    for uk in (False, True):
        outs[uk] = feddpc.server_step({"delta_prev": prev}, params, deltas,
                                      0.1, 1.0, use_kernel=uk,
                                      staleness_weights=wgts)
    for a, b in zip(jax.tree.leaves(outs[False][:2]),
                    jax.tree.leaves(outs[True][:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    for key, va in outs[False][2].items():
        np.testing.assert_allclose(np.asarray(va),
                                   np.asarray(outs[True][2][key]),
                                   rtol=2e-4, atol=2e-5)


def test_feddpc_batched_server_step_bf16_state_stays_f32(rng):
    """delta_prev is server STATE: both epilogue paths must keep it f32
    even for bf16 params/deltas (regression: the kernel path used to
    inherit the input dtype)."""
    from repro.core import feddpc
    ks = jax.random.split(rng, 2)
    params = {"w": jax.random.normal(ks[0], (33, 40), jnp.bfloat16)}
    deltas = {"w": jax.random.normal(ks[1], (4, 33, 40), jnp.bfloat16)}
    prev = feddpc.init_state(params)["delta_prev"]
    for uk in (False, True):
        _, state, _ = feddpc.server_step({"delta_prev": prev}, params,
                                         deltas, 0.1, 1.0, use_kernel=uk)
        assert state["delta_prev"]["w"].dtype == jnp.float32, uk


def test_feddpc_fused_dots(rng):
    k1, k2 = jax.random.split(rng)
    d = jax.random.normal(k1, (5000,))
    p = jax.random.normal(k2, (5000,))
    got = fp_ops.fused_dots_flat(d, p)
    want = jnp.stack([jnp.vdot(d, p), jnp.vdot(d, d), jnp.vdot(p, p)])
    np.testing.assert_allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("m", [8, 64, 512, 1024])
def test_feddpc_guard_dots_bitwise_vs_ref(m, rng):
    """kernel.guard_dots (the reduction pass with the update guard's
    non-finite column fused in — DESIGN.md §12) vs ref.guard_dots_ref,
    BITWISE per grid block in interpret mode: same block, same jnp
    reduction, same result — including NaN/Inf entries scattered through
    d (they must zero out of the dots and land in the count). Full
    blocks only, like ops._to_2d always produces (partial-block padding
    semantics are exactly what the padding avoids)."""
    from repro.kernels.feddpc_project import kernel as fp_kernel
    k1, k2, k3 = jax.random.split(rng, 3)
    d2 = jax.random.normal(k1, (m, fp_kernel.LANE))
    p2 = jax.random.normal(k2, (m, fp_kernel.LANE))
    bad = jax.random.uniform(k3, d2.shape) < 0.05
    d2 = jnp.where(bad, jnp.where(d2 > 0, jnp.nan, jnp.inf), d2)
    rows = min(fp_kernel.DEFAULT_ROWS, m)
    got = fp_kernel.guard_dots(d2, p2, rows=rows, interpret=True)
    want = jnp.stack([fp_ref.guard_dots_ref(d2[i:i + rows], p2[i:i + rows])
                      for i in range(0, m, rows)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the count column is exact by construction
    assert int(got[:, 3].sum()) == int(bad.sum())


def test_feddpc_guard_dots_flat_nonfinite(rng):
    """ops.guard_dots_flat (padded flat entry point) against the whole-
    array oracle: the zero padding must not inflate the non-finite
    count, and the dots must stay finite despite NaN/Inf in d."""
    k1, k2 = jax.random.split(rng)
    d = jax.random.normal(k1, (5001,))
    p = jax.random.normal(k2, (5001,))
    d = d.at[7].set(jnp.nan).at[4999].set(jnp.inf)
    got = fp_ops.guard_dots_flat(d, p)
    d2, _ = fp_ops._to_2d(d)
    p2, _ = fp_ops._to_2d(p)
    want = fp_ref.guard_dots_ref(d2, p2)
    assert np.all(np.isfinite(np.asarray(got)))
    assert int(got[3]) == 2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------- flash_attention ----------------

@pytest.mark.parametrize("b,sq,sk,h,kv,d", [
    (2, 256, 256, 8, 2, 64),
    (1, 128, 128, 4, 4, 128),
    (1, 100, 100, 4, 2, 64),          # pad path
    (2, 64, 64, 16, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, sk, h, kv, d, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), dtype)
    pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    got = fa_ops.flash_attention(q, k, v, pos, kpos)
    want = fa_ref.attention_ref(q, k, v, pos, kpos)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_sliding_window(rng):
    ks = jax.random.split(rng, 3)
    b, s, h, kv, d = 2, 384, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    got = fa_ops.flash_attention(q, k, v, pos, pos, window=100)
    want = fa_ref.attention_ref(q, k, v, pos, pos, window=100)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_ring_cache_decode(rng):
    """Decode against a partially-filled ring cache (-1 = empty slots)."""
    ks = jax.random.split(rng, 3)
    b, sk, h, kv, d = 2, 300, 16, 2, 128
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    q_pos = jnp.full((b, 1), sk, jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    k_pos = k_pos.at[:, -7:].set(-1)
    got = fa_ops.flash_attention(q, k, v, q_pos, k_pos, window=128)
    want = fa_ref.attention_ref(q, k, v, q_pos, k_pos, window=128)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_sdpa(rng):
    """Kernel == the model's blocked jnp path (impl='pallas' contract)."""
    from repro.models.attention import sdpa
    ks = jax.random.split(rng, 3)
    b, s, h, kv, d = 1, 256, 8, 4, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    got = sdpa(q, k, v, pos, pos, impl="pallas")
    want = sdpa(q, k, v, pos, pos, impl="blocked")
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------- ssm_scan ----------------

@pytest.mark.parametrize("b,s,d_in,n", [
    (2, 64, 128, 16),
    (1, 128, 256, 16),
    (2, 100, 96, 8),                  # pad path
    (1, 17, 64, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(b, s, d_in, n, dtype, rng):
    ks = jax.random.split(rng, 5)
    u = jax.random.normal(ks[0], (b, s, d_in), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d_in))) * 0.1
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d_in, n)) * 0.3)
    dsk = jnp.ones((d_in,))
    y, h = ss_ops.ssm_scan(u, dt, bm, cm, a, dsk)
    y2, h2 = ss_ref.ssm_scan_ref(u, dt, bm, cm, a, dsk)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y2, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(h, h2, rtol=2e-4, atol=2e-4)


def test_ssm_scan_matches_model_associative_scan(rng):
    from repro.configs.base import get_config
    from repro.models import ssm as ssm_mod
    from repro.models.layers import linear
    cfg = get_config("falcon-mamba-7b", smoke=True)
    p = ssm_mod.init_mamba(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1),
                          (2, 32, cfg.d_model), jnp.float32)
    y_model, _ = ssm_mod.mamba_forward(cfg, p, x)
    xz = linear(p["in_proj"], x)
    d_in = cfg.ssm_d_inner
    u, z = xz[..., :d_in], xz[..., d_in:]
    u_ext = jnp.concatenate(
        [jnp.zeros((2, cfg.ssm_conv - 1, d_in)), u], axis=1)
    u_c = jax.nn.silu(ssm_mod._conv_causal_from(p, u_ext, 32, cfg.ssm_conv))
    dt, bm, cm = ssm_mod._ssm_params(cfg, p, u_c)
    a = -jnp.exp(p["a_log"])
    yk, _ = ss_ops.ssm_scan(u_c, dt, bm, cm, a, p["d_skip"])
    y_kernel = linear(p["out_proj"], yk * jax.nn.silu(z))
    np.testing.assert_allclose(y_kernel, y_model, rtol=2e-4, atol=2e-4)
