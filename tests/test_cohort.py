"""Cohort-vectorized round == serial reference, for every server rule.

The FederatedTrainer's default path fuses the whole round (vmapped local
training + server step) into one jit'd program (core/round.py
``make_cohort_round``). These tests pin it to the historical serial path
(kept under cfg.vectorize=False) on a tiny task: same params, server
state, per-round losses, and diagnostics — plus the FedDPC invariants on
the fused path and the shape-bucketing (grow-once) compile guarantee.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import feddpc, projection as proj
from repro.core.api import FLConfig, FederatedTrainer
from repro.core.baselines import ALGORITHM_NAMES, get_algorithm
from repro.core.client import make_cohort_local_update, make_local_update
from repro.ingest import stack_batches, stack_cohort

NUM_CLIENTS = 6
K = 3


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    """(c % 3) + 1 minibatches — cohorts are ragged by construction."""
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def run_trainer(algo, vectorize, rounds=3, batch_fn=ragged_batch_fn,
                seed=7, **cfg_kw):
    kw = dict(eta_l=0.05, eta_g=0.1, seed=seed, eval_every=10 ** 9)
    kw.update(cfg_kw)
    cfg = FLConfig(algorithm=algo, rounds=rounds, clients_per_round=K,
                   vectorize=vectorize, **kw)
    tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn, cfg)
    tr.run()
    return tr


def assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------- equivalence: vectorized == serial ----------------

@pytest.mark.parametrize("algo", ALGORITHM_NAMES)
def test_vectorized_round_matches_serial(algo):
    vec = run_trainer(algo, vectorize=True)
    ser = run_trainer(algo, vectorize=False)
    assert_trees_close(vec.params, ser.params)
    assert_trees_close(vec.server_state, ser.server_state)
    for rv, rs in zip(vec.history, ser.history):
        assert np.isclose(rv.train_loss, rs.train_loss, rtol=1e-4, atol=1e-6)
        assert rv.diagnostics.keys() == rs.diagnostics.keys()
        for key in rv.diagnostics:
            assert np.isclose(rv.diagnostics[key], rs.diagnostics[key],
                              rtol=1e-3, atol=1e-4), (key, rv, rs)


def test_vectorized_is_the_default():
    assert FLConfig().vectorize is True


def test_caller_params_survive_donation():
    """The fused round donates its buffers; the caller's init tree must
    stay usable (sweeps reuse one init across trainer instances)."""
    params = make_params()
    cfg = FLConfig(algorithm="feddpc", rounds=2, clients_per_round=K,
                   eta_l=0.05, eta_g=0.1, seed=0, eval_every=10 ** 9)
    FederatedTrainer(loss_fn, params, NUM_CLIENTS, ragged_batch_fn, cfg).run()
    assert np.isfinite(np.asarray(params["w"])).all()     # not invalidated


# ---------------- FedDPC invariants on the fused path ----------------

def test_round1_degenerates_to_scaled_fedavg():
    """delta_prev = 0 => projection is 0, scale == lam + 1: one FedDPC
    round equals one FedAvg round at eta_g * (lam + 1)."""
    lam, eta_g = 1.5, 0.1
    dpc = run_trainer("feddpc", True, rounds=1, lam=lam, eta_g=eta_g)
    avg = run_trainer("fedavg", True, rounds=1, eta_g=eta_g * (lam + 1.0))
    assert_trees_close(dpc.params, avg.params)


def test_fused_orthogonality_invariant():
    """<Delta_t, Delta_{t-1}> ~ 0 for every round after the first."""
    tr = run_trainer("feddpc", True, rounds=5)
    for rec in tr.history[1:]:
        d = rec.diagnostics
        denom = max(d["norm_global_update"], 1e-9)
        assert abs(d["global_dot_prev"]) / (denom * denom + 1e-9) < 0.05


def test_zero_residual_client_is_finite():
    """A client whose update IS the projection direction (delta ==
    delta_prev) has norm_resid -> 0; the lam + ||d||/||r|| scale must stay
    finite and the aggregate free of NaN/Inf through the fused step."""
    params = make_params()
    delta_prev = {"w": jnp.ones((4, 3), jnp.float32),
                  "b": jnp.ones((3,), jnp.float32)}
    r = np.random.RandomState(0)
    other = {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
             "b": jnp.asarray(r.randn(3), jnp.float32)}
    deltas = jax.tree.map(lambda a, b: jnp.stack([a, b]), delta_prev, other)
    step = jax.jit(lambda s, p, d: feddpc.server_step(s, p, d, 0.1, 1.0))
    new_params, new_state, diag = step(
        {"delta_prev": delta_prev}, params, deltas)
    for leaf in (jax.tree.leaves(new_params) + jax.tree.leaves(new_state)
                 + jax.tree.leaves(diag)):
        assert np.isfinite(np.asarray(leaf)).all()
    # the zero-residual client contributes ~nothing after projection
    scaled, d1 = proj.project_and_scale(delta_prev, delta_prev, lam=1.0)
    assert float(d1["norm_resid"]) < 1e-3
    assert np.isfinite(np.asarray(jax.tree.leaves(scaled)[0])).all()


# ---------------- ragged cohorts + shape bucketing ----------------

def test_ragged_cohort_matches_per_client():
    """Mask padding in stack_cohort: vectorized per-client deltas equal
    the serial ones when clients have different minibatch counts."""
    params = make_params()
    lists = [ragged_batch_fn(c, 0) for c in range(K)]   # 1, 2, 3 batches
    mx = max(len(b) for b in lists)
    batches, masks = stack_cohort(lists, mx)
    cohort = make_cohort_local_update(loss_fn, 0.05)
    d_vec, l_vec = cohort(params, batches, masks, None)
    serial = make_local_update(loss_fn, 0.05)
    for j, bl in enumerate(lists):
        b, m = stack_batches(bl, mx)
        d_ser, l_ser = serial(params, b, m, None)
        assert_trees_close(jax.tree.map(lambda x: x[j], d_vec), d_ser)
        assert np.isclose(float(l_vec[j]), float(l_ser), rtol=1e-5)


@pytest.mark.parametrize("algo", ["feddpc", "fedavg", "fedvarp"])
def test_zero_data_client_counts_as_real_under_padding(algo):
    """Pad detection via ``real_clients`` (regression): a genuinely
    sampled client with ZERO valid minibatches (all-False mask row)
    must still count in the server mean / FedVARP table — the legacy
    masks.any(axis=1) fallback reclassified it as padding. A padded
    round told its pad count must equal the unpadded round on the same
    cohort."""
    from repro.core.baselines import make_algorithm
    from repro.core.round import make_cohort_round

    lists = [ragged_batch_fn(0, 0), [], ragged_batch_fn(2, 0)]
    mx = max(len(b) for b in lists)
    batches, masks = stack_cohort(lists, mx)                # (3, mx, ...)
    batches_p, masks_p = stack_cohort(lists, mx, pad_to=4)  # + 1 pad row
    assert not masks[1].any()                               # zero-data row
    ids = jnp.asarray([0, 1, 2], jnp.int32)
    ids_p = jnp.asarray([0, 1, 2, NUM_CLIENTS], jnp.int32)

    def run(**round_kw):
        a = make_algorithm(algo)
        rnd = make_cohort_round(loss_fn, a, 0.05, 0.1, donate=False,
                                **round_kw)
        state = a.init(make_params(), NUM_CLIENTS)
        if round_kw:
            return rnd(state, make_params(), batches_p, masks_p, ids_p)
        return rnd(state, make_params(), batches, masks, ids)

    ref_p, ref_s, ref_l, _ = run()
    new_p, new_s, new_l, _ = run(pad_clients=True, real_clients=3)
    assert_trees_close(ref_p, new_p, rtol=1e-6, atol=1e-7)
    assert_trees_close(ref_s, new_s, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(new_l)[:3], np.asarray(ref_l),
                               rtol=1e-6)
    # the legacy fallback drops the zero-data client from the mean
    # denominator — documented misclassification, kept only for callers
    # that cannot know their pad count
    leg_p, _, _, _ = run(pad_clients=True)
    assert any(not np.allclose(np.asarray(x), np.asarray(y), rtol=1e-6)
               for x, y in zip(jax.tree.leaves(ref_p),
                               jax.tree.leaves(leg_p)))


def test_prefetch_matches_blocking():
    """Double-buffered ingest determinism: same seed => identical client
    schedule, losses, and final state as the blocking stack_cohort path
    (the prefetch thread draws the sampling RNG in round order)."""
    for algo in ("feddpc", "fedvarp"):          # stateless + stateful rules
        pf = run_trainer(algo, True, rounds=4, prefetch=True)
        bl = run_trainer(algo, True, rounds=4, prefetch=False)
        assert len(pf.schedule) >= 4 and len(bl.schedule) >= 4
        for a, b in zip(bl.schedule[:4], pf.schedule[:4]):
            assert (a == b).all(), (algo, a, b)
        for rp, rb in zip(pf.history, bl.history):
            assert np.isclose(rp.train_loss, rb.train_loss,
                              rtol=1e-6, atol=1e-8), algo
        assert_trees_close(pf.params, bl.params)
        assert_trees_close(pf.server_state, bl.server_state)
        pf.close()


def test_prefetch_out_of_order_round_raises():
    cfg = FLConfig(algorithm="feddpc", rounds=4, clients_per_round=K,
                   eta_l=0.05, eta_g=0.1, seed=0, eval_every=10 ** 9,
                   prefetch=True)
    tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, cfg)
    tr.run_round(0)
    with pytest.raises(RuntimeError, match="sequential"):
        tr.run_round(2)
    tr.close()


def test_async_eval_matches_sync():
    """Async eval runs on a pre-donation snapshot and folds into the same
    RoundRecord the blocking path fills."""
    def eval_fn(p):
        return float(jnp.mean(jnp.tanh(p["w"])))

    hists = {}
    for async_eval in (False, True):
        cfg = FLConfig(algorithm="feddpc", rounds=5, clients_per_round=K,
                       eta_l=0.05, eta_g=0.1, seed=7, eval_every=2,
                       async_eval=async_eval)
        tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                              ragged_batch_fn, cfg, eval_fn)
        hists[async_eval] = tr.run()
        tr.close()
    for ra, rs in zip(hists[True], hists[False]):
        assert (ra.test_accuracy is None) == (rs.test_accuracy is None)
        if ra.test_accuracy is not None:
            assert np.isclose(ra.test_accuracy, rs.test_accuracy,
                              rtol=1e-6, atol=1e-8)


def test_async_eval_tail_drains_before_close():
    """The final round's async eval launches with no later boundary to
    fold it in: close()/finalize() must DRAIN it into the last
    RoundRecord (and best_accuracy must resolve it too) — a slow eval_fn
    would otherwise leave test_accuracy None and silently drop the last
    round from the best-accuracy scan."""
    import time as _time

    def slow_eval(p):
        _time.sleep(0.3)
        return float(jnp.mean(jnp.tanh(p["w"])))

    cfg = FLConfig(algorithm="feddpc", rounds=4, clients_per_round=K,
                   eta_l=0.05, eta_g=0.1, seed=7, eval_every=2,
                   async_eval=True)
    tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, cfg, slow_eval)
    for t in range(4):
        tr.run_round(t)
    tr.close()     # eval of round 3 is still in flight here
    assert tr.history[-1].test_accuracy is not None
    evaled = [r.round for r in tr.history if r.test_accuracy is not None]
    assert 3 in evaled
    best, best_round = tr.best_accuracy
    assert best is not None
    assert best == max(r.test_accuracy for r in tr.history
                       if r.test_accuracy is not None)


def test_sharded_round_matches_single_device():
    """Client-axis sharded round == single-device round on a FORCED
    8-host-device mesh for feddpc/fedavg/fedexp. The device count locks at
    jax init, so the check runs in a subprocess (tests/_sharded_cohort_check)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "tests",
                                      "_sharded_cohort_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL OK" in proc.stdout


def test_grow_once_keeps_jit_cache_bounded():
    """M pads to the cohort max and only grows: a later round with FEWER
    batches reuses the compiled program (no new jit cache entry)."""
    def shrinking_batch_fn(c, t):
        r = np.random.RandomState(1000 * c + t)
        return [{"x": r.randn(8, 4).astype(np.float32),
                 "y": r.randn(8, 3).astype(np.float32)}
                for _ in range(3 if t == 0 else 1)]

    cfg = FLConfig(algorithm="feddpc", rounds=3, clients_per_round=K,
                   eta_l=0.05, eta_g=0.1, seed=0, eval_every=10 ** 9)
    tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          shrinking_batch_fn, cfg)
    tr.run_round(0)
    assert tr._max_batches == 3
    cache_size = getattr(tr._cohort_round, "_cache_size", None)
    if cache_size is None:              # private jax API; jax-version drift
        pytest.skip("jit cache introspection unavailable on this jax")
    n_compiled = cache_size()
    assert n_compiled == 1
    tr.run_round(1)                     # max 1 batch -> padded back to 3
    tr.run_round(2)
    assert tr._max_batches == 3
    assert cache_size() == n_compiled
