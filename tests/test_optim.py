"""Local optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import (adamw, constant_schedule, cosine_schedule,
                                    get_optimizer, momentum, sgd)


def _quad(params):
    return jnp.sum((params["w"] - 3.0) ** 2)


def _run(opt, steps=200):
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    for i in range(steps):
        g = jax.grad(_quad)(params)
        upd, state = opt.update(g, state, params, jnp.asarray(i))
        params = jax.tree.map(lambda p, u: p - u, params, upd)
    return params


def test_sgd_converges():
    p = _run(sgd(0.1))
    np.testing.assert_allclose(p["w"], 3.0, atol=1e-3)


def test_momentum_converges():
    p = _run(momentum(0.02, 0.9))
    np.testing.assert_allclose(p["w"], 3.0, atol=1e-2)


def test_adamw_converges():
    p = _run(adamw(0.1), steps=400)
    np.testing.assert_allclose(p["w"], 3.0, atol=1e-2)


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.05, weight_decay=0.5)
    params = {"w": jnp.full(3, 10.0)}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(3)}
    for i in range(50):
        upd, state = opt.update(zero_g, state, params, jnp.asarray(i))
        params = jax.tree.map(lambda p, u: p - u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 10.0


def test_get_optimizer_registry():
    for name in ("sgd", "momentum", "adamw"):
        assert get_optimizer(name, 0.1) is not None


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) <= 1.0 + 1e-6
    assert float(lr(5)) < float(lr(10))
    assert float(lr(100)) < 0.01
    assert float(constant_schedule(0.3)(50)) == np.float32(0.3)
