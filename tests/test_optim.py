"""Local optimizers + schedules, and the server-optimizer layer
(DESIGN.md §14): registry semantics, the fedadam/fedyogi moment math,
cross-regime equivalence at the anchor cells, and bitwise moment-state
checkpoint round-trips."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.optim.optimizers import (adamw, constant_schedule, cosine_schedule,
                                    get_optimizer, momentum, sgd)
from repro.optim.server import (SERVER_OPTIMIZER_NAMES,
                                make_server_optimizer)


def _quad(params):
    return jnp.sum((params["w"] - 3.0) ** 2)


def _run(opt, steps=200):
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    for i in range(steps):
        g = jax.grad(_quad)(params)
        upd, state = opt.update(g, state, params, jnp.asarray(i))
        params = jax.tree.map(lambda p, u: p - u, params, upd)
    return params


def test_sgd_converges():
    p = _run(sgd(0.1))
    np.testing.assert_allclose(p["w"], 3.0, atol=1e-3)


def test_momentum_converges():
    p = _run(momentum(0.02, 0.9))
    np.testing.assert_allclose(p["w"], 3.0, atol=1e-2)


def test_adamw_converges():
    p = _run(adamw(0.1), steps=400)
    np.testing.assert_allclose(p["w"], 3.0, atol=1e-2)


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.05, weight_decay=0.5)
    params = {"w": jnp.full(3, 10.0)}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(3)}
    for i in range(50):
        upd, state = opt.update(zero_g, state, params, jnp.asarray(i))
        params = jax.tree.map(lambda p, u: p - u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 10.0


def test_get_optimizer_registry():
    for name in ("sgd", "momentum", "adamw"):
        assert get_optimizer(name, 0.1) is not None


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) <= 1.0 + 1e-6
    assert float(lr(5)) < float(lr(10))
    assert float(lr(100)) < 0.01
    assert float(constant_schedule(0.3)(50)) == np.float32(0.3)


# ---- server-optimizer layer (DESIGN.md §14) ----

NUM_CLIENTS, K, ROUNDS = 6, 2, 4


def _sloss(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _sparams(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(5, 3) * 0.4, jnp.float32),
            "b": jnp.zeros((3,), jnp.float32)}


def _sbatches(c, t):
    r = np.random.RandomState(97 * c + t)
    return [{"x": r.randn(4, 5).astype(np.float32),
             "y": r.randn(4, 3).astype(np.float32)}
            for _ in range((c % 2) + 1)]


def _strain(rounds=ROUNDS, algo="feddpc", **exec_kw):
    cfg = ExecConfig(rounds=rounds, clients_per_round=K, seed=11,
                     eval_every=10 ** 9, prefetch=False, **exec_kw)
    with FederatedTrainer(_sloss, _sparams(), NUM_CLIENTS, _sbatches, cfg,
                          algo=AlgoConfig(name=algo, eta_l=0.05,
                                          eta_g=0.1)) as tr:
        tr.run()
    return tr


def _assert_trees_equal(a, b, ctx=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), ctx
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y),
                              equal_nan=True), ctx


def test_make_server_optimizer_registry():
    assert set(SERVER_OPTIMIZER_NAMES) == {"sgd", "fedadam", "fedyogi"}
    # the anchor: no optimizer object at all — nothing enters the jit
    assert make_server_optimizer(None) is None
    assert make_server_optimizer("sgd") is None
    for name in ("fedadam", "fedyogi"):
        opt = make_server_optimizer(name)
        assert opt is not None and opt.name == name and opt.stateful
        assert opt.config_dict() == {"name": name}
    with pytest.raises(ValueError, match="unknown server optimizer"):
        make_server_optimizer("nadam")


def test_server_adaptive_math_matches_numpy():
    """fedadam/fedyogi apply == the hand-rolled Reddi et al. update."""
    params = {"w": jnp.asarray([1.0, -2.0, 0.5], jnp.float32)}
    proposed = {"w": jnp.asarray([0.6, -1.7, 0.9], jnp.float32)}
    lr, b1, b2, eps = 0.1, 0.9, 0.99, 1e-3
    for name in ("fedadam", "fedyogi"):
        opt = make_server_optimizer(name)
        state = opt.init(params)
        assert (np.asarray(state["m"]["w"]) == 0).all()
        assert state["v"]["w"].dtype == jnp.float32
        p = np.asarray(params["w"], np.float64).astype(np.float32)
        m = v = np.zeros(3, np.float32)
        new, state2 = params, state
        for _ in range(3):
            new, state2 = opt.apply(new, proposed, state2)
            g = p - np.asarray(proposed["w"], np.float32)
            m = b1 * m + (1 - b1) * g
            if name == "fedadam":
                v = b2 * v + (1 - b2) * g * g
            else:
                v = v - (1 - b2) * g * g * np.sign(v - g * g)
            p = p - lr * m / (np.sqrt(v) + eps)
            np.testing.assert_allclose(np.asarray(new["w"]), p,
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(np.asarray(state2["m"]["w"]), m,
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(np.asarray(state2["v"]["w"]), v,
                                       rtol=1e-6, atol=1e-7)


def test_server_sgd_explicit_is_bitwise_default():
    """ExecConfig(server_opt="sgd") resolves to NO optimizer object, so
    the run is bitwise-identical to the pre-layer default."""
    base = _strain()
    explicit = _strain(server_opt="sgd")
    _assert_trees_equal(base.params, explicit.params, "params")
    _assert_trees_equal(base.server_state, explicit.server_state, "state")
    for rb, re_ in zip(base.history, explicit.history):
        assert rb.train_loss == re_.train_loss
    assert explicit._opt_state is None


def test_server_opt_changes_trajectory():
    base = _strain()
    for name in ("fedadam", "fedyogi"):
        tr = _strain(server_opt=name)
        assert not np.allclose(np.asarray(tr.params["w"]),
                               np.asarray(base.params["w"])), name


def test_server_opt_serial_vectorized_async_anchor_allclose():
    """The adaptive optimizers consume the POST-projection aggregate, so
    every execution path applies the identical preconditioned step: the
    vectorized round and the buffered-async anchor cell (B=K,
    concurrency 1, DeterministicRuntime) match the serial reference."""
    for name in ("fedadam", "fedyogi"):
        serial = _strain(server_opt=name, vectorize=False)
        vec = _strain(server_opt=name)
        anchor = _strain(server_opt=name, async_buffer=True)
        for other in (vec, anchor):
            np.testing.assert_allclose(
                np.asarray(other.params["w"]),
                np.asarray(serial.params["w"]), rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(other._opt_state["m"]["w"]),
                np.asarray(serial._opt_state["m"]["w"]),
                rtol=1e-5, atol=1e-6)
            for rs, ro in zip(serial.history, other.history):
                assert np.isclose(rs.train_loss, ro.train_loss,
                                  rtol=1e-4, atol=1e-6), name


def test_server_opt_state_bitwise_resume():
    """Moment state round-trips bitwise through save/resume: the resumed
    run is indistinguishable from the uninterrupted one."""
    full = _strain(rounds=ROUNDS, server_opt="fedadam")
    with tempfile.TemporaryDirectory() as d:
        cfg = ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=11,
                         eval_every=10 ** 9, prefetch=False,
                         server_opt="fedadam")
        algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)
        with FederatedTrainer(_sloss, _sparams(), NUM_CLIENTS, _sbatches,
                              cfg, algo=algo) as tr:
            tr.run_round(0)
            tr.run_round(1)
            tr.save(d)
        tr2 = FederatedTrainer.resume(d, _sloss, _sparams(), NUM_CLIENTS,
                                      _sbatches, cfg, algo=algo)
        assert tr2.start_round == 2
        with tr2:
            tr2.run()
    _assert_trees_equal(full.params, tr2.params, "params")
    _assert_trees_equal(full._opt_state, tr2._opt_state, "opt_state")


def test_server_opt_async_midbuffer_resume_bitwise():
    """Saving with waves in flight (concurrency 2) must carry the
    moment state of the last FOLD: the resumed trajectory replays the
    uninterrupted one bitwise, in-flight entries included."""
    kw = dict(server_opt="fedyogi", async_buffer=True,
              async_concurrency=2, buffer_size=2)
    full = _strain(rounds=6, **kw)
    with tempfile.TemporaryDirectory() as d:
        cfg = ExecConfig(rounds=6, clients_per_round=K, seed=11,
                         eval_every=10 ** 9, prefetch=False, **kw)
        algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)
        with FederatedTrainer(_sloss, _sparams(), NUM_CLIENTS, _sbatches,
                              cfg, algo=algo) as tr:
            for t in range(3):
                tr.run_round(t)
            tr.save(d)
        tr2 = FederatedTrainer.resume(d, _sloss, _sparams(), NUM_CLIENTS,
                                      _sbatches, cfg, algo=algo)
        with tr2:
            tr2.run()
    _assert_trees_equal(full.params, tr2.params, "params")
    _assert_trees_equal(full._opt_state, tr2._opt_state, "opt_state")
    # resume restores the pre-save history, so the full trajectories align
    assert len(full.history) == len(tr2.history)
    for rf, rr in zip(full.history, tr2.history):
        assert rf.train_loss == rr.train_loss, (rf.round, rr.round)


def test_server_opt_resume_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)

        def cfg(**kw):
            return ExecConfig(rounds=2, clients_per_round=K, seed=11,
                              eval_every=10 ** 9, prefetch=False, **kw)

        with FederatedTrainer(_sloss, _sparams(), NUM_CLIENTS, _sbatches,
                              cfg(server_opt="fedadam"), algo=algo) as tr:
            tr.run_round(0)
            tr.save(d)
        with pytest.raises(ValueError, match="server optimizer"):
            FederatedTrainer.resume(d, _sloss, _sparams(), NUM_CLIENTS,
                                    _sbatches, cfg(server_opt="fedyogi"),
                                    algo=algo)
        with pytest.raises(ValueError, match="server optimizer"):
            FederatedTrainer.resume(d, _sloss, _sparams(), NUM_CLIENTS,
                                    _sbatches, cfg(), algo=algo)
