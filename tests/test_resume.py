"""Checkpoint/resume reproduces an uninterrupted run EXACTLY.

The three phases each run in a fresh subprocess (tests/_resume_check.py)
— fresh jit caches, fresh RNG objects — because that is the scenario
``FederatedTrainer.save()/resume()`` exists for: params, server state
(including FedVARP's per-client table), the sampled participation
schedule (Markov availability chain included), and per-round losses must
be bitwise equal between save-at-t + resume and never-stopping.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

pytestmark = pytest.mark.slow


def _run_phase(phase, workdir, root):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "tests", "_resume_check.py"),
         phase, workdir],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert f"PHASE {phase} OK" in proc.stdout


def test_resume_matches_uninterrupted(tmp_path):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    wd = str(tmp_path)
    for phase in ("full", "part", "resume"):
        _run_phase(phase, wd, root)
    full = np.load(os.path.join(wd, "full.npz"))
    res = np.load(os.path.join(wd, "resume.npz"))
    assert set(full.files) == set(res.files)
    for key in full.files:
        np.testing.assert_array_equal(full[key], res[key], err_msg=key)
