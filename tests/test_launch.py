"""Launch drivers: serve loop smoke, train CLI smoke, FL round step, and
the train-step microbatching equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.shapes import InputShape
from repro.launch import steps as steps_mod
from repro.launch.serve import serve_encdec, serve_lm


def test_serve_lm_smoke():
    cfg = get_config("starcoder2-3b", smoke=True)
    tokens, stats = serve_lm(cfg, batch=2, prompt_len=8, gen=4)
    assert tokens.shape == (2, 4)
    assert not jnp.isnan(tokens).any()
    assert stats["tok_per_s"] > 0


def test_serve_ssm_smoke():
    cfg = get_config("falcon-mamba-7b", smoke=True)
    tokens, _ = serve_lm(cfg, batch=2, prompt_len=8, gen=4)
    assert tokens.shape == (2, 4)


def test_serve_encdec_smoke():
    cfg = get_config("whisper-base", smoke=True)
    tokens, _ = serve_encdec(cfg, batch=2, gen=4)
    assert tokens.shape == (2, 4)


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    from repro.launch.train import main
    rc = main(["--model", "lenet5", "--rounds", "2", "--clients", "6",
               "--participation", "0.5", "--samples-per-class", "20",
               "--batch-size", "16", "--eval-every", "1",
               "--out", str(tmp_path / "hist.json")])
    assert rc == 0
    assert (tmp_path / "hist.json").exists()


def test_microbatched_train_step_equivalence(rng):
    """microbatches=4 must produce the same update as microbatches=1 when
    the loss is a mean over examples (linear in the batch split)."""
    cfg = get_config("starcoder2-3b", smoke=True)
    shape = InputShape("t", 16, 8, "train")
    step1 = steps_mod.make_train_step(cfg, lr=0.01, microbatches=1,
                                      remat="none")
    step4 = steps_mod.make_train_step(cfg, lr=0.01, microbatches=4,
                                      remat="none")
    from repro.models import transformer as tf
    params = tf.init_lm(cfg, rng, jnp.float32)
    toks = jax.random.randint(rng, (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    p1, l1 = jax.jit(step1)(params, batch)
    p4, l4 = jax.jit(step4)(params, batch)
    # losses are means over valid tokens; equal-size microbatches with no
    # padding -> identical means
    assert np.isclose(float(l1), float(l4), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_fl_round_step_learns(rng):
    from repro.core.round import make_fl_round_step
    from repro.models import transformer as tf
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    loss_fn = lambda p, b: tf.loss_fn(cfg, p, b)
    step = jax.jit(make_fl_round_step(loss_fn, 0.05, 0.05))
    params = tf.init_lm(cfg, rng, jnp.float32)
    delta = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    toks = jax.random.randint(rng, (3, 2, 2, 17), 0, cfg.vocab_size)
    batches = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    losses = []
    for _ in range(3):
        params, delta, m = step(params, delta, batches)
        losses.append(float(m["train_loss"]))
    assert losses[-1] < losses[0]
