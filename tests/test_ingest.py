"""Staged ingest pipeline (DESIGN.md §10): depth-N determinism, device
staging, the staging ring's semantics, checkpointing with in-flight
staging, and the one-release deprecation shims over the moved modules.

The heavyweight cross-regime equivalence (staged regimes on the forced
8-device mesh) lives in tests/test_regime_matrix.py; the fresh-process
bitwise resume with depth-8 staging lives in tests/test_resume.py. These
are the fast single-process contracts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.ingest import (CohortIngestPipeline, CohortPlacer,
                          CohortPrefetcher, ListDataSource, stack_cohort)

NUM_CLIENTS = 6
K = 3


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def run_trainer(rounds=5, **exec_kw):
    kw = dict(clients_per_round=K, seed=7, eval_every=10 ** 9)
    kw.update(exec_kw)
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, ExecConfig(rounds=rounds, **kw),
                          algo=AlgoConfig(eta_l=0.05, eta_g=0.1)) as tr:
        tr.run()
    return tr


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------- depth-N determinism ----------------

def test_depth_n_prefetch_is_bitwise_deterministic():
    """Depths 1..8 x {device-staged, host-staged} all replay the
    blocking path's RNG draws bit for bit: identical schedules, params,
    server state, and per-round losses (the round-order contract)."""
    ref = run_trainer(prefetch=False)
    for depth in (1, 2, 4, 8):
        for device_stage in (True, False):
            tr = run_trainer(prefetch=True, prefetch_depth=depth,
                             device_stage=device_stage)
            label = (depth, device_stage)
            for a, b in zip(ref.schedule, tr.schedule[:len(ref.schedule)]):
                assert (np.asarray(a) == np.asarray(b)).all(), label
            assert_trees_equal(ref.params, tr.params)
            assert_trees_equal(ref.server_state, tr.server_state)
            assert [r.train_loss for r in ref.history] == \
                [r.train_loss for r in tr.history], label


def test_depth_must_be_positive():
    with pytest.raises(ValueError, match="depth"):
        run_trainer(prefetch_depth=0)


# ---------------- ingest wait split ----------------

def test_ingest_seconds_split_sums_and_places():
    """ingest_seconds == host + device split; device-staged rounds pay
    ~no consumer-side transfer wait (it moved to the staging thread),
    host-staged rounds report a real placement component."""
    for device_stage in (True, False):
        tr = run_trainer(prefetch=True, device_stage=device_stage)
        for r in tr.history:
            assert r.ingest_seconds == pytest.approx(
                r.ingest_host_seconds + r.ingest_device_seconds)
        if device_stage:
            assert all(r.ingest_device_seconds == 0.0 for r in tr.history)
    # the blocking path measures placement explicitly too
    tr = run_trainer(prefetch=False)
    assert any(r.ingest_device_seconds > 0.0 for r in tr.history)


def test_round_inputs_arrive_device_placed():
    """With device staging the round's inputs are committed jax arrays
    before dispatch — StagedCohort carries no host numpy leaves."""
    cfg = ExecConfig(rounds=2, clients_per_round=K, seed=0,
                     eval_every=10 ** 9, prefetch=True)
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, cfg,
                          algo=AlgoConfig(eta_l=0.05, eta_g=0.1)) as tr:
        staged = tr._pipeline.get(0)
        try:
            for leaf in jax.tree.leaves((staged.batches, staged.masks,
                                         staged.ids)):
                assert isinstance(leaf, jax.Array), type(leaf)
        finally:
            staged.release()


# ---------------- staging-ring semantics ----------------

def test_ring_depth_one_single_buffers():
    produced = []

    def produce(t, slot):
        produced.append(t)
        return t

    ring = CohortPrefetcher(produce, 0, 4, slots=1)
    try:
        for t in range(4):
            item, slot = ring.get(t)
            assert item == t
            ring.release(slot)
    finally:
        ring.stop()
    assert produced == [0, 1, 2, 3]


def test_ring_horizon_and_out_of_order_errors():
    ring = CohortPrefetcher(lambda t, slot: t, 0, 3, slots=2)
    try:
        with pytest.raises(RuntimeError, match="horizon"):
            ring.get(3)
        _, slot = ring.get(0)
        ring.release(slot)
        with pytest.raises(RuntimeError, match="sequential"):
            ring.get(2)
    finally:
        ring.stop()


def test_ring_stall_deadline_raises_instead_of_spinning():
    """A producer hung inside produce_fn (alive thread, nothing staged)
    used to spin get() forever — the 1s dead-thread poll only escaped
    on a DEAD producer. With a stall deadline the consumer raises,
    naming the stuck round (regression)."""
    import threading
    import time
    hang = threading.Event()

    def produce(t, slot):
        if t == 1:
            hang.wait(timeout=30)       # simulate a deadlocked source read
        return t

    ring = CohortPrefetcher(produce, 0, 4, slots=1, stall_timeout=0.3)
    try:
        item, slot = ring.get(0)
        assert item == 0
        ring.release(slot)
        tic = time.monotonic()
        with pytest.raises(RuntimeError, match="round 1"):
            ring.get(1)
        assert time.monotonic() - tic < 5.0     # bounded, not the 30s hang
        assert ring._thread.is_alive()          # the ALIVE-but-stuck case
    finally:
        hang.set()                              # unstick before join
        ring.stop()


def test_ring_stop_joins_producer_and_drains_staged_slots():
    """stop() must JOIN the producer (not just drop a sentinel) and
    drain staged-but-unconsumed items so their buffers aren't pinned by
    the dead ring (regression: stop() returned with the producer still
    mid-produce and _ready still holding staged rounds)."""
    staged = []

    def produce(t, slot):
        staged.append(t)
        return ("payload", t)

    ring = CohortPrefetcher(produce, 0, 100, slots=4)
    item, slot = ring.get(0)                    # let the producer spin up
    ring.release(slot)
    ring.stop()
    assert not ring._thread.is_alive()          # joined, not abandoned
    assert ring._ready.empty()                  # staged work was drained
    assert len(staged) <= 100
    ring.stop()                                 # idempotent


def test_trainer_threads_stall_deadline_to_the_ring():
    """ExecConfig.ingest_stall_s reaches the staging ring: a source that
    blocks forever on a mid-run round surfaces as the stall error
    instead of hanging the round loop."""
    import threading
    hang = threading.Event()

    def hanging_batch_fn(c, t):
        if t == 2:
            hang.wait(timeout=30)
        return ragged_batch_fn(c, t)

    cfg = ExecConfig(rounds=4, clients_per_round=K, seed=3,
                     eval_every=10 ** 9, prefetch=True,
                     ingest_stall_s=0.3)
    try:
        with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                              hanging_batch_fn, cfg,
                              algo=AlgoConfig(eta_l=0.05, eta_g=0.1)) as tr:
            tr.run_round(0)
            tr.run_round(1)
            with pytest.raises(RuntimeError, match="stall"):
                tr.run_round(2)
    finally:
        hang.set()


def test_staged_cohort_release_is_idempotent():
    src = ListDataSource(ragged_batch_fn)
    pipe = CohortIngestPipeline(
        src, lambda t: np.asarray([0, 1, 2]), num_clients=NUM_CLIENTS,
        rounds=3, depth=2, device_stage=True, placer=CohortPlacer())
    try:
        staged = pipe.get(0)
        staged.release()
        staged.release()                    # second release is a no-op
        staged2 = pipe.get(1)
        staged2.release()
    finally:
        pipe.close()


def test_blocking_stage_matches_ring_values():
    """stage_blocking and the ring stage the same bytes for a round."""
    sample = lambda t: np.asarray([2, 0, 4])
    src = ListDataSource(ragged_batch_fn)
    a = CohortIngestPipeline(src, sample, num_clients=NUM_CLIENTS,
                             rounds=2, depth=2, device_stage=True,
                             placer=CohortPlacer())
    b = CohortIngestPipeline(src, sample, num_clients=NUM_CLIENTS,
                             rounds=2, depth=2, device_stage=True,
                             placer=CohortPlacer())
    try:
        s1 = a.get(0)
        s2 = b.stage_blocking(0)
        assert_trees_equal(s1.batches, s2.batches)
        np.testing.assert_array_equal(np.asarray(s1.masks),
                                      np.asarray(s2.masks))
        np.testing.assert_array_equal(np.asarray(s1.ids),
                                      np.asarray(s2.ids))
        s1.release()
    finally:
        a.close()
        b.close()


# ---------------- checkpointing with in-flight staging ----------------

def test_save_restore_with_deep_inflight_staging(tmp_path):
    """At depth 8 with 6 rounds the producer stages (and SAMPLES) every
    remaining round the moment the run starts; save() after round 2 must
    roll RNG/sampler/schedule back to round 3's pre-draw capture so the
    resumed run re-draws the staged-but-unconsumed rounds identically.
    (The fresh-process bitwise version is tests/test_resume.py.)"""
    ec = ExecConfig(rounds=6, clients_per_round=K, seed=11,
                    eval_every=10 ** 9, prefetch=True, prefetch_depth=8)
    ac = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, ec, algo=ac) as full:
        full.run()
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, ec, algo=ac) as part:
        part.run_round(0)
        part.run_round(1)
        part.run_round(2)
        part.save(str(tmp_path))
    res = FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                  NUM_CLIENTS, ragged_batch_fn, ec, algo=ac)
    with res:
        assert res.start_round == 3
        res.run()
    assert_trees_equal(full.params, res.params)
    assert_trees_equal(full.server_state, res.server_state)
    assert [r.train_loss for r in full.history] == \
        [r.train_loss for r in res.history]
    for a, b in zip(full.schedule, res.schedule):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_stack_cohort_reexport_identical():
    """stack_cohort via repro.ingest is the one the trainer uses (no
    forked copies): same padding semantics as before the move."""
    lists = [ragged_batch_fn(c, 0) for c in range(K)]
    mx = max(len(b) for b in lists)
    b, m = stack_cohort(lists, mx, pad_to=5)
    assert m.shape == (5, mx) and not m[K:].any()
