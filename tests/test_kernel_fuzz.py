"""Hypothesis-driven fuzz coverage for the FedDPC batched Pallas
epilogue (kernels/feddpc_project), interpret mode vs the pure-jnp oracle
(ref.py): ragged cohort sizes K, zero ``delta_prev`` (the round-1
degenerate case), leaf/row shapes that are NOT multiples of the block
size (the kernel's largest-divisor row search and ops.py's pad-to-lane
path), and the two-axis model-sharded route — where the kernel must
fall back to the reference epilogue (DESIGN.md §2).

Runs under hypothesis when installed, else the deterministic fallback
(tests/_hypothesis_compat.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import feddpc
from repro.kernels.feddpc_project import kernel as fp_kernel
from repro.kernels.feddpc_project import ops as fp_ops
from repro.kernels.feddpc_project import ref as fp_ref

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")

# rows that are NOT multiples of the block target: primes force the
# kernel's largest-divisor search down to small (even unit) blocks
M_CASES = (1, 7, 8, 96, 127, 128, 129, 200)


@given(st.integers(1, 9), st.sampled_from(M_CASES),
       st.sampled_from([False, True]))
def test_batched_epilogue_raw_fuzz(k, m, zero_prev):
    """kernel.batched_epilogue on raw (K, M, 128) blocks == ref.py, for
    ragged K x non-multiple-of-block M x zero delta_prev."""
    r = np.random.RandomState(k * 1000 + m + int(zero_prev))
    d3 = jnp.asarray(r.randn(k, m, 128), jnp.float32)
    p2 = (jnp.zeros((m, 128), jnp.float32) if zero_prev
          else jnp.asarray(r.randn(m, 128), jnp.float32))
    w2 = jnp.asarray(r.randn(m, 128), jnp.float32)
    coefs = jnp.asarray(r.randn(k), jnp.float32)
    scales = jnp.asarray(1.0 + np.abs(r.randn(k)), jnp.float32)
    got_w, got_dt = fp_kernel.batched_epilogue(d3, p2, w2, coefs, scales,
                                               0.3, interpret=True)
    want_w, want_dt = fp_ref.batched_epilogue_ref(d3, p2, w2, coefs,
                                                  scales, 0.3)
    np.testing.assert_allclose(got_w, want_w, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got_dt, want_dt, rtol=2e-5, atol=2e-5)


@given(st.integers(1, 8), st.sampled_from([3, 37, 127, 130, 1000]),
       st.sampled_from([False, True]))
def test_batched_server_epilogue_tree_fuzz(k, n, zero_prev):
    """ops.batched_server_epilogue over a pytree whose leaf sizes are
    not lane/block multiples == the flat-vector oracle math, leaf by
    leaf (exercises the per-leaf pad + un-pad path end to end)."""
    r = np.random.RandomState(k * 77 + n)
    shapes = [(n,), (5, 7), (2, 3, 4)]
    params = {f"l{i}": jnp.asarray(r.randn(*s), jnp.float32)
              for i, s in enumerate(shapes)}
    deltas = jax.tree.map(
        lambda x: jnp.asarray(r.randn(k, *np.shape(x)), jnp.float32), params)
    prev = (jax.tree.map(jnp.zeros_like, params) if zero_prev
            else jax.tree.map(lambda x: x * 0.3, params))
    coefs = jnp.asarray(r.randn(k), jnp.float32)
    scales = jnp.asarray(1.0 + np.abs(r.randn(k)), jnp.float32)
    new_w, dt = fp_ops.batched_server_epilogue(deltas, prev, params,
                                               coefs, scales, 0.2,
                                               interpret=True)
    bc = lambda s, x: s.reshape((-1,) + (1,) * (x.ndim - 1))
    want_dt = jax.tree.map(
        lambda d, p: jnp.mean(bc(scales, d) * (d - bc(coefs, d) * p[None]),
                              axis=0), deltas, prev)
    want_w = jax.tree.map(lambda w, d: w - 0.2 * d, params, want_dt)
    for a, b in zip(jax.tree.leaves(new_w), jax.tree.leaves(want_w)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(dt), jax.tree.leaves(want_dt)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


@given(st.integers(1, 6), st.sampled_from([False, True]))
def test_server_step_model_sharded_falls_back_to_reference(k, round1):
    """feddpc.server_step(use_kernel=True, model_sharded=True) must take
    the reference epilogue (the Pallas path would flatten partitioned
    leaves); on one device that makes it BITWISE equal to the jnp path,
    and the reduction-pass scalars are untouched by the flag."""
    r = np.random.RandomState(k)
    params = {"w": jnp.asarray(r.randn(12, 16), jnp.float32),
              "b": jnp.asarray(r.randn(16), jnp.float32)}
    deltas = jax.tree.map(
        lambda x: jnp.asarray(r.randn(k, *np.shape(x)), jnp.float32), params)
    prev = (jax.tree.map(jnp.zeros_like, params) if round1
            else jax.tree.map(lambda x: x * 0.5, params))
    ref_out = feddpc.server_step({"delta_prev": prev}, params, deltas,
                                 0.1, 1.0, use_kernel=False)
    got_out = feddpc.server_step({"delta_prev": prev}, params, deltas,
                                 0.1, 1.0, use_kernel=True,
                                 model_sharded=True)
    for a, b in zip(jax.tree.leaves(ref_out), jax.tree.leaves(got_out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unit_model_axis_degenerates_to_prefix_layout():
    """A ("clients", "model") mesh whose model axis has size 1 must
    behave exactly like the 1-D client mesh: cohort_round_shardings
    takes the replicated-prefix branch (templates ignored), and the
    jit'd round equals the meshless one. The REAL per-leaf spec
    threading (model axis > 1) is only reachable with multiple devices
    and is covered by the regime matrix's forced-8-device subprocess
    plus the FakeMesh unit tests in test_sharding.py."""
    from repro.core.baselines import make_algorithm
    from repro.core.round import make_cohort_round

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    r = np.random.RandomState(0)
    params = {"w": jnp.asarray(r.randn(4, 8), jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    algo = make_algorithm("feddpc")
    state = algo.init(params, 4)
    batches = {"x": jnp.asarray(r.randn(3, 2, 5, 4), jnp.float32),
               "y": jnp.asarray(r.randn(3, 2, 5, 8), jnp.float32)}
    masks = jnp.ones((3, 2), bool)
    ids = jnp.arange(3, dtype=jnp.int32)
    mesh = jax.make_mesh((1, 1), ("clients", "model"))
    plain = make_cohort_round(loss_fn, algo, 0.05, 0.1, donate=False)
    two = make_cohort_round(loss_fn, algo, 0.05, 0.1, donate=False,
                            mesh=mesh, shard_templates=(params, state))
    p0, s0, l0, d0 = plain(state, params, batches, masks, ids)
    p1, s1, l1, d1 = two(state, params, batches, masks, ids)
    for a, b in zip(jax.tree.leaves((p0, s0, l0)),
                    jax.tree.leaves((p1, s1, l1))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    assert d0.keys() == d1.keys()


@given(st.integers(1, 9), st.sampled_from(M_CASES),
       st.sampled_from([False, True]),
       st.sampled_from(["int8", "bf16"]))
def test_dequant_batched_epilogue_raw_fuzz(k, m, zero_prev, qdtype):
    """kernel.dequant_batched_epilogue (the fused dequant->residual->
    scale->mean grid, DESIGN.md §13) == dequant-then-epilogue oracle,
    for ragged K x non-block-multiple M x zero delta_prev x both wire
    dtypes."""
    r = np.random.RandomState(k * 1000 + m + int(zero_prev))
    if qdtype == "int8":
        q3 = jnp.asarray(r.randint(-127, 128, (k, m, 128)), jnp.int8)
        qscales = jnp.asarray(0.01 + np.abs(r.randn(k)) * 0.1, jnp.float32)
        qzeros = jnp.asarray(r.randn(k) * 0.01, jnp.float32)
    else:
        q3 = jnp.asarray(r.randn(k, m, 128), jnp.bfloat16)
        qscales = jnp.ones((k,), jnp.float32)
        qzeros = jnp.zeros((k,), jnp.float32)
    p2 = (jnp.zeros((m, 128), jnp.float32) if zero_prev
          else jnp.asarray(r.randn(m, 128), jnp.float32))
    w2 = jnp.asarray(r.randn(m, 128), jnp.float32)
    coefs = jnp.asarray(r.randn(k), jnp.float32)
    scales = jnp.asarray(1.0 + np.abs(r.randn(k)), jnp.float32)
    got_w, got_dt = fp_kernel.dequant_batched_epilogue(
        q3, p2, w2, coefs, scales, 0.3, qscales, qzeros, interpret=True)
    want_w, want_dt = fp_ref.dequant_batched_epilogue_ref(
        q3, p2, w2, coefs, scales, 0.3, qscales, qzeros)
    np.testing.assert_allclose(got_w, want_w, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got_dt, want_dt, rtol=2e-5, atol=2e-5)


@given(st.integers(1, 6), st.sampled_from(M_CASES),
       st.sampled_from([False, True]))
def test_dequant_buffer_fold_raw_fuzz(b, m, zero_prev):
    """kernel.dequant_buffer_fold (scatter-accumulate fold with fused
    dequant; staleness weights compose with the dequant scales as plain
    per-arrival multipliers) == the dequant-then-fold oracle."""
    r = np.random.RandomState(b * 31 + m)
    q3 = jnp.asarray(r.randint(-127, 128, (b, m, 128)), jnp.int8)
    qscales = jnp.asarray(0.01 + np.abs(r.randn(b)) * 0.1, jnp.float32)
    qzeros = jnp.asarray(r.randn(b) * 0.01, jnp.float32)
    p2 = (jnp.zeros((m, 128), jnp.float32) if zero_prev
          else jnp.asarray(r.randn(m, 128), jnp.float32))
    w2 = jnp.asarray(r.randn(m, 128), jnp.float32)
    coefs = jnp.asarray(r.randn(b), jnp.float32)
    scales = jnp.asarray(1.0 + np.abs(r.randn(b)), jnp.float32)
    wgts = jnp.asarray(r.uniform(0.2, 1.0, b), jnp.float32)
    got_w, got_dt = fp_kernel.dequant_buffer_fold(
        q3, p2, w2, coefs, scales, wgts, 0.25, qscales, qzeros,
        interpret=True)
    want_w, want_dt = fp_ref.dequant_buffer_fold_ref(
        q3, p2, w2, coefs, scales, wgts, 0.25, qscales, qzeros)
    np.testing.assert_allclose(got_w, want_w, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(got_dt, want_dt, rtol=2e-5, atol=2e-5)


@given(st.integers(1, 5), st.sampled_from([3, 37, 127, 130]),
       st.sampled_from([False, True]))
def test_dequant_server_epilogue_matches_decode_then_epilogue(k, n, wgt):
    """ops.dequant_*_server_* over a real codec payload (non-lane-
    multiple leaf shapes) == decode the payload with the codec, then the
    plain f32 epilogue/fold — the fused route must be a pure layout
    optimization."""
    from repro.codec import make_codec
    codec = make_codec("int8")
    r = np.random.RandomState(k * 13 + n + int(wgt))
    params = {"a": jnp.asarray(r.randn(n), jnp.float32),
              "b": jnp.asarray(r.randn(4, 11), jnp.float32)}
    deltas = jax.tree.map(
        lambda x: jnp.asarray(r.randn(k, *np.shape(x)), jnp.float32), params)
    payload = codec.encode_cohort(deltas)
    decoded = codec.decode_cohort(payload)
    prev = jax.tree.map(lambda x: x * 0.3, params)
    coefs = jnp.asarray(r.randn(k), jnp.float32)
    scales = jnp.asarray(1.0 + np.abs(r.randn(k)), jnp.float32)
    wgts = (jnp.asarray(r.uniform(0.2, 1.0, k), jnp.float32)
            if wgt else None)
    if wgts is None:
        got = fp_ops.dequant_batched_server_epilogue(
            payload, prev, params, coefs, scales, 0.2, interpret=True)
        want = fp_ops.batched_server_epilogue(
            decoded, prev, params, coefs, scales, 0.2, interpret=True)
    else:
        got = fp_ops.dequant_buffered_server_fold(
            payload, prev, params, coefs, scales, wgts, 0.2,
            interpret=True)
        want = fp_ops.buffered_server_fold(
            decoded, prev, params, coefs, scales, wgts, 0.2,
            interpret=True)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
