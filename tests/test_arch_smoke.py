"""Per-assigned-architecture smoke tests (deliverable f): instantiate the
REDUCED variant of each family, run one forward + one train step on CPU,
assert output shapes + no NaNs. Decode smoke for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_arch_ids, get_config
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf

ARCHS = all_arch_ids()
B, S = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.modality == "vision":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.num_experts <= 4
    if cfg.is_encoder_decoder:
        params = encdec_mod.init_encdec(cfg, rng, jnp.float32)
        frames = jax.random.normal(rng, (B, cfg.encoder_seq_len, cfg.d_model))
        toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        batch = {"frames": frames, "tokens": toks, "labels": toks}
        loss_fn = lambda p, b: encdec_mod.encdec_loss_fn(cfg, p, b)
    else:
        params = tf.init_lm(cfg, rng, jnp.float32)
        batch = _batch(cfg, rng)
        loss_fn = lambda p, b: tf.loss_fn(cfg, p, b)

    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    assert loss.shape == ()
    assert not jnp.isnan(loss), arch
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = loss_fn(new_params, batch)
    assert not jnp.isnan(loss2)
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a, True).is_encoder_decoder])
def test_prefill_then_decode(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = tf.init_lm(cfg, rng, jnp.float32)
    cap = S + 4
    states = tf.init_states(cfg, B, cap, jnp.float32)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    embeds = None
    off = 0
    if cfg.modality == "vision":
        embeds = jnp.zeros((B, cfg.num_patches, cfg.d_model), jnp.float32)
        off = cfg.num_patches
    logits, states, _ = tf.lm_forward(cfg, params, toks, embeds=embeds,
                                      states=states, logits_slice_last=True)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    pos = jnp.full((B, 1), S + off, jnp.int32)
    logits2, states, _ = tf.lm_forward(cfg, params, tok, positions=pos,
                                       states=states, logits_slice_last=True)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert not jnp.isnan(logits2).any(), arch


@pytest.mark.parametrize("arch", ["whisper-base"])
def test_encdec_decode_smoke(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = encdec_mod.init_encdec(cfg, rng, jnp.float32)
    frames = jax.random.normal(rng, (B, cfg.encoder_seq_len, cfg.d_model))
    enc_out = encdec_mod.encode(cfg, params, frames)
    states = encdec_mod.init_decoder_states(cfg, B, 8, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        pos = jnp.full((B, 1), i, jnp.int32)
        logits, states = encdec_mod.decode(cfg, params, tok, enc_out,
                                           positions=pos, states=states)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
    assert not jnp.isnan(logits).any()


def test_stack_plan_consistency():
    """prefix + period * groups == num_layers for every assigned arch."""
    for arch in ARCHS:
        cfg = get_config(arch)
        if cfg.is_encoder_decoder:
            continue
        prefix, period, groups = tf.stack_plan(cfg)
        assert prefix + period * groups == cfg.num_layers, arch


def test_param_counts_sane():
    """Analytic parameter counts are in the right ballpark of the arch
    names (catching config typos)."""
    expect = {"starcoder2-3b": (2.5e9, 4e9),
              "minitron-8b": (7e9, 10.5e9),
              "llava-next-mistral-7b": (6e9, 8e9),
              "falcon-mamba-7b": (6e9, 8.5e9),
              "phi4-mini-3.8b": (3e9, 5e9),
              "deepseek-v2-236b": (2.0e11, 2.7e11),
              "command-r-35b": (2.8e10, 4.0e10),
              "whisper-base": (5e7, 1.2e8),
              "jamba-1.5-large-398b": (3.3e11, 4.6e11),
              "kimi-k2-1t-a32b": (0.85e12, 1.2e12)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
