"""Shared pytree allclose helper for the equivalence checks (importable
from both the pytest modules and the forced-device subprocess scripts —
it must not import jax config side effects, only compare)."""
import jax
import numpy as np


def assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)
