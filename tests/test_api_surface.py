"""The composable engine surface (DESIGN.md §3): algorithm registry,
pluggable samplers, data sources, config split, cohort padding, and the
deprecated flat-FLConfig shim's round-for-round equivalence."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines
from repro.core.api import (AlgoConfig, ExecConfig, FLConfig,
                            FederatedTrainer)
from repro.core.baselines import (FedDPCHyper, FedProxHyper, ServerAlgo,
                                  make_algorithm, register_algorithm)
from repro.core.round import make_cohort_round
from repro.ingest import (DataSource, IteratorDataSource, ListDataSource,
                          as_data_source, stack_cohort)
from repro.core.samplers import (CyclicSampler, MarkovSampler,
                                 UniformSampler, WeightedSampler)

NUM_CLIENTS = 6
K = 3


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------- FLConfig shim == new spelling ----------------

@pytest.mark.parametrize("algo,flat_kw", [
    ("feddpc", {"lam": 1.3}),
    ("fedprox", {"mu": 0.05}),
    ("fedvarp", {}),
])
def test_flat_config_shim_equivalent(algo, flat_kw):
    """Old surface warns but produces ROUND-FOR-ROUND identical results
    to the ExecConfig/AlgoConfig/ListDataSource/UniformSampler spelling
    (ISSUE 3 acceptance criterion)."""
    with pytest.warns(DeprecationWarning, match="FLConfig is deprecated"):
        old = FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
            FLConfig(algorithm=algo, rounds=3, clients_per_round=K,
                     eta_l=0.05, eta_g=0.1, seed=7, eval_every=10 ** 9,
                     **flat_kw))
    with old:
        old.run()
    hyper = {"feddpc": FedDPCHyper(lam=1.3),
             "fedprox": FedProxHyper(mu=0.05)}.get(algo)
    with FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS,
            ListDataSource(ragged_batch_fn),
            ExecConfig(rounds=3, clients_per_round=K, seed=7,
                       eval_every=10 ** 9),
            algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1, hyper=hyper),
            sampler=UniformSampler(NUM_CLIENTS, K)) as new:
        new.run()
    assert_trees_equal(old.params, new.params)
    assert_trees_equal(old.server_state, new.server_state)
    assert [r.train_loss for r in old.history] == \
        [r.train_loss for r in new.history]
    for a, b in zip(old.schedule, new.schedule):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_flconfig_and_algoconfig_conflict():
    with pytest.raises(ValueError, match="not both"):
        FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                         ragged_batch_fn, FLConfig(), algo=AlgoConfig())


# ---------------- registry ----------------

def test_registry_unknown_name():
    with pytest.raises(ValueError, match="unknown algorithm"):
        make_algorithm("fednope")


def test_registry_hyper_coercion_and_type_check():
    a = make_algorithm("feddpc", {"lam": 2.0})
    assert a.hyper == FedDPCHyper(lam=2.0)
    with pytest.raises(TypeError):
        make_algorithm("fedprox", FedDPCHyper())
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm("fedavg")(lambda h: None)


def test_registered_custom_algorithm_runs_in_trainer():
    """register_algorithm is the extension point: a user-defined rule
    plugs into the trainer (and the fused round) by name."""
    name = "_test_halfavg"
    if name not in baselines._REGISTRY:          # idempotent across reruns
        @register_algorithm(name)
        def _build(h):
            def step(state, params, deltas, client_ids, eta_g, t,
                     client_mask=None, **_):
                delta = baselines._mean_over_clients(deltas, client_mask)
                half = jax.tree.map(lambda d: 0.5 * d, delta)
                return (baselines._apply(params, half, eta_g),
                        {"delta_prev": half}, {})
            return ServerAlgo(name, baselines._fedavg_init, step)
    with FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
            ExecConfig(rounds=2, clients_per_round=K, eval_every=10 ** 9),
            algo=AlgoConfig(name=name, eta_l=0.05, eta_g=0.1)) as tr:
        hist = tr.run()
    assert np.isfinite(hist[-1].train_loss)


def test_client_hparams_flow_from_hyper():
    """FedProx's mu reaches the local update through the algorithm's
    hyper dataclass — two mu values give different trajectories."""
    outs = {}
    for mu in (0.0, 5.0):
        with FederatedTrainer(
                loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
                ExecConfig(rounds=2, clients_per_round=K, seed=1,
                           eval_every=10 ** 9),
                algo=AlgoConfig(name="fedprox", eta_l=0.05, eta_g=0.1,
                                hyper=FedProxHyper(mu=mu))) as tr:
            tr.run()
        outs[mu] = tr.params
    assert not np.allclose(np.asarray(outs[0.0]["w"]),
                           np.asarray(outs[5.0]["w"]))


# ---------------- samplers ----------------

def test_uniform_sampler_matches_legacy_draw():
    rng_a, rng_b = np.random.RandomState(3), np.random.RandomState(3)
    s = UniformSampler(NUM_CLIENTS, K)
    for t in range(4):
        legacy = rng_a.choice(NUM_CLIENTS, size=K, replace=False)
        assert (s.sample(rng_b, t) == legacy).all()


def test_weighted_sampler_excludes_zero_weight():
    s = WeightedSampler([0.0, 1.0, 1.0, 3.0, 5.0], 3)
    rng = np.random.RandomState(0)
    for t in range(20):
        ids = s.sample(rng, t)
        assert len(set(ids.tolist())) == 3 and 0 not in ids
    with pytest.raises(ValueError):
        WeightedSampler([0.0, 0.0, 1.0], 2)


def test_cyclic_sampler_covers_all_clients():
    s = CyclicSampler(5, 2)
    rng = np.random.RandomState(0)
    seen = set()
    for t in range(5):
        ids = s.sample(rng, t)
        assert (s.sample(rng, t) == ids).all()       # deterministic, no RNG
        seen.update(ids.tolist())
    assert seen == set(range(5))


def test_markov_sampler_constant_k_and_state_roundtrip():
    s = MarkovSampler(10, 4, p_on=0.3, p_off=0.6)
    rng = np.random.RandomState(1)
    for t in range(6):
        ids = s.sample(rng, t)
        assert ids.shape == (4,) and len(set(ids.tolist())) == 4
    twin = MarkovSampler(10, 4, p_on=0.3, p_off=0.6)
    twin.load_state_dict(s.state_dict())
    rng_a, rng_b = np.random.RandomState(9), np.random.RandomState(9)
    for t in range(6, 10):
        assert (s.sample(rng_a, t) == twin.sample(rng_b, t)).all()


def test_nonuniform_sampler_drives_trainer():
    with FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
            ExecConfig(rounds=4, clients_per_round=2, eval_every=10 ** 9),
            algo=AlgoConfig(eta_l=0.05, eta_g=0.1),
            sampler=CyclicSampler(NUM_CLIENTS, 2)) as tr:
        tr.run()
    assert (np.asarray(tr.schedule[0]) == [0, 1]).all()
    assert (np.asarray(tr.schedule[1]) == [2, 3]).all()


def test_bad_sampler_output_raises():
    class Bad(UniformSampler):
        def sample(self, rng, t):
            return np.arange(K + 1)                  # wrong cohort size
    tr = FederatedTrainer(
        loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
        ExecConfig(rounds=2, clients_per_round=K, prefetch=False,
                   eval_every=10 ** 9),
        algo=AlgoConfig(eta_l=0.05, eta_g=0.1),
        sampler=Bad(NUM_CLIENTS, K))
    with pytest.raises(ValueError, match="clients_per_round"):
        tr.run_round(0)
    tr.close()


# ---------------- data sources ----------------

def test_as_data_source_coercion():
    src = as_data_source(ragged_batch_fn)
    assert isinstance(src, ListDataSource)
    assert as_data_source(src) is src
    with pytest.raises(TypeError):
        as_data_source(42)


def test_streaming_source_matches_list_source():
    """A generator-backed source produces the identical run (consumed on
    the ingest path) as the materialized list source."""
    def gen(c, t):
        yield from ragged_batch_fn(c, t)

    runs = {}
    for key, src in (("list", ListDataSource(ragged_batch_fn)),
                     ("stream", IteratorDataSource(gen))):
        with FederatedTrainer(
                loss_fn, make_params(), NUM_CLIENTS, src,
                ExecConfig(rounds=3, clients_per_round=K, seed=2,
                           eval_every=10 ** 9),
                algo=AlgoConfig(eta_l=0.05, eta_g=0.1)) as tr:
            tr.run()
        runs[key] = tr
    assert_trees_equal(runs["list"].params, runs["stream"].params)
    assert [r.train_loss for r in runs["list"].history] == \
        [r.train_loss for r in runs["stream"].history]


def test_streaming_image_source_runs():
    import functools
    from repro.ingest import (StreamingImageSource,
                              build_federated_image_data)
    from repro.models.vision import (VisionConfig, init_vision,
                                     vision_loss_fn)
    vc = VisionConfig(name="t", family="lenet5", num_classes=4,
                      image_size=16)
    data = build_federated_image_data(
        num_classes=4, num_clients=NUM_CLIENTS, alpha=0.3,
        samples_per_class=20, test_per_class=5, seed=0, image_size=16)
    src = StreamingImageSource(data, batch_size=16)
    assert src.client_weights().sum() == 4 * 20
    with FederatedTrainer(
            functools.partial(vision_loss_fn, vc),
            init_vision(vc, jax.random.PRNGKey(0)), NUM_CLIENTS, src,
            ExecConfig(rounds=2, clients_per_round=K, eval_every=10 ** 9),
            algo=AlgoConfig(eta_l=0.05, eta_g=0.05),
            sampler=WeightedSampler(src.client_weights(), K)) as tr:
        hist = tr.run()
    assert np.isfinite(hist[-1].train_loss)


# ---------------- cohort padding (single-device semantics) ----------------

@pytest.mark.parametrize("algo,hyper", [
    ("feddpc", None), ("feddpc", FedDPCHyper(use_kernel=True)),
    ("fedvarp", None), ("fedexp", None)])
def test_padded_cohort_matches_unpadded(algo, hyper):
    """Dummy clients (all-False mask rows, out-of-range ids) must not
    perturb the client mean, FedExP's count, or FedVARP's table."""
    pad_to, num = 5, 10
    params = make_params()
    lists = [ragged_batch_fn(c, 0) for c in range(K)]
    mx = max(len(b) for b in lists)
    b_plain, m_plain = stack_cohort(lists, mx)
    b_pad, m_pad = stack_cohort(lists, mx, pad_to=pad_to)
    assert m_pad.shape[0] == pad_to and not m_pad[K:].any()
    a = make_algorithm(algo, hyper)
    st1 = st2 = a.init(params, num)
    p1 = p2 = params
    plain = make_cohort_round(loss_fn, a, 0.05, 0.1, donate=False)
    padded = make_cohort_round(loss_fn, a, 0.05, 0.1, donate=False,
                               pad_clients=True)
    ids = jnp.arange(K, dtype=jnp.int32)
    ids_pad = jnp.concatenate([ids, jnp.full((pad_to - K,), num, jnp.int32)])
    for _ in range(2):      # second round exercises delta_prev / the table
        p1, st1, l1, d1 = plain(st1, p1, b_plain, jnp.asarray(m_plain), ids)
        p2, st2, l2, d2 = padded(st2, p2, b_pad, jnp.asarray(m_pad), ids_pad)
    for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=1e-6)
    for x, y in zip(jax.tree.leaves(st1), jax.tree.leaves(st2)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2)[:K], rtol=1e-6)
    assert not np.asarray(l2)[K:].any()      # dummies report zero loss
    for key in d1:
        np.testing.assert_allclose(float(d1[key]), float(d2[key]),
                                   rtol=1e-3, atol=1e-4, err_msg=key)


# ---------------- context manager / lifecycle ----------------

def test_context_manager_closes_prefetcher():
    with FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
            ExecConfig(rounds=3, clients_per_round=K, prefetch=True,
                       eval_every=10 ** 9),
            algo=AlgoConfig(eta_l=0.05, eta_g=0.1)) as tr:
        tr.run_round(0)
        assert tr._prefetcher is not None
    assert tr._prefetcher._stopped


def test_resume_with_wrong_sampler_raises(tmp_path):
    """A checkpoint drawn by one sampler cannot silently resume under
    another — the checkpointed sampler state would be discarded and the
    bitwise-resume guarantee broken."""
    ec = ExecConfig(rounds=4, clients_per_round=K, eval_every=10 ** 9)
    ac = AlgoConfig(eta_l=0.05, eta_g=0.1)
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, ec, algo=ac,
                          sampler=MarkovSampler(NUM_CLIENTS, K)) as tr:
        tr.run_round(0)
        tr.save(str(tmp_path))
    with pytest.raises(ValueError, match="MarkovSampler"):
        FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                NUM_CLIENTS, ragged_batch_fn, ec, algo=ac)
    # a changed cohort size cannot continue the run either
    ec_wrong = ExecConfig(rounds=4, clients_per_round=K + 1,
                          eval_every=10 ** 9)
    with pytest.raises(ValueError, match="clients_per_round"):
        FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                NUM_CLIENTS, ragged_batch_fn, ec_wrong,
                                algo=ac,
                                sampler=MarkovSampler(NUM_CLIENTS, K + 1))


def test_save_restores_in_process(tmp_path):
    """Fast single-process sanity for save/restore (the fresh-process
    bitwise test is tests/test_resume.py)."""
    ec = ExecConfig(rounds=4, clients_per_round=K, seed=11,
                    eval_every=10 ** 9)
    ac = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, ec, algo=ac) as full:
        full.run()
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, ec, algo=ac) as part:
        part.run_round(0)
        part.run_round(1)
        part.save(str(tmp_path))
    res = FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                  NUM_CLIENTS, ragged_batch_fn, ec, algo=ac)
    with res:
        assert res._start_round == 2
        res.run()
    assert_trees_equal(full.params, res.params)
    assert [r.train_loss for r in full.history] == \
        [r.train_loss for r in res.history]
    for a, b in zip(full.schedule, res.schedule):
        assert (np.asarray(a) == np.asarray(b)).all()
