"""Worker body for the multi-process (multi-host) cohort checks
(DESIGN.md §15) — two modes in one file:

DISTRIBUTED mode (spawned by launch/distributed.spawn_local with the
REPRO_DIST_* contract, 2 processes x 2 CPU devices each): joins the
jax.distributed job, runs the hierarchical 2-process round
(shard_clients=True, edges=2 — each host is one edge aggregator over
its local client slice) for feddpc/fedavg/fedvarp and checks it
round-for-round against a PROCESS-LOCAL serial reference computed in
the same job (identical schedule, allclose params / server state /
losses / diagnostics). Also checks the prefetch on/off runs of the
hierarchical round are BITWISE identical, saves a mid-run checkpoint
(process 0 writes, KV barrier), and (process 0) dumps the finished
run's params/losses for the parent's cross-process resume check.

SINGLE-PROCESS mode (--resume): a plain subprocess (no REPRO_DIST_*
environment) resumes the 2-process checkpoint on a single process and
must land allclose on the dumped 2-process final state — the
cross-process -> single-process resume acceptance check.

Every process must run the SAME sequence of cross-process computations,
so assertions run on all processes (state is replicated; a failure
exits that child nonzero and spawn_local surfaces its tail).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.launch.distributed import maybe_initialize  # noqa: E402

_CTX = maybe_initialize()      # BEFORE any jax device query

import numpy as np             # noqa: E402
import jax                     # noqa: E402

from repro.core.api import (AlgoConfig, ExecConfig,     # noqa: E402
                            FederatedTrainer)
from _matrix_task import (NUM_CLIENTS, K, ROUNDS,       # noqa: E402
                          batch_fn, loss_fn, make_params)
from _tree_assert import assert_trees_close             # noqa: E402

EDGES = 2


def algo_cfg(name):
    return AlgoConfig(name=name, eta_l=0.05, eta_g=0.1)


def exec_cfg(**kw):
    return ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=5,
                      eval_every=10 ** 9, **kw)


def run(name, **kw):
    with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                          exec_cfg(**kw), algo=algo_cfg(name)) as tr:
        tr.run()
    return tr


def host_tree(tree):
    return jax.tree.map(np.asarray, tree)


def check_algo(name):
    ref = run(name, vectorize=False)              # process-local serial
    tr = run(name, shard_clients=True, edges=EDGES)
    assert tr.mesh is not None and tr.mesh.devices.size == 4, tr.mesh
    for a, b in zip(ref.schedule[:ROUNDS], tr.schedule[:ROUNDS]):
        assert (np.asarray(a) == np.asarray(b)).all(), (name, a, b)
    assert_trees_close(host_tree(tr.params), host_tree(ref.params))
    assert_trees_close(host_tree(tr.server_state),
                       host_tree(ref.server_state))
    for rv, rs in zip(tr.history, ref.history):
        assert np.isclose(rv.train_loss, rs.train_loss,
                          rtol=1e-4, atol=1e-6), name
        assert rv.diagnostics.keys() == rs.diagnostics.keys(), name
        for key in rv.diagnostics:
            assert np.isclose(rv.diagnostics[key], rs.diagnostics[key],
                              rtol=1e-3, atol=1e-4), (name, key)
        # hierarchical comm receipt: server fan-in is E summaries, not
        # K deltas — edge uplink carries the per-client deltas
        assert rv.comm_bytes_edge_up == rv.comm_bytes_up > 0, name
        assert rv.comm_bytes_server_up > 0, name
        assert rs.comm_bytes_edge_up == 0, name
    # prefetch off must be BITWISE identical to prefetch on
    tr2 = run(name, shard_clients=True, edges=EDGES, prefetch=False)
    for x, y in zip(jax.tree.leaves(host_tree(tr.params)),
                    jax.tree.leaves(host_tree(tr2.params))):
        assert np.array_equal(x, y, equal_nan=True), name
    print(f"[multihost] {name}: 2-process hierarchical == serial OK "
          f"(pid {jax.process_index()})", flush=True)
    return tr


def distributed_main(args):
    ctx = _CTX
    assert ctx is not None, "distributed mode needs the REPRO_DIST_* env"
    assert jax.process_count() == 2, jax.process_count()
    assert len(jax.devices()) == 4, jax.devices()
    assert len(jax.local_devices()) == 2, jax.local_devices()
    final = {}
    for name in ("feddpc", "fedavg", "fedvarp"):
        final[name] = check_algo(name)
    # cross-process checkpointing: save after round 1, continue, and
    # verify a SAME-JOB resume lands bitwise on the uninterrupted run
    tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                          exec_cfg(shard_clients=True, edges=EDGES),
                          algo=algo_cfg("feddpc"))
    with tr:
        tr.run_round(0)
        tr.run_round(1)
        path = tr.save(args.ckpt)
        assert os.path.basename(path) == "step_00000002", path
        # only process 0 wrote; the barrier guarantees the files exist
        # for every process by now
        assert os.path.isdir(path), path
        tr.run_round(2)
    tr2 = FederatedTrainer.resume(
        args.ckpt, loss_fn, make_params(), NUM_CLIENTS, batch_fn,
        exec_cfg(shard_clients=True, edges=EDGES), algo=algo_cfg("feddpc"))
    assert tr2.start_round == 2, tr2.start_round
    with tr2:
        tr2.run()
    for x, y in zip(jax.tree.leaves(host_tree(tr.params)),
                    jax.tree.leaves(host_tree(tr2.params))):
        assert np.array_equal(x, y, equal_nan=True), "same-job resume"
    print(f"[multihost] 2-process save/resume bitwise OK "
          f"(pid {jax.process_index()})", flush=True)
    if args.out and jax.process_index() == 0:
        ft = host_tree(final["feddpc"].params)
        np.savez(args.out,
                 **{f"params_{k}": v for k, v in ft.items()},
                 losses=np.asarray([r.train_loss
                                    for r in final["feddpc"].history]))
    print("MULTIHOST_WORKER_OK", flush=True)


def resume_main(args):
    """Single process: resume the 2-process checkpoint and match the
    dumped 2-process final state (allclose — the process-count change
    reorders the f32 reductions like any mesh-shape change)."""
    assert _CTX is None, "resume mode must run OUTSIDE the distributed job"
    tr = FederatedTrainer.resume(
        args.ckpt, loss_fn, make_params(), NUM_CLIENTS, batch_fn,
        exec_cfg(**({"shard_clients": True, "edges": EDGES}
                    if args.resume_sharded else {})),
        algo=algo_cfg("feddpc"))
    assert tr.start_round == 2, tr.start_round
    with tr:
        tr.run()
    ref = np.load(args.expect)
    got = host_tree(tr.params)
    for k, v in got.items():
        np.testing.assert_allclose(v, ref[f"params_{k}"],
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    # the resumed round's loss must match the 2-process run's
    assert np.isclose(tr.history[-1].train_loss, ref["losses"][-1],
                      rtol=1e-4, atol=1e-6)
    print("MULTIHOST_RESUME_OK", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True)
    ap.add_argument("--out", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--resume-sharded", action="store_true")
    ap.add_argument("--expect", default="")
    args = ap.parse_args()
    if args.resume:
        resume_main(args)
    else:
        distributed_main(args)


if __name__ == "__main__":
    main()
