"""Integration: full federated rounds on synthetic heterogeneous data —
FedDPC learns, beats round-1 loss, the trainer API works for every
algorithm, and the distributed round step matches the simulation-mode
server math."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import feddpc
from repro.core.api import FLConfig, FederatedTrainer
from repro.core.baselines import ALGORITHM_NAMES
from repro.core.round import make_fl_round_step
from repro.ingest import build_federated_image_data, client_batches
from repro.models import transformer as tf
from repro.models.vision import (VisionConfig, init_vision, vision_accuracy,
                                 vision_loss_fn)

# multi-round runs on real models; deselect with -m "not slow" for the
# quick tier-1 pass (the fast cohort equivalence suite is test_cohort.py)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def vision_task():
    vc = VisionConfig(name="lenet5", family="lenet5", num_classes=4)
    data = build_federated_image_data(
        num_classes=4, num_clients=10, alpha=0.2, samples_per_class=40,
        test_per_class=10, seed=0)
    params = init_vision(vc, jax.random.PRNGKey(0))
    loss_fn = functools.partial(vision_loss_fn, vc)

    def batch_fn(c, t):
        return list(client_batches(data, c, 32, t))

    te_x = jnp.asarray(data.test_images)
    te_y = jnp.asarray(data.test_labels)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    return params, loss_fn, batch_fn, eval_fn, data.num_clients


def test_feddpc_learns(vision_task):
    params, loss_fn, batch_fn, eval_fn, k = vision_task
    cfg = FLConfig(algorithm="feddpc", rounds=10, clients_per_round=4,
                   eta_l=0.02, eta_g=0.02, eval_every=9, seed=0)
    tr = FederatedTrainer(loss_fn, params, k, batch_fn, cfg, eval_fn)
    hist = tr.run()
    assert hist[-1].train_loss < hist[0].train_loss * 0.8
    best, _ = tr.best_accuracy
    assert best > 0.4     # 4 classes, random = 0.25


@pytest.mark.parametrize("algo", ALGORITHM_NAMES)
def test_trainer_api_all_algorithms(vision_task, algo):
    params, loss_fn, batch_fn, eval_fn, k = vision_task
    cfg = FLConfig(algorithm=algo, rounds=3, clients_per_round=3,
                   eta_l=0.02, eta_g=0.02, eval_every=10, seed=1)
    tr = FederatedTrainer(loss_fn, params, k, batch_fn, cfg, None)
    hist = tr.run()
    assert len(hist) == 3
    assert np.isfinite(hist[-1].train_loss)


def test_feddpc_orthogonality_diagnostic(vision_task):
    params, loss_fn, batch_fn, eval_fn, k = vision_task
    cfg = FLConfig(algorithm="feddpc", rounds=4, clients_per_round=3,
                   eta_l=0.02, eta_g=0.02, seed=2, eval_every=100)
    tr = FederatedTrainer(loss_fn, params, k, batch_fn, cfg, None)
    hist = tr.run()
    for rec in hist[1:]:
        d = rec.diagnostics
        denom = max(d["norm_global_update"], 1e-9)
        # previous norm not recorded; dot scaled by current norm only —
        # the invariant is that the dot is tiny relative to norms^2
        assert abs(d["global_dot_prev"]) / (denom * denom + 1e-9) < 0.05


def test_use_kernel_path_equivalence(vision_task):
    """FedDPC with the Pallas epilogue == pure-jnp server math."""
    params, loss_fn, batch_fn, eval_fn, k = vision_task
    outs = {}
    for use_kernel in (False, True):
        cfg = FLConfig(algorithm="feddpc", rounds=2, clients_per_round=3,
                       eta_l=0.02, eta_g=0.02, seed=3, eval_every=100,
                       use_kernel=use_kernel)
        tr = FederatedTrainer(loss_fn, params, k, batch_fn, cfg, None)
        tr.run()
        outs[use_kernel] = tr.params
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_distributed_round_matches_simulation():
    """core/round.py (one jit'd FL round) == api.py simulation trainer for
    the same clients/batches/hyper-params."""
    cfg = get_config("starcoder2-3b", smoke=True)
    loss_fn = lambda p, b: tf.loss_fn(cfg, p, b)
    params = tf.init_lm(cfg, jax.random.PRNGKey(0), jnp.float32)
    key = jax.random.PRNGKey(1)
    K, M, B, S = 3, 2, 2, 16
    toks = jax.random.randint(key, (K, M, B, S + 1), 0, cfg.vocab_size)
    batches = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    eta_l, eta_g = 0.05, 0.05
    round_step = make_fl_round_step(loss_fn, eta_l, eta_g, lam=1.0)
    delta0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    p_dist, d_dist, _ = jax.jit(round_step)(params, delta0, batches)

    # manual: per-client local SGD then feddpc server step
    from repro.core.client import make_local_update
    local = make_local_update(loss_fn, eta_l)
    deltas = []
    for kk in range(K):
        bt = {"tokens": batches["tokens"][kk], "labels": batches["labels"][kk]}
        d, _ = local(params, bt, jnp.ones((M,), bool), None)
        deltas.append(d)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    p_sim, _, _ = feddpc.server_step({"delta_prev": delta0}, params, stacked,
                                     eta_g, 1.0)
    for a, b in zip(jax.tree.leaves(p_dist), jax.tree.leaves(p_sim)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
