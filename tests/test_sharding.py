"""Sharding rules: spec correctness on a debug mesh (divisibility guards,
name-based rules, batch/state spec classes) and roofline HLO parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_debug_mesh
from repro.configs.shapes import InputShape
from repro.roofline.analysis import collective_bytes
from repro.sharding.rules import (ShardingPolicy, batch_specs, param_specs,
                                  state_specs)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


def _spec_of(specs, *path):
    node = specs
    for k in path:
        node = node[k]
    return node


def test_param_rules_dense(mesh):
    cfg = get_config("starcoder2-3b", smoke=True).with_(
        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)
    spec = steps_mod.params_spec(cfg)
    pol = ShardingPolicy(batch_axes=("data",))
    # pretend a 16-way model axis via a fake axis-size map by using the
    # real mesh but checking the *rule* output on divisible dims
    specs = param_specs(spec, mesh, pol)
    # mesh is 1x1: axis size 1 divides everything -> full rule output
    assert _spec_of(specs, "embed") == P("model", None)
    lm = _spec_of(specs, "lm_head", "w")
    assert lm == P(None, "model")
    layer = specs["stack"][0]
    # stacked (scan) leaves carry a leading group dim -> spec is padded
    assert layer["mixer"]["wq"]["w"] == P(None, None, "model")
    assert layer["mixer"]["wo"]["w"] == P(None, "model", None)
    assert layer["norm1"]["scale"] == P()


def test_param_rules_divisibility_guard():
    """On a model axis that does NOT divide a dim, the dim replicates."""
    import jax as _jax
    if len(_jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_debug_mesh(1, 1)
    pol = ShardingPolicy(batch_axes=("data",))
    # axis size 1 divides everything; simulate with a direct call of the
    # internal rule using a fake axis size
    from repro.sharding.rules import _leaf_spec
    s = _leaf_spec("stack/0/mixer/wq/w", (64, 48), pol,
                   {"model": 5, "data": 1})
    assert s == P(None, None)          # 48 % 5 != 0 -> replicate
    s2 = _leaf_spec("stack/0/mixer/wq/w", (64, 50), pol,
                    {"model": 5, "data": 1})
    assert s2 == P(None, "model")


def test_moe_expert_rules():
    from repro.sharding.rules import _leaf_spec
    pol = ShardingPolicy(batch_axes=("data",), expert_axis="data")
    sizes = {"model": 4, "data": 2}
    g = _leaf_spec("stack/0/mlp/gate", (8, 64, 128), pol, sizes)
    assert g == P("data", None, "model")
    d = _leaf_spec("stack/0/mlp/down", (8, 128, 64), pol, sizes)
    assert d == P("data", "model", None)
    r = _leaf_spec("stack/0/mlp/router/w", (64, 8), pol, sizes)
    assert r == P(None, None)


def test_batch_specs_divisibility(mesh):
    pol = ShardingPolicy(batch_axes=("data",))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = batch_specs(batch, mesh, pol)
    assert specs["tokens"] == P("data", None)
    odd = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
    # batch 1 is divisible by axis size 1 -> still sharded (1-way)
    assert batch_specs(odd, mesh, pol)["tokens"] == P("data", None)


def test_state_specs_classes(mesh):
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    shape = InputShape("t", 32, 2, "decode")
    st = steps_mod.states_spec(cfg, shape)
    specs = state_specs(st, mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    names = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path): s for path, s in flat}
    # jamba smoke attn_every=2 -> group has both ssm state and kv cache
    kv_specs = [s for p, s in names.items() if p.endswith("k")]
    assert kv_specs, "expected kv cache leaves"
    ssm_h = [s for p, s in names.items() if p.endswith("h")]
    assert ssm_h, "expected ssm state leaves"


class _FakeCohortMesh:
    """Shape-only stand-in for a (2 clients x 4 model) mesh: the spec
    builders read nothing but axis_names and devices.shape, so the
    two-axis rules are unit-testable on tier-1's single real device."""
    axis_names = ("clients", "model")

    class devices:
        shape = (2, 4)


def test_cohort_param_specs_name_rules_and_fallback():
    """Two-axis cohort specs (DESIGN.md §2): §8 name rules apply when
    they match; unmatched leaves fall back to sharding the LAST
    model-divisible dim; nothing divisible -> replicate."""
    from repro.sharding.rules import cohort_param_specs

    params = {
        "wq": {"w": jnp.zeros((64, 48))},      # §8 rule: (d0, model) on dim -1
        "w1": jnp.zeros((8, 16)),              # fallback: last divisible dim
        "b1": jnp.zeros((16,)),                # fallback: 1-D divisible
        "odd": jnp.zeros((7, 9)),              # nothing divisible by 4
        "head": {"w": jnp.zeros((6, 12))},     # §8 replicates -> fallback
        "scalar": jnp.zeros(()),
    }
    specs = cohort_param_specs(params, _FakeCohortMesh())
    assert specs["wq"]["w"] == P(None, "model")
    assert specs["w1"] == P(None, "model")
    assert specs["b1"] == P("model")
    assert specs["odd"] == P(None, None)
    assert specs["head"]["w"] == P(None, "model")
    assert specs["scalar"] == P()


def test_cohort_state_specs_covary_with_params():
    """Server state co-varies with the param layout by path matching:
    mirrors take the param leaf's spec, per-client tables keep their
    leading num_clients dim replicated, scalars replicate."""
    from repro.sharding.rules import cohort_state_specs

    params = {"w1": jnp.zeros((8, 16)), "b1": jnp.zeros((16,))}
    state = {
        "delta_prev": {"w1": jnp.zeros((8, 16)), "b1": jnp.zeros((16,))},
        "y": {"w1": jnp.zeros((10, 8, 16)), "b1": jnp.zeros((10, 16))},
        "t": jnp.zeros(()),
    }
    specs = cohort_state_specs(state, params, _FakeCohortMesh())
    assert specs["delta_prev"]["w1"] == P(None, "model")
    assert specs["delta_prev"]["b1"] == P("model")
    assert specs["y"]["w1"] == P(None, None, "model")
    assert specs["y"]["b1"] == P(None, "model")
    assert specs["t"] == P()


def test_two_axis_shardings_require_templates():
    """A two-axis mesh without params/server_state templates fails
    loudly instead of silently replicating a model that does not fit."""
    from repro.launch.mesh import make_cohort_mesh
    from repro.sharding.rules import cohort_round_shardings
    if len(jax.devices()) != 1:
        pytest.skip("expects the default 1-device CPU")
    mesh = jax.make_mesh((1, 1), ("clients", "model"))
    # size-1 model axis -> still the replicated prefix layout, no
    # templates needed
    (s, p, b, m, i), _ = cohort_round_shardings(mesh)
    assert p.spec == P() and b.spec == P("clients")
    with pytest.raises(ValueError, match="does not divide"):
        make_cohort_mesh(model=3)   # 1 device cannot tile (clients, 3)
    with pytest.raises(ValueError, match="templates"):
        cohort_round_shardings(_FakeCohortMesh())   # real >1 model axis


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[512]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[256]{0}, f32[256]{0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(%z), dimensions={0}
  %cp = u8[100]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_coll = f32[4]{0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 512 * 4
    assert out["reduce-scatter"] == 2 * 256 * 4
    assert out["all-to-all"] == 8 * 64 * 2
    assert out["collective-permute"] == 100


def test_input_specs_all_shapes():
    """input_specs produces consistent ShapeDtypeStructs for every
    (arch x shape) — the 40 dry-run combos' argument builders."""
    from repro.configs.base import all_arch_ids
    from repro.configs.shapes import SHAPES
    for arch in all_arch_ids():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            specs = steps_mod.input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves, (arch, sname)
            if shape.kind == "train" and not cfg.is_encoder_decoder:
                toks = (specs["batch"]["tokens"].shape
                        if "batch" in specs else None)
                total = toks[1] + (cfg.num_patches
                                   if cfg.modality == "vision" else 0)
                assert total == shape.seq_len
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
            # states spec builds without allocation
            if shape.kind != "train":
                st = steps_mod.states_spec(cfg, shape)
                assert jax.tree.leaves(st)


def test_long_context_window_policy():
    """Dense archs get the sliding window for long_500k; SSM/hybrid don't."""
    from repro.configs.shapes import get_shape
    long = get_shape("long_500k")
    assert steps_mod.effective_window(
        get_config("command-r-35b"), long) == steps_mod.WINDOW
    assert steps_mod.effective_window(
        get_config("falcon-mamba-7b"), long) == 0
    assert steps_mod.effective_window(
        get_config("jamba-1.5-large-398b"), long) == 0
    # and the dense ring cache is window-sized, not 500k
    assert steps_mod.cache_capacity(
        get_config("command-r-35b"), long) == steps_mod.WINDOW
