"""Subprocess body for the cross-regime equivalence test matrix.

Runs on 8 FORCED host devices (the XLA flag must be set before jax
initializes, which is why this lives in its own process rather than the
main pytest interpreter). Every cell of the matrix

    algorithm x sampler x execution regime x {prefetch on/off}

must reproduce the serial reference (vectorize=False, prefetch=False)
round for round: identical client schedule, allclose params / server
state / per-round losses / diagnostics. The axes come from the LIVE
registries — ``repro.core.baselines.ALGORITHM_NAMES``,
``repro.core.samplers.sampler_matrix`` and ``repro.core.api.
EXEC_REGIMES`` — so a newly registered algorithm, sampler, or execution
regime auto-enrolls without touching this file.

On the 8-device harness ``sharded2d`` is the (2 clients x 4 model) mesh
of the acceptance criteria; the model dims of the toy task (16, 4) are
4-divisible so the model axis genuinely partitions the leaves.

Invoked by tests/test_regime_matrix.py with either
    --cells algo:sampler:regime:{P|N}[,...]     matrix cells to check
    --cross-mesh-resume                         save on 2-axis, resume 1-D
    --kernel-fallback                           use_kernel under sharded2d
"""
import argparse
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()

import numpy as np          # noqa: E402
import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402

from repro.core.api import (AlgoConfig, EXEC_REGIMES,       # noqa: E402
                            ExecConfig, FederatedTrainer)
from repro.core.baselines import default_hyper              # noqa: E402
from repro.core.samplers import sampler_matrix              # noqa: E402
from _tree_assert import assert_trees_close                 # noqa: E402
# the toy task lives in _matrix_task.py (no env side effects) so the
# multi-process worker (tests/_multihost_worker.py) shares it verbatim
from _matrix_task import (NUM_CLIENTS, K, ROUNDS,           # noqa: E402
                          batch_fn, loss_fn, make_params)


def run_cell(algo: str, sampler_name: str, regime: str, prefetch: bool,
             use_kernel: bool = False, codec=None,
             server_opt=None) -> FederatedTrainer:
    kw = dict(EXEC_REGIMES[regime])
    if codec is not None:
        kw["codec"] = codec
    if server_opt is not None:
        kw["server_opt"] = server_opt
    cfg = ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=5,
                     eval_every=10 ** 9, prefetch=prefetch, **kw)
    with FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS, batch_fn, cfg,
            algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1,
                            hyper=default_hyper(algo,
                                                use_kernel=use_kernel)),
            sampler=sampler_matrix(NUM_CLIENTS, K)[sampler_name]) as tr:
        tr.run()
    return tr


_ref_cache = {}


def reference(algo: str, sampler_name: str, codec=None,
              server_opt=None) -> FederatedTrainer:
    key = (algo, sampler_name, codec, server_opt)
    if key not in _ref_cache:
        _ref_cache[key] = run_cell(algo, sampler_name, "serial", False,
                                   codec=codec, server_opt=server_opt)
    return _ref_cache[key]


# Documented quantization-drift bounds for the LOSSY codec regimes vs
# the NO-codec serial reference (DESIGN.md §13). Strict regime
# equivalence (vectorized / async / 2-axis mesh vs serial) is still
# checked at the default tolerances — against a serial reference run
# with the SAME codec, so the only slack granted here is the codec's
# own quantization error, never an execution-regime divergence.
CODEC_TOL = {
    "bf16": dict(rtol=5e-2, atol=5e-3),
    "int8": dict(rtol=1e-1, atol=2e-2),
    "int8_sym": dict(rtol=1e-1, atol=2e-2),
    "int8_sr": dict(rtol=2e-1, atol=5e-2),
}


def check_cell(cell: str):
    algo, sampler_name, regime, pf = cell.split(":")
    prefetch = {"P": True, "N": False}[pf]
    if regime == "serial" and not prefetch:
        # this IS the reference configuration: run it into the cache
        reference(algo, sampler_name)
        print(f"[matrix] {cell} is the reference OK")
        return
    tr = run_cell(algo, sampler_name, regime, prefetch)
    codec = EXEC_REGIMES[regime].get("codec")
    sopt = EXEC_REGIMES[regime].get("server_opt")
    lossy = codec is not None and codec != "identity"
    plain = reference(algo, sampler_name, server_opt=sopt)
    if lossy:
        # the documented drift bound vs the uncompressed run
        tol = CODEC_TOL[codec]
        assert_trees_close(tr.params, plain.params, **tol)
        for rv, rs in zip(tr.history, plain.history):
            assert np.isclose(rv.train_loss, rs.train_loss,
                              rtol=tol["rtol"], atol=tol["atol"]), cell
    # strict regime equivalence: same-codec (and same-server-opt)
    # serial reference
    ref = plain if not lossy else reference(algo, sampler_name, codec,
                                            server_opt=sopt)
    for a, b in zip(ref.schedule[:ROUNDS], tr.schedule[:ROUNDS]):
        assert (np.asarray(a) == np.asarray(b)).all(), (cell, a, b)
    assert_trees_close(tr.params, ref.params)
    assert_trees_close(tr.server_state, ref.server_state)
    for rv, rs in zip(tr.history, ref.history):
        assert np.isclose(rv.train_loss, rs.train_loss,
                          rtol=1e-4, atol=1e-6), cell
        assert rv.diagnostics.keys() == rs.diagnostics.keys(), cell
        for key in rv.diagnostics:
            assert np.isclose(rv.diagnostics[key], rs.diagnostics[key],
                              rtol=1e-3, atol=1e-4), (cell, key)
    if regime == "sharded2d":
        assert tr.mesh is not None and tr.mesh.devices.shape == (2, 4)
        # the model axis must actually partition a param leaf
        from jax.sharding import PartitionSpec as P
        assert tr.params["w1"].sharding.spec == P(None, "model"), \
            tr.params["w1"].sharding
    if sopt is not None:
        # optimizer state must exist and (on the two-axis mesh) the
        # moments must CO-LOCATE with their param leaves (DESIGN.md §14)
        assert tr._opt_state is not None, cell
        if regime.endswith("_2d"):
            for mom in ("m", "v"):
                for leaf, pleaf in zip(
                        jax.tree_util.tree_leaves(tr._opt_state[mom]),
                        jax.tree_util.tree_leaves(tr.params)):
                    assert leaf.sharding == pleaf.sharding, \
                        (cell, mom, leaf.sharding, pleaf.sharding)
    print(f"[matrix] {cell} == serial reference OK")


def check_cross_mesh_resume():
    """Save a 2-axis run mid-stream, resume it on a 1-D client mesh: the
    checkpoint holds full host arrays, so a mesh-shape change across
    save/resume works (allclose — mesh shape changes the reduction
    order, so bitwise equality only holds for same-mesh resume, which
    tests/test_resume.py covers)."""
    algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)

    def cfg(**kw):
        return ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=5,
                          eval_every=10 ** 9, **kw)

    with tempfile.TemporaryDirectory() as d:
        tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                              cfg(shard_clients=True, shard_model=4),
                              algo=algo)
        with tr:
            tr.run_round(0)
            tr.run_round(1)
            tr.save(d)
            tr.run_round(2)
        tr2 = FederatedTrainer.resume(d, loss_fn, make_params(), NUM_CLIENTS,
                                      batch_fn, cfg(shard_clients=True),
                                      algo=algo)
        assert tr2.start_round == 2, tr2.start_round
        assert tr2.mesh.devices.shape == (8,)
        with tr2:
            tr2.run()
    assert_trees_close(tr.params, tr2.params)
    assert_trees_close(tr.server_state, tr2.server_state)
    print("[matrix] cross-mesh (2x4 -> 8) resume OK")


def check_codec_identity_bitwise():
    """codec=identity must be BITWISE identical to no-codec — the
    encode/decode hooks return the SAME arrays, so every regime's round
    math is untouched down to the last ulp (acceptance criterion)."""
    for regime in ("serial", "vectorized", "sharded2d", "async_buffer"):
        base = run_cell("feddpc", "uniform", regime, True)
        idt = run_cell("feddpc", "uniform", regime, True, codec="identity")
        for which, a, b in (("params", base.params, idt.params),
                            ("state", base.server_state, idt.server_state)):
            la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
            assert len(la) == len(lb)
            for x, y in zip(la, lb):
                assert np.array_equal(np.asarray(x), np.asarray(y),
                                      equal_nan=True), (regime, which)
        for rb, ri in zip(base.history, idt.history):
            assert rb.train_loss == ri.train_loss, regime
        print(f"[matrix] identity bitwise == no-codec under {regime} OK")


def check_kernel_fallback():
    """FedDPCHyper(use_kernel=True) under the two-axis mesh must fall
    back to the reference epilogue (model-sharded leaves) and still
    match the serial reference."""
    tr = run_cell("feddpc", "uniform", "sharded2d", True, use_kernel=True)
    ref = reference("feddpc", "uniform")
    assert_trees_close(tr.params, ref.params)
    assert_trees_close(tr.server_state, ref.server_state)
    print("[matrix] use_kernel fallback under sharded2d OK")


def main():
    assert len(jax.devices()) == 8, jax.devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="")
    ap.add_argument("--cross-mesh-resume", action="store_true")
    ap.add_argument("--kernel-fallback", action="store_true")
    ap.add_argument("--codec-identity-bitwise", action="store_true")
    args = ap.parse_args()
    for cell in [c for c in args.cells.split(",") if c]:
        check_cell(cell)
    if args.cross_mesh_resume:
        check_cross_mesh_resume()
    if args.kernel_fallback:
        check_kernel_fallback()
    if args.codec_identity_bitwise:
        check_codec_identity_bitwise()
    print("ALL OK")


if __name__ == "__main__":
    main()
