"""Optional-hypothesis shim.

Property tests in this repo use ``hypothesis`` when it is installed (see
requirements-dev.txt) and fall back to a tiny deterministic sampler when
it is not, so ``pytest`` collection never dies on the import. The
fallback mimics just the API surface these tests use:

  given(*strategies)             runs the test body over max_examples
                                 deterministic draws (seeded by the test
                                 name), always including the boundary
                                 draw (every strategy's minimum / first
                                 element) as example #0
  settings.register_profile / load_profile    max_examples only
  st.integers / st.floats / st.sampled_from

Import it as ``from _hypothesis_compat import given, settings, st``.
"""
from __future__ import annotations

try:                                    # real hypothesis when available
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # deterministic fallback
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class settings:                                          # noqa: N801
        _profiles = {"default": {"max_examples": 10}}
        _active = "default"

        def __init__(self, **kw):
            pass

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            cls._active = name

        @classmethod
        def _max_examples(cls):
            return int(cls._profiles.get(cls._active, {})
                       .get("max_examples", 10))

    class _Strategy:
        def __init__(self, boundary, sampler):
            self.boundary = boundary          # shrink target / edge case
            self.sampler = sampler            # rng -> value

        def draw(self, rng, i):
            return self.boundary if i == 0 else self.sampler(rng)

    class _Namespace:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                int(min_value),
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                float(min_value),
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(seq[0], lambda rng: seq[rng.randint(len(seq))])

    st = _Namespace()

    def given(*strategies):
        def deco(f):
            def runner():
                seed = zlib.crc32(f.__name__.encode()) & 0x7FFFFFFF
                rng = np.random.RandomState(seed)
                for i in range(settings._max_examples()):
                    args = [s.draw(rng, i) for s in strategies]
                    try:
                        f(*args)
                    except AssertionError as e:
                        raise AssertionError(
                            f"falsified on example {args!r}: {e}") from e
            # plain attribute copy — functools.wraps would expose f's
            # signature through __wrapped__ and make pytest treat the
            # strategy args as fixtures
            runner.__name__ = f.__name__
            runner.__doc__ = f.__doc__
            runner.__module__ = f.__module__
            return runner
        return deco
