"""Child body for tests/test_distributed.py — the spawner/KV smoke.

Joins the 2-process job, checks the process/device topology, exercises
barrier() and kv_allmax(), then (with --fail) process 1 exits nonzero
AFTER the barrier so the parent can check spawn_local's failure
surfacing without wedging process 0 inside initialize().
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.launch.distributed import (barrier, is_coordinator,  # noqa: E402
                                      kv_allmax, maybe_initialize)

ctx = maybe_initialize()
assert ctx is not None, "worker needs the REPRO_DIST_* environment"
assert ctx.num_processes == 2, ctx

import jax  # noqa: E402

pid = jax.process_index()
assert ctx.process_id == pid
assert jax.process_count() == 2
assert len(jax.local_devices()) == 1, jax.local_devices()
assert len(jax.devices()) == 2, jax.devices()
assert is_coordinator() == (pid == 0)

# kv_allmax: every process publishes, everyone reads the max
assert kv_allmax("smoke/a", 10 + pid) == 11
assert kv_allmax("smoke/b", 5 - pid) == 5

barrier("smoke/done")
if "--fail" in sys.argv and pid == 1:
    print("CHILD_FAILING_ON_PURPOSE", flush=True)
    sys.exit(3)
print("DIST_SMOKE_OK", flush=True)
