"""Delta-codec subsystem (repro/codec; DESIGN.md §13): wire-format
round-trip error bounds (property tests), error-feedback residual decay,
uplink byte accounting, the trainer-level identity/lossy contracts, and
bitwise checkpointing of the EF accumulator — including the mid-buffer
async cut where quantized in-flight payloads live in the checkpoint.

The cross-regime allclose cells (codec_* regimes vs serial on the forced
8-device mesh, and the identity-bitwise sweep) live in
tests/test_regime_matrix.py; these are the fast single-process
contracts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.codec import (CODEC_NAMES, EncodedCohort, make_codec,
                         tree_nbytes)
from repro.codec.base import sanitized_residual
from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.runtime import MarkovRuntime

settings.register_profile("codec", max_examples=10, deadline=None)
settings.load_profile("codec")

NUM_CLIENTS = 8
K = 3


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def make_trainer(rounds=4, algo="feddpc", **exec_kw):
    kw = dict(clients_per_round=K, seed=7, eval_every=10 ** 9)
    kw.update(exec_kw)
    return FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                            ragged_batch_fn, ExecConfig(rounds=rounds, **kw),
                            algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1))


def tree_maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------- registry / wire format ----------------

def test_registry_names_and_unknown_codec():
    assert {"identity", "bf16", "int8", "int8_sym", "int8_sr"} \
        <= set(CODEC_NAMES)
    assert make_codec(None) is None
    assert make_codec("") is None
    with pytest.raises(ValueError, match="int8"):
        make_codec("no-such-codec")


def test_identity_is_the_same_object():
    """IdentityCodec encode/decode return the SAME pytree object — the
    structural guarantee behind the bitwise acceptance criterion."""
    c = make_codec("identity")
    t = make_params()
    assert c.encode(t) is t
    assert c.decode(t) is t
    assert not c.lossy


def test_payload_layout_and_cohort_roundtrip():
    """Cohort encode carries the leading client axis on q and (K,)
    per-leaf scale/zero vectors; decode is q * scale + zero."""
    c = make_codec("int8")
    r = np.random.RandomState(0)
    stacked = {"w": jnp.asarray(r.randn(K, 4, 3), jnp.float32),
               "b": jnp.asarray(r.randn(K, 3), jnp.float32)}
    p = c.encode_cohort(stacked)
    assert set(p) == {"q", "scale", "zero"}
    assert p["q"]["w"].shape == (K, 4, 3) and p["q"]["w"].dtype == jnp.int8
    assert p["scale"]["w"].shape == (K,)
    dec = c.decode_cohort(p)
    want = jax.tree.map(
        lambda q, s, z: q.astype(jnp.float32)
        * s.reshape((-1,) + (1,) * (q.ndim - 1))
        + z.reshape((-1,) + (1,) * (q.ndim - 1)),
        p["q"], p["scale"], p["zero"])
    assert tree_maxdiff(dec, want) == 0.0


def test_zero_range_leaf_roundtrips_exactly():
    """A constant leaf has range 0: the scale guard kicks in and the
    decode reproduces the constant exactly (no 0/0)."""
    for name in ("bf16", "int8", "int8_sym"):
        c = make_codec(name)
        t = {"a": jnp.full((5,), 3.25, jnp.float32),
             "z": jnp.zeros((4, 2), jnp.float32)}
        assert tree_maxdiff(c.decode(c.encode(t)), t) == 0.0, name


def test_nonfinite_rows_stay_nonfinite_after_decode():
    """Quantizers must PROPAGATE NaN/Inf (scales stay nonfinite), so the
    chaos UpdateGuard still sees a nonfinite row in the quantized
    domain and quarantines it."""
    for name in ("bf16", "int8", "int8_sym"):
        c = make_codec(name)
        bad = {"a": jnp.asarray([1.0, jnp.nan, 2.0], jnp.float32)}
        dec = c.decode(c.encode(bad))
        assert not bool(jnp.isfinite(dec["a"]).all()), name
        inf = {"a": jnp.asarray([1.0, jnp.inf, 2.0], jnp.float32)}
        dec = c.decode(c.encode(inf))
        assert not bool(jnp.isfinite(dec["a"]).all()), name


def test_sanitized_residual_zeroes_nonfinite():
    raw = {"a": jnp.asarray([1.0, jnp.nan, 2.0], jnp.float32)}
    dec = {"a": jnp.asarray([1.0, jnp.nan, jnp.inf], jnp.float32)}
    r = sanitized_residual(raw, dec)
    np.testing.assert_array_equal(np.asarray(r["a"]), [0.0, 0.0, 0.0])


def test_stochastic_codec_requires_key():
    c = make_codec("int8_sr")
    assert c.stochastic
    with pytest.raises(ValueError, match="key"):
        c.encode(make_params())
    p = c.encode(make_params(), key=jax.random.PRNGKey(0))
    assert p["q"]["w"].dtype == jnp.int8


# ---------------- property tests: round-trip error bounds ------------

@given(st.integers(0, 10 ** 6), st.floats(1e-3, 1e3))
def test_int8_roundtrip_error_bound(seed, mag):
    """Affine int8 with round-to-nearest: per-element error <= scale/2,
    scale = range/254 (the documented wire-format bound)."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(64) * mag, jnp.float32)
    c = make_codec("int8")
    p = c.encode({"x": x})
    dec = c.decode(p)["x"]
    scale = float(p["scale"]["x"])
    rng = float(x.max() - x.min())
    assert scale <= rng / 254 * 1.001 + 1e-12
    assert float(jnp.max(jnp.abs(dec - x))) <= scale * 0.5 * 1.001 + 1e-12


@given(st.integers(0, 10 ** 6), st.floats(1e-3, 1e3))
def test_int8_sym_roundtrip_error_bound(seed, mag):
    """Symmetric int8: zero point is exactly 0 and error <= amax/254."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(64) * mag, jnp.float32)
    c = make_codec("int8_sym")
    p = c.encode({"x": x})
    assert float(jnp.abs(p["zero"]["x"])) == 0.0
    amax = float(jnp.max(jnp.abs(x)))
    err = float(jnp.max(jnp.abs(c.decode(p)["x"] - x)))
    assert err <= amax / 254 * 1.001 + 1e-12


@given(st.integers(0, 10 ** 6), st.floats(1e-3, 1e3))
def test_bf16_roundtrip_relative_error_bound(seed, mag):
    """bf16 keeps 8 significand bits: relative error <= 2^-8 per
    element (round-to-nearest is 2^-9; the bound documented for the
    wire format is the conservative one)."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(64) * mag, jnp.float32)
    dec = make_codec("bf16").decode(make_codec("bf16").encode({"x": x}))
    rel = jnp.abs(dec["x"] - x) / jnp.maximum(jnp.abs(x), 1e-30)
    assert float(jnp.max(rel)) <= 2.0 ** -8 + 1e-12


@given(st.integers(0, 10 ** 6))
def test_int8_sr_unbiased_and_bounded(seed):
    """Stochastic rounding: error < 1 scale per element, and averaging
    over many independent keys recovers the input (unbiasedness — the
    property that makes SR compose with EF)."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(16), jnp.float32)
    c = make_codec("int8_sr")
    p0 = c.encode({"x": x}, key=jax.random.PRNGKey(seed))
    scale = float(p0["scale"]["x"])
    assert float(jnp.max(jnp.abs(c.decode(p0)["x"] - x))) \
        <= scale * 1.001 + 1e-12
    acc = np.zeros(16, np.float64)
    n = 64
    for i in range(n):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        acc += np.asarray(c.decode(c.encode({"x": x}, key=key))["x"])
    # mean error of n unbiased draws ~ scale / sqrt(n); allow 4 sigma
    assert float(np.max(np.abs(acc / n - np.asarray(x)))) \
        <= 4.0 * scale / np.sqrt(n) + 1e-12


@given(st.sampled_from(["int8", "int8_sym"]), st.integers(0, 10 ** 6))
def test_error_feedback_residual_decay(name, seed):
    """Server-side EF on a CONSTANT delta: shipping delta + residual
    each round makes the running mean of the decoded updates converge
    to the true delta at O(scale/T) — strictly better than the one-shot
    quantization floor. This is the round-over-round decay property the
    trainer's accumulator relies on."""
    c = make_codec(name)
    r = np.random.RandomState(seed)
    d = jnp.asarray(r.randn(32), jnp.float32)
    ef = jnp.zeros_like(d)
    total = np.zeros(32, np.float64)
    errs = []
    for t in range(1, 9):
        ship = d + ef
        dec = c.decode(c.encode({"x": ship}))["x"]
        ef = ship - dec
        total += np.asarray(dec)
        errs.append(float(np.max(np.abs(total / t - np.asarray(d)))))
    scale = float(c.encode({"x": d})["scale"]["x"])
    # after T rounds the accumulated error is one residual, so the mean
    # error shrinks ~1/T; one-shot error can be as large as scale/2
    assert errs[-1] <= scale / 2.0 / 4.0 + 1e-12   # >=4x below one-shot cap
    assert errs[-1] <= errs[0] + 1e-12


# ---------------- byte accounting ----------------

def test_client_bytes_accounting():
    t = make_params()
    f32 = tree_nbytes(t)
    assert f32 == sum(np.asarray(x).nbytes for x in jax.tree.leaves(t))
    assert make_codec("identity").client_bytes(t) == f32
    # at model scale (elements >> leaves) the per-leaf scale/zero
    # overhead vanishes: bf16 halves, int8 quarters the uplink
    big = {"w": jnp.zeros((64, 32), jnp.float32),
           "b": jnp.zeros((512,), jnp.float32)}
    f32 = tree_nbytes(big)
    assert make_codec("bf16").client_bytes(big) * 2 <= f32 + 16 * 2
    assert make_codec("int8").client_bytes(big) * 2 < f32


def _big_loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2) + 0.0 * jnp.sum(p["pad"])


def _big_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32),
            "pad": jnp.zeros((1024,), jnp.float32)}


def test_comm_bytes_up_reduction_on_round_records():
    """RoundRecord.comm_bytes_up: int8 uplink is >= 2x smaller than the
    identity/no-codec uplink, every round (the acceptance ratio; the
    params carry a model-scale leaf so the byte count is not dominated
    by the per-leaf scale/zero overhead)."""
    def mk(**kw):
        return FederatedTrainer(
            _big_loss_fn, _big_params(), NUM_CLIENTS, ragged_batch_fn,
            ExecConfig(rounds=3, clients_per_round=K, seed=7,
                       eval_every=10 ** 9, **kw),
            algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1))

    with mk() as plain:
        plain.run()
    with mk(codec="int8") as q:
        q.run()
    for rp, rq in zip(plain.history, q.history):
        assert rp.comm_bytes_up > 0 and rq.comm_bytes_up > 0
        assert rp.comm_bytes_up >= 2 * rq.comm_bytes_up


def test_place_encoded_preserves_values_and_shrinks_bytes():
    from repro.ingest.placement import CohortPlacer
    c = make_codec("int8")
    r = np.random.RandomState(3)
    stacked = {"w": jnp.asarray(r.randn(K, 4, 3), jnp.float32)}
    enc = EncodedCohort(codec=c.name, payload=c.encode_cohort(stacked),
                        clients=K)
    assert 2 * enc.nbytes < tree_nbytes(stacked)
    placed = CohortPlacer().place_encoded(enc)
    assert placed.codec == c.name and placed.clients == K
    assert tree_maxdiff(placed.payload, enc.payload) == 0.0
    assert tree_maxdiff(c.decode_cohort(placed.payload),
                        c.decode_cohort(enc.payload)) == 0.0


# ---------------- trainer-level contracts ----------------

def test_ef_requires_lossy_codec():
    with pytest.raises(ValueError, match="(?i)lossy"):
        make_trainer(codec="identity", codec_ef=True)
    with pytest.raises(ValueError, match="(?i)lossy"):
        make_trainer(codec_ef=True)


def test_exec_codec_overrides_algo_codec():
    """ExecConfig.codec is the execution override of AlgoConfig.codec
    (EXEC_REGIMES entries are ExecConfig kwargs)."""
    cfg = ExecConfig(rounds=1, clients_per_round=K, seed=7,
                     eval_every=10 ** 9, codec="int8")
    tr = FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                          ragged_batch_fn, cfg,
                          algo=AlgoConfig(name="feddpc", eta_l=0.05,
                                          eta_g=0.1, codec="identity"))
    assert tr._codec.name == "int8"
    tr2 = make_trainer(rounds=1)
    assert tr2._codec is None


def test_ef_accumulator_active_and_bounded():
    """The trainer's EF accumulator does real work: nonzero after the
    first round (quantization error exists), yet bounded by the
    per-round quantization scale across a long run — the residual is
    re-shipped and cancels instead of accumulating (the decay property
    itself is pinned by test_error_feedback_residual_decay)."""
    rounds = 12
    with make_trainer(rounds=rounds, codec="int8", codec_ef=True) as qe:
        qe.run_round(0)
        ef1 = jax.tree.map(lambda x: np.asarray(x), qe._ef)
        for t in range(1, rounds):
            qe.run_round(t)
    assert max(float(np.max(np.abs(x)))
               for x in jax.tree.leaves(ef1)) > 0.0
    # the deltas on this toy task are O(1); an EF blow-up would be
    # orders of magnitude above this bound
    assert max(float(jnp.max(jnp.abs(x)))
               for x in jax.tree.leaves(qe._ef)) < 0.1


def test_ef_state_survives_save_resume_bitwise(tmp_path):
    """Sync rounds: cut an int8+EF run, save, resume in a fresh trainer
    — params, server state, EF accumulator and losses reproduce the
    uninterrupted run bitwise (the EF tree rides the npz sidecar)."""
    kw = dict(codec="int8", codec_ef=True, rounds=6)
    with make_trainer(**kw) as full:
        full.run()
    with make_trainer(**kw) as part:
        for t in range(3):
            part.run_round(t)
        assert part._ef is not None
        part.save(str(tmp_path))
    res = FederatedTrainer.resume(
        str(tmp_path), loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
        ExecConfig(clients_per_round=K, seed=7, eval_every=10 ** 9, **kw),
        algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1))
    with res:
        assert res.start_round == 3
        res.run()
    for a, b in zip(jax.tree.leaves(full.params), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(full._ef), jax.tree.leaves(res._ef)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [r.train_loss for r in full.history] == \
        [r.train_loss for r in res.history]


def _markov_rt():
    return MarkovRuntime(NUM_CLIENTS, fast=0.5, slow=3.0,
                         p_slow=0.4, p_fast=0.5, dropout=0.1)


def test_ef_mid_buffer_async_save_resume_bitwise(tmp_path):
    """The async acceptance cut: int8+EF through the buffered-async
    engine, saved with IN-FLIGHT quantized payload entries on the heap
    (concurrency 3 > buffer 2), resumed fresh — bitwise equal to the
    uninterrupted run, EF accumulator included. Exercises the encoded
    BufferEntry npz round-trip (int8 codes reload as int8)."""
    kw = dict(codec="int8", codec_ef=True, async_buffer=True,
              buffer_size=2, async_concurrency=3, rounds=6)

    def mk():
        return FederatedTrainer(
            loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
            ExecConfig(clients_per_round=K, seed=7, eval_every=10 ** 9,
                       **kw),
            algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1),
            runtime=_markov_rt())

    with mk() as full:
        full.run()
    with mk() as part:
        for t in range(3):
            part.run_round(t)
        assert len(part._engine.inflight()) > 0      # mid-buffer cut
        part.save(str(tmp_path))
    res = FederatedTrainer.resume(
        str(tmp_path), loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
        ExecConfig(clients_per_round=K, seed=7, eval_every=10 ** 9, **kw),
        algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1),
        runtime=_markov_rt())
    with res:
        assert res.start_round == 3
        res.run()
    for a, b in zip(jax.tree.leaves(full.params), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(full._ef), jax.tree.leaves(res._ef)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [r.train_loss for r in full.history] == \
        [r.train_loss for r in res.history]


def test_resume_rejects_mismatched_codec(tmp_path):
    """The checkpoint echoes the codec config; resuming under a
    different codec (or dropping EF) fails loudly instead of silently
    decoding the EF tree into the wrong pipeline."""
    with make_trainer(codec="int8", codec_ef=True, rounds=2) as tr:
        tr.run_round(0)
        tr.save(str(tmp_path))
    algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)

    def cfg(**kw):
        return ExecConfig(rounds=2, clients_per_round=K, seed=7,
                          eval_every=10 ** 9, **kw)

    with pytest.raises(ValueError, match="codec"):
        FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                NUM_CLIENTS, ragged_batch_fn,
                                cfg(codec="bf16"), algo=algo)
    with pytest.raises(ValueError, match="codec"):
        FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                NUM_CLIENTS, ragged_batch_fn,
                                cfg(codec="int8"), algo=algo)
    with pytest.raises(ValueError, match="codec"):
        FederatedTrainer.resume(str(tmp_path), loss_fn, make_params(),
                                NUM_CLIENTS, ragged_batch_fn, cfg(),
                                algo=algo)


# ---------------- parallel decode workers (ingest satellite) ---------

def test_parallel_decode_order_is_deterministic(tmp_path):
    """ImageDecodePool output order is the input order regardless of
    worker count — the batch stacks (and thus every downstream round)
    are bit-identical between serial and parallel decode."""
    from repro.ingest import TinyImageNetSource
    from repro.ingest.readers import write_tiny_imagenet_fixture
    write_tiny_imagenet_fixture(str(tmp_path), num_wnids=3, per_wnid=5,
                                val_per_wnid=2, image_size=8)
    serial = TinyImageNetSource(str(tmp_path), num_clients=4, alpha=0.5,
                                batch_size=4, seed=0, image_size=8,
                                decode_workers=0)
    par = TinyImageNetSource(str(tmp_path), num_clients=4, alpha=0.5,
                             batch_size=4, seed=0, image_size=8,
                             decode_workers=4)
    assert par.decoder.workers == 4
    for c in range(4):
        for bs, bp in zip(serial.client_batches(c, 0),
                          par.client_batches(c, 0)):
            np.testing.assert_array_equal(bs["images"], bp["images"])
            np.testing.assert_array_equal(bs["labels"], bp["labels"])
    xs, ys = serial.test_arrays()
    xp, yp = par.test_arrays()
    np.testing.assert_array_equal(xs, xp)
    np.testing.assert_array_equal(ys, yp)
    par.decoder.close()
    par.decoder.close()     # idempotent
