"""Checkpoint round-trip + rotation + FL server-state restore."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt


def _state(seed):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 3)),
                       "layers": [jnp.ones((2,)), jnp.zeros((5,))]},
            "delta_prev": {"w": jax.random.normal(k, (4, 3)) * 0.1,
                           "layers": [jnp.ones((2,)), jnp.zeros((5,))]},
            "round": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    s = _state(0)
    ckpt.save(str(tmp_path), 7, s)
    like = jax.tree.map(jnp.zeros_like, s)
    r = ckpt.restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_allclose(a, b)


def test_rotation_keeps_last_k(tmp_path):
    for step in range(5):
        ckpt.save(str(tmp_path), step, _state(step), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    import os
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 2


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, {"w": jnp.zeros((3,))})
    import pytest
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((4,))})


def test_restore_latest(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.ones((2,))})
    ckpt.save(str(tmp_path), 9, {"w": jnp.full((2,), 9.0)})
    out = ckpt.restore(str(tmp_path), {"w": jnp.zeros((2,))})
    np.testing.assert_allclose(out["w"], 9.0)
