"""Buffered-async round engine (DESIGN.md §11): runtime-model RNG
contracts, virtual-time arrival semantics, sync-anchor equivalence,
determinism across staging depths, dropout, and the mid-buffer bitwise
checkpoint.

The cross-regime allclose cells (async_buffer vs serial on the forced
8-device mesh) live in tests/test_regime_matrix.py; these are the fast
single-process contracts.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.runtime import (DeterministicRuntime, ExponentialRuntime,
                                HeavyTailRuntime, MarkovRuntime,
                                make_runtime, runtime_matrix)

NUM_CLIENTS = 8
K = 3


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def make_trainer(runtime=None, rounds=5, algo="feddpc", **exec_kw):
    kw = dict(clients_per_round=K, seed=7, eval_every=10 ** 9,
              async_buffer=True)
    kw.update(exec_kw)
    return FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                            ragged_batch_fn, ExecConfig(rounds=rounds, **kw),
                            algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1),
                            runtime=runtime)


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------- runtime-model RNG contracts ----------------

def test_deterministic_runtime_consumes_zero_draws():
    """The anchor-cell property: DeterministicRuntime must not move the
    trainer RNG at all (like CyclicSampler), so enabling async_buffer
    with the default runtime cannot perturb the sampled schedule."""
    rng = np.random.RandomState(3)
    before = rng.get_state()
    lat, dropped = DeterministicRuntime(2.5).draw(rng, 0, np.arange(4))
    after = rng.get_state()
    assert (lat == 2.5).all() and not dropped.any()
    assert before[0] == after[0] and (before[1] == after[1]).all()
    assert before[2:] == after[2:]


@pytest.mark.parametrize("cls,kw", [
    (ExponentialRuntime, dict(mean=0.7)),
    (HeavyTailRuntime, dict(shape=1.3, scale=0.4)),
])
def test_iid_runtimes_consume_two_config_independent_draws(cls, kw):
    """Exponential/heavytail consume exactly TWO draws per wave — one
    latency vector, one dropout vector — and the count must not depend
    on the dropout config (dropout=0 still burns the dropout draw), or
    a config change would shift every later round's schedule."""
    states = []
    for dropout in (0.0, 0.5):
        rng = np.random.RandomState(11)
        for wave in range(4):
            lat, dropped = cls(dropout=dropout, **kw).draw(
                rng, wave, np.arange(5))
            assert lat.shape == (5,) and (lat > 0).all()
            assert dropped.shape == (5,) and dropped.dtype == bool
        states.append(rng.get_state())
    assert (states[0][1] == states[1][1]).all()     # same rng trajectory
    # pin the exact call sequence (the resume contract): latency draw
    # then dropout draw, shaped by the cohort
    rng_a, rng_b = np.random.RandomState(5), np.random.RandomState(5)
    cls(dropout=0.2, **kw).draw(rng_a, 0, np.arange(7))
    if cls is ExponentialRuntime:
        rng_b.exponential(kw["mean"], size=7)
    else:
        rng_b.pareto(kw["shape"], size=7)
    rng_b.rand(7)
    assert (rng_a.get_state()[1] == rng_b.get_state()[1]).all()


def test_markov_runtime_three_draws_and_client_independent_chain():
    """MarkovRuntime consumes exactly THREE draws per wave (chain
    evolution over ALL clients, latency, dropout) and the chain
    trajectory is independent of WHICH clients were sampled — otherwise
    the sampler's cohort would leak into every later wave's latencies."""
    rng_a, rng_b = np.random.RandomState(2), np.random.RandomState(2)
    ra = MarkovRuntime(10, fast=0.5, slow=3.0, p_slow=0.4, p_fast=0.5)
    rb = MarkovRuntime(10, fast=0.5, slow=3.0, p_slow=0.4, p_fast=0.5)
    for wave in range(5):
        ra.draw(rng_a, wave, np.arange(3))
        rb.draw(rng_b, wave, np.array([7, 2, 9]))
    assert (ra._slow_state == rb._slow_state).all()
    assert (rng_a.get_state()[1] == rng_b.get_state()[1]).all()
    # exact draw sequence: rand(num_clients), exponential(k), rand(k)
    rng_c = np.random.RandomState(2)
    mc = MarkovRuntime(10, fast=0.5, slow=3.0, p_slow=0.4, p_fast=0.5)
    rng_d = np.random.RandomState(2)
    mc.draw(rng_c, 0, np.arange(4))
    rng_d.rand(10)
    rng_d.exponential(1.0, size=4)
    rng_d.rand(4)
    assert (rng_c.get_state()[1] == rng_d.get_state()[1]).all()


def test_markov_runtime_state_json_roundtrip():
    """The chain state survives the checkpoint JSON sidecar channel and
    a restored model continues the exact trajectory."""
    rng = np.random.RandomState(0)
    m = MarkovRuntime(6, p_slow=0.5, p_fast=0.5)
    for wave in range(3):
        m.draw(rng, wave, np.arange(2))
    snap_state = json.loads(json.dumps(m.state_dict()))
    snap_rng = rng.get_state()
    lat_a, drop_a = m.draw(rng, 3, np.arange(4))
    m2 = MarkovRuntime(6, p_slow=0.5, p_fast=0.5)
    m2.load_state_dict(snap_state)
    rng2 = np.random.RandomState(0)
    rng2.set_state(snap_rng)
    lat_b, drop_b = m2.draw(rng2, 3, np.arange(4))
    np.testing.assert_array_equal(lat_a, lat_b)
    np.testing.assert_array_equal(drop_a, drop_b)


def test_runtime_registry_and_config_echo():
    models = runtime_matrix(12)
    assert set(models) == {"deterministic", "exponential", "heavytail",
                           "markov"}
    for name, m in models.items():
        built = make_runtime(name, 12)
        assert type(built) is type(m)
        cfg = m.config_dict()
        assert cfg["class"] == type(m).__name__
        assert json.loads(json.dumps(cfg)) == cfg
    with pytest.raises(ValueError, match="unknown runtime"):
        make_runtime("nope", 4)
    with pytest.raises(ValueError):
        ExponentialRuntime(mean=-1.0)
    with pytest.raises(ValueError):
        HeavyTailRuntime(dropout=1.0)
    with pytest.raises(ValueError):
        MarkovRuntime(4, fast=2.0, slow=1.0)


# ---------------- engine semantics ----------------

def test_anchor_cell_matches_sync_round_in_process():
    """DeterministicRuntime + B=K + concurrency 1: the buffered-async
    run consumes the same schedule and reports staleness identically 0
    — arrivals keep wave order so every buffer holds exactly the
    current wave. (The allclose-vs-serial matrix cell is the subprocess
    test; here we pin schedule + zero staleness + loss closeness.)"""
    with make_trainer(async_buffer=False) as sync:
        sync.run()
    with make_trainer() as tr:           # registry defaults = anchor cell
        tr.run()
    for a, b in zip(sync.schedule, tr.schedule):
        assert (np.asarray(a) == np.asarray(b)).all()
    for r in tr.history:
        assert r.staleness_mean == 0.0 and r.staleness_max == 0.0
    for a, b in zip(sync.history, tr.history):
        assert b.train_loss == pytest.approx(a.train_loss, rel=1e-5)
    for x, y in zip(jax.tree.leaves(sync.params), jax.tree.leaves(tr.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_async_is_bitwise_deterministic_and_prefetch_independent():
    """Same seed + same runtime config => bitwise identical run, with
    the ingest producer staging ahead (prefetch=True) or blocking —
    the round-order RNG contract extended to runtime draws."""
    def go(prefetch):
        rt = MarkovRuntime(NUM_CLIENTS, fast=0.5, slow=3.0,
                           p_slow=0.4, p_fast=0.5, dropout=0.1)
        with make_trainer(runtime=rt, prefetch=prefetch, buffer_size=2,
                          async_concurrency=3) as tr:
            tr.run()
        return tr
    a, b, c = go(True), go(True), go(False)
    for other in (b, c):
        assert_trees_equal(a.params, other.params)
        assert_trees_equal(a.server_state, other.server_state)
        assert [r.train_loss for r in a.history] == \
            [r.train_loss for r in other.history]
        assert [(r.staleness_mean, r.staleness_max) for r in a.history] == \
            [(r.staleness_mean, r.staleness_max) for r in other.history]
        for s, t in zip(a.schedule, other.schedule):
            assert (np.asarray(s) == np.asarray(t)).all()


def test_staleness_appears_under_concurrency():
    """With B < K*concurrency and spread-out latencies, some buffered
    updates must be stale (version gap > 0) and the records say so."""
    rt = ExponentialRuntime(mean=1.0)
    with make_trainer(runtime=rt, buffer_size=2, async_concurrency=3,
                      rounds=6) as tr:
        tr.run()
    assert any(r.staleness_max > 0 for r in tr.history)
    for r in tr.history:
        assert r.staleness_mean <= r.staleness_max
        assert np.isfinite(r.train_loss)


def test_dropout_path_still_fills_buffers():
    """Dropped clients never reach the buffer; the engine keeps
    dispatching waves until every round's buffer fills anyway."""
    rt = ExponentialRuntime(mean=1.0, dropout=0.4)
    with make_trainer(runtime=rt, buffer_size=3, async_concurrency=2,
                      rounds=4) as tr:
        hist = tr.run()
    assert len(hist) == 4
    assert all(np.isfinite(r.train_loss) for r in hist)
    # dropout consumed waves: more cohorts were sampled than rounds run
    assert len(tr.schedule) >= len(hist)


def test_prescaling_algorithms_fold_staleness_too():
    """A non-staleness-aware rule (fedavg) takes the discount by delta
    pre-scaling — the run completes and differs from the undiscounted
    one once staleness is non-zero."""
    def go(alpha):
        rt = ExponentialRuntime(mean=1.0)
        with make_trainer(runtime=rt, algo="fedavg", buffer_size=2,
                          async_concurrency=3, staleness_alpha=alpha) as tr:
            tr.run()
        return tr
    a, b = go(0.5), go(2.0)
    assert any(r.staleness_max > 0 for r in a.history)
    la = jax.tree.leaves(a.params)
    lb = jax.tree.leaves(b.params)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_config_validation():
    with pytest.raises(ValueError, match="async_buffer"):
        make_trainer(runtime=DeterministicRuntime(), async_buffer=False)
    with pytest.raises(ValueError, match="vectorize"):
        make_trainer(vectorize=False)


# ---------------- mid-buffer checkpointing ----------------

def _markov_rt():
    return MarkovRuntime(NUM_CLIENTS, fast=0.5, slow=3.0,
                         p_slow=0.4, p_fast=0.5, dropout=0.1)


def test_mid_buffer_save_resume_is_bitwise(tmp_path):
    """The acceptance criterion: cut the run at a server-round boundary
    with IN-FLIGHT waves on the virtual-time heap (concurrency 3 >
    buffer 2 guarantees a non-empty heap), save, resume in a fresh
    trainer — params, server state, losses, staleness series, and the
    schedule all reproduce the uninterrupted run bitwise."""
    kw = dict(buffer_size=2, async_concurrency=3, rounds=6)
    with make_trainer(runtime=_markov_rt(), **kw) as full:
        full.run()
    with make_trainer(runtime=_markov_rt(), **kw) as part:
        for t in range(3):
            part.run_round(t)
        assert len(part._engine.inflight()) > 0      # mid-buffer cut
        part.save(str(tmp_path))
    res = FederatedTrainer.resume(
        str(tmp_path), loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
        ExecConfig(clients_per_round=K, seed=7, eval_every=10 ** 9,
                   async_buffer=True, **kw),
        algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1),
        runtime=_markov_rt())
    with res:
        assert res.start_round == 3
        res.run()
    assert_trees_equal(full.params, res.params)
    assert_trees_equal(full.server_state, res.server_state)
    assert [r.train_loss for r in full.history] == \
        [r.train_loss for r in res.history]
    assert [(r.staleness_mean, r.staleness_max) for r in full.history] == \
        [(r.staleness_mean, r.staleness_max) for r in res.history]
    for a, b in zip(full.schedule, res.schedule):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_resume_rejects_mismatched_async_config(tmp_path):
    """The checkpoint echoes buffer/alpha/concurrency and the runtime
    config; resuming under a different async parameterization must fail
    loudly instead of silently diverging."""
    kw = dict(buffer_size=2, async_concurrency=3, rounds=4)
    with make_trainer(runtime=_markov_rt(), **kw) as tr:
        tr.run_round(0)
        tr.save(str(tmp_path))
    common = dict(clients_per_round=K, seed=7, eval_every=10 ** 9,
                  async_buffer=True)
    algo = AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1)
    with pytest.raises(ValueError):
        FederatedTrainer.resume(
            str(tmp_path), loss_fn, make_params(), NUM_CLIENTS,
            ragged_batch_fn,
            ExecConfig(rounds=4, buffer_size=3, async_concurrency=3,
                       **common), algo=algo, runtime=_markov_rt())
    with pytest.raises(ValueError):
        FederatedTrainer.resume(
            str(tmp_path), loss_fn, make_params(), NUM_CLIENTS,
            ragged_batch_fn,
            ExecConfig(**common, **kw), algo=algo,
            runtime=ExponentialRuntime())
    with pytest.raises(ValueError):
        # async checkpoint into a sync trainer
        FederatedTrainer.resume(
            str(tmp_path), loss_fn, make_params(), NUM_CLIENTS,
            ragged_batch_fn,
            ExecConfig(rounds=4, clients_per_round=K, seed=7,
                       eval_every=10 ** 9), algo=algo)
