"""Data pipeline: Dirichlet partitioning properties + synthetic datasets."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.dirichlet import dirichlet_partition, partition_stats
from repro.data.synthetic import make_image_dataset, make_lm_dataset
from repro.ingest import build_federated_image_data, client_batches

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 10_000), st.sampled_from([0.1, 0.5, 2.0]),
       st.integers(3, 12))
def test_partition_disjoint_and_covering(seed, alpha, k):
    labels = np.random.RandomState(seed).randint(0, 5, size=300)
    parts = dirichlet_partition(labels, k, alpha, seed=seed, min_size=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)       # disjoint + covering


def test_alpha_controls_heterogeneity():
    """Smaller alpha -> more skewed label marginals (higher TV distance)."""
    labels = np.random.RandomState(0).randint(0, 10, size=5000)
    tv = {}
    for alpha in (0.1, 10.0):
        parts = dirichlet_partition(labels, 20, alpha, seed=1)
        tv[alpha] = partition_stats(labels, parts)["mean_tv_from_uniform"]
    assert tv[0.1] > tv[10.0] + 0.1


def test_image_dataset_learnable_structure():
    x, y = make_image_dataset(num_classes=4, samples_per_class=50, seed=0)
    assert x.shape == (200, 32, 32, 3) and y.shape == (200,)
    # class templates are distinct: intra-class distance < inter-class
    means = np.stack([x[y == c].mean(0) for c in range(4)])
    intra = np.mean([np.sqrt(((x[y == c] - means[c]) ** 2
                              ).sum(axis=(1, 2, 3))).mean()
                     for c in range(4)])
    inter = np.mean([np.linalg.norm(means[a] - means[b])
                     for a in range(4) for b in range(4) if a != b])
    assert inter > 1.0 and np.isfinite(intra)


def test_lm_dataset_topics_skew_vocab():
    toks, topics = make_lm_dataset(64, 32, 500, seed=0, num_topics=4)
    assert toks.shape == (64, 32)
    assert toks.max() < 500 and toks.min() >= 0
    # different topics -> different token histograms
    h = []
    for t in range(2):
        sel = toks[topics == t].reshape(-1)
        h.append(np.bincount(sel, minlength=500) / max(len(sel), 1))
    assert np.abs(h[0] - h[1]).sum() > 0.1


def test_client_batches_wrap_small_clients():
    data = build_federated_image_data(num_classes=4, num_clients=12,
                                      alpha=0.1, samples_per_class=60,
                                      test_per_class=5, seed=0)
    smallest = int(np.argmin([len(i) for i in data.client_indices]))
    batches = list(client_batches(data, smallest, batch_size=16, round_num=0))
    assert len(batches) >= 1
    assert batches[0]["images"].shape[0] == 16


def test_batches_reshuffle_across_rounds():
    data = build_federated_image_data(num_classes=4, num_clients=5,
                                      alpha=1.0, samples_per_class=50,
                                      test_per_class=5, seed=0)
    b0 = next(iter(client_batches(data, 0, 8, round_num=0)))
    b1 = next(iter(client_batches(data, 0, 8, round_num=1)))
    assert not np.allclose(b0["images"], b1["images"])
