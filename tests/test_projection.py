"""Unit + property tests for the FedDPC projection/scaling math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import projection as proj

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def _vec_tree(seed, scale=1.0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"a": jax.random.normal(k1, (7, 5)) * scale,
            "b": [jax.random.normal(k2, (11,)) * scale]}


def _flat(t):
    return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(t)])


def test_tree_vdot_matches_flat():
    a, b = _vec_tree(0), _vec_tree(1)
    assert np.isclose(float(proj.tree_vdot(a, b)),
                      float(jnp.vdot(_flat(a), _flat(b))), rtol=1e-6)


def test_projection_coefficient_formula():
    a, b = _vec_tree(0), _vec_tree(1)
    coef = proj.project_coefficient(a, b)
    want = jnp.vdot(_flat(a), _flat(b)) / jnp.vdot(_flat(b), _flat(b))
    assert np.isclose(float(coef), float(want), rtol=1e-6)


def test_projection_onto_zero_is_zero():
    a = _vec_tree(0)
    z = proj.tree_zeros_like(a)
    assert float(proj.project_coefficient(a, z)) == 0.0
    scaled, diag = proj.project_and_scale(a, z, lam=1.0)
    # residual == delta; scale == lam + 1 -> scaled == 2 * delta
    np.testing.assert_allclose(_flat(scaled), 2.0 * _flat(a), rtol=1e-5)


@given(st.integers(0, 2**16), st.integers(0, 2**16),
       st.floats(0.0, 2.0))
def test_residual_orthogonal_to_prev(s1, s2, lam):
    d, p = _vec_tree(s1), _vec_tree(s2 + 100)
    scaled, diag = proj.project_and_scale(d, p, lam=lam)
    dot = float(proj.tree_vdot(scaled, p))
    norm = float(proj.tree_norm(scaled)) * float(proj.tree_norm(p))
    if norm > 1e-6:
        assert abs(dot) / norm < 1e-3      # cos angle ~ 0


@given(st.integers(0, 2**16), st.floats(0.0, 2.0))
def test_scale_at_least_lam_plus_one(seed, lam):
    # ||resid|| <= ||delta||  =>  scale = lam + ||d||/||r|| >= lam + 1
    d, p = _vec_tree(seed), _vec_tree(seed + 7)
    _, diag = proj.project_and_scale(d, p, lam=lam)
    assert float(diag["scale"]) >= lam + 1.0 - 1e-4


def test_pythagoras_residual_norm():
    d, p = _vec_tree(3), _vec_tree(4)
    scaled, diag = proj.project_and_scale(d, p, lam=0.0)
    coef = diag["coef"]
    resid = jax.tree.map(lambda a, b: a - coef * b, d, p)
    assert np.isclose(float(diag["norm_resid"]),
                      float(jnp.linalg.norm(_flat(resid))), rtol=1e-4)


def test_scaled_residual_direction():
    d, p = _vec_tree(5), _vec_tree(6)
    scaled, diag = proj.project_and_scale(d, p, lam=1.0)
    coef, scale = diag["coef"], diag["scale"]
    want = scale * (_flat(d) - coef * _flat(p))
    np.testing.assert_allclose(_flat(scaled), want, rtol=1e-5, atol=1e-5)


def test_kernel_path_matches_reference():
    d, p = _vec_tree(8), _vec_tree(9)
    ref, _ = proj.project_and_scale(d, p, lam=1.0, use_kernel=False)
    ker, _ = proj.project_and_scale(d, p, lam=1.0, use_kernel=True)
    np.testing.assert_allclose(_flat(ref), _flat(ker), rtol=1e-5, atol=1e-5)
