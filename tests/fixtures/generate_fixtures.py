"""Regenerate the committed dataset-reader fixtures under
tests/fixtures/data/ — tiny synthetic datasets in the EXACT on-disk
formats of the real downloads (cifar-10-batches-py / cifar-100-python /
tiny-imagenet-200), written by the ingest subsystem's own fixture
writers (repro.ingest.readers.write_*_fixture), so the reader smoke lane
runs without network access.

  PYTHONPATH=src python tests/fixtures/generate_fixtures.py

Deterministic (fixed seeds): re-running reproduces the committed bytes.
TinyImageNet images are .npy (decodable without PIL); CIFAR pickles are
protocol-default python pickles like the originals.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "..", "src"))

from repro.ingest.readers import (write_cifar10_fixture,          # noqa: E402
                                  write_cifar100_fixture,
                                  write_tiny_imagenet_fixture)

DATA_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")


def main():
    os.makedirs(DATA_DIR, exist_ok=True)
    print(write_cifar10_fixture(DATA_DIR, per_class=4, test_per_class=2,
                                train_batches=2, seed=0))
    print(write_cifar100_fixture(DATA_DIR, num_classes=20, per_class=2,
                                 test_per_class=1, seed=1))
    print(write_tiny_imagenet_fixture(DATA_DIR, num_wnids=4, per_wnid=4,
                                      val_per_wnid=1, seed=2))


if __name__ == "__main__":
    main()
